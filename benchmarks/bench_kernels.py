"""Kernel microbenchmarks: correctness re-check at paper dims + analytic
VMEM footprints per BlockSpec (the CPU container cannot time TPU kernels;
interpret-mode wall time is meaningless — footprints and oracle agreement
are what transfer)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.mla_decode import mla_decode_kernel

from .common import check, save, table


def mla_vmem_footprint(H=128, D=576, v_dim=512, block_k=512) -> dict:
    f32 = 4
    return {
        "q (H,D)": H * D * f32,
        "cache block (bk,D)": block_k * D * f32,
        "scores (H,bk)": H * block_k * f32,
        "acc (H,v)": H * v_dim * f32,
        "m+l (H,2)": H * 2 * f32,
    }


def run() -> bool:
    # paper dims, interpret mode, vs oracle
    B, H, S, Dl, Dr = 1, 128, 2048, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Dl + Dr), jnp.float32)
    ckv = jax.random.normal(ks[1], (B, S, Dl), jnp.float32)
    krope = jax.random.normal(ks[2], (B, S, Dr), jnp.float32)
    t0 = time.time()
    out = mla_decode_kernel(q, ckv, krope, S - 1, block_k=512, interpret=True)
    dt = time.time() - t0
    want = ref.mla_decode_ref(q, ckv, krope, S - 1)
    err = float(jnp.max(jnp.abs(out - want)))
    ok = check(
        "mla_decode kernel == oracle at DeepSeek dims",
        err < 1e-4,
        f"max err {err:.2e} ({dt:.1f}s interpret)",
    )

    fp = mla_vmem_footprint()
    total = sum(fp.values())
    rows = [[k, f"{v/2**10:.0f} KiB"] for k, v in fp.items()]
    rows.append(["TOTAL", f"{total/2**20:.2f} MiB"])
    md = (
        "# Kernel VMEM budgets (TPU v5e: ~128 MiB VMEM/core)\n\n"
        "## mla_decode (grid (B, nk), block_k=512)\n\n"
        + table(["buffer", "bytes"], rows)
    )
    save("kernel_vmem.md", md)
    print(md)
    ok &= check(
        "mla_decode VMEM fits v5e", total < 100 * 2**20, f"{total/2**20:.2f} MiB"
    )
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
