"""Regression gate over the serving-bench artifacts (the CI `bench-gate`
job): diff `benchmarks/artifacts/*.json` against the committed baselines
in `benchmarks/baselines/` with per-metric tolerance rules and exit
non-zero on any regression.

Three rule kinds, chosen per metric for cross-machine honesty:

  eq         deterministic structure — token counts, compile counts,
             hit rates, acceptance of the identity draft.  These are
             seeded and topology-invariant, so CI compares them exactly.
  approx     closed-form model outputs (modeled bytes, break-even,
             operational intensity) — jax-independent arithmetic, gated
             to a tiny relative tolerance so cost-model regressions trip.
  min_ratio / max_ratio
             wall-clock metrics (tokens/s, step ms) — the baseline was
             measured on a different machine than CI, so only large
             moves in the BAD direction fail.

A baseline key missing from the fresh artifact fails too (a silently
dropped bench row is itself a regression).  Refresh baselines after an
intentional change with:

    PYTHONPATH=src python benchmarks/bench_serving.py ... && \
        python benchmarks/check_regression.py --update
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

HERE = os.path.dirname(os.path.abspath(__file__))

# (file, dotted metric path, kind, tolerance) — kinds per the docstring.
RULES = [
    # throughput: generous ratio (CI runners differ from the baseline box)
    ("bench_serving.json", "paged_prefix.tokens_per_s", "min_ratio", 0.25),
    # deterministic serving structure: exact
    ("bench_serving.json", "paged_prefix.decode_tokens", "eq", None),
    ("bench_serving.json", "paged_prefix.prefill_tokens", "eq", None),
    ("bench_serving.json", "paged_prefix.prefill_compiles", "eq", None),
    ("bench_serving.json", "paged_prefix.total_blocks_allocated", "eq", None),
    ("bench_serving.json", "paged_prefix.prefix_hit_rate", "approx", 1e-9),
    ("bench_serving.json", "paged.prefill_tokens", "eq", None),
    ("bench_serving.json", "paged.prefill_compiles", "eq", None),
    ("bench_serving.json", "paged_mesh.decode_tokens", "eq", None),
    ("bench_serving.json", "paged_mesh.prefill_compiles", "eq", None),
    ("bench_serving.json", "util_gain", "approx", 0.05),
    # speculative decoding: the oracle and the compile bounds are exact;
    # shallow-draft acceptance is numerics-adjacent, so ratio-gated
    ("bench_serving.json", "paged_spec.self.spec_accept_rate", "eq", None),
    ("bench_serving.json", "paged_spec.self.decode_tokens", "eq", None),
    ("bench_serving.json", "paged_spec.self.spec_compiles", "eq", None),
    ("bench_serving.json", "paged_spec.shallow.spec_compiles", "eq", None),
    ("bench_serving.json", "paged_spec.shallow.prefill_compiles", "eq", None),
    ("bench_serving.json", "paged_spec.shallow.spec_mean_emitted", "min_ratio", 0.7),
    ("bench_serving.json", "paged_spec.shallow_mesh.decode_tokens", "eq", None),
    # quantized int8 pool (PR 8): serving structure is seeded/exact; the
    # modeled byte shrink and OI shift are closed-form; the oracle error
    # is quantization numerics (deterministic per seed, but FP-summation
    # order can wiggle across BLAS builds) so only a blowup fails
    ("bench_serving.json", "paged_quant.decode_tokens", "eq", None),
    ("bench_serving.json", "paged_quant.prefill_compiles", "eq", None),
    ("bench_serving.json", "paged_quant.cache_token_bytes", "approx", 1e-9),
    ("bench_serving.json", "paged_quant.model.cache_read_ratio", "approx", 1e-6),
    ("bench_serving.json", "paged_quant.model.token_bytes_ratio", "approx", 1e-6),
    ("bench_serving.json", "paged_quant.model.attn_oi_int8", "approx", 1e-9),
    ("bench_serving.json", "paged_quant.model.rescale_multiplies_exp_add", "eq", None),
    ("bench_serving.json", "paged_quant.oracle_max_err", "max_ratio", 5.0),
    ("bench_serving.json", "paged_quant.tokens_per_s", "min_ratio", 0.25),
    # closed-form cost model: near-exact
    ("bench_serving.json", "paged_spec.model.verify_bytes", "approx", 1e-9),
    ("bench_serving.json", "paged_spec.model.decode_bytes", "approx", 1e-9),
    ("bench_serving.json", "paged_spec.model.break_even_emitted", "approx", 1e-6),
    ("bench_serving.json", "paged_mesh.model_dp_bytes.dp2_cache_read", "approx", 1e-9),
    ("bench_prefill_kernel.json", "gather.model_bytes", "approx", 1e-9),
    ("bench_prefill_kernel.json", "pallas.model_bytes", "approx", 1e-9),
    ("bench_prefill_kernel.json", "gather.attn_oi", "approx", 1e-9),
    ("bench_prefill_kernel.json", "pallas.attn_oi", "approx", 1e-9),
    ("bench_prefill_kernel.json", "gather.compiles", "eq", None),
    ("bench_prefill_kernel.json", "pallas.compiles", "eq", None),
    # step latency: only a large slowdown fails
    ("bench_prefill_kernel.json", "gather.step_ms", "max_ratio", 4.0),
    ("bench_prefill_kernel.json", "pallas.step_ms", "max_ratio", 4.0),
    # roofline drift (PR 7): structure is deterministic (row counts per
    # kind, dispatched-scheme coverage) so it's exact; the measured/
    # modeled time ratio is CPU wall vs a TPU model, so only its p50 and
    # p95/p50 spread are held, with wide bands — jit-compile outliers
    # land in the p95 and CI boxes differ from the baseline machine.
    ("bench_drift.json", "report.rows", "eq", None),
    ("bench_drift.json", "report.kinds.decode.schemes", "eq", None),
    ("bench_drift.json", "report.kinds.decode.rows", "eq", None),
    ("bench_drift.json", "report.kinds.verify.schemes", "eq", None),
    ("bench_drift.json", "report.kinds.verify.rows", "eq", None),
    ("bench_drift.json", "report.kinds.prefill.rows", "eq", None),
    ("bench_drift.json", "report.summary.time_ratio_p50", "max_ratio", 8.0),
    ("bench_drift.json", "report.summary.spread", "max_ratio", 10.0),
    ("bench_drift.json", "ttft_ms.count", "eq", None),
    # open-loop load harness (PR 9): step-denominated metrics are
    # deterministic (seeded arrivals, seeded sampling, step-count
    # arithmetic) so the knee and the trace-driven row are exact; wall
    # throughput and TTFT latency get the usual wide cross-machine bands
    ("bench_load.json", "trace_driven.async.parity", "eq", None),
    ("bench_load.json", "trace_driven.async.decode_tokens", "eq", None),
    ("bench_load.json", "trace_driven.async.steps", "eq", None),
    ("bench_load.json", "trace_driven.async.goodput_slo", "approx", 1e-9),
    ("bench_load.json", "knee.decode_tokens", "eq", None),
    ("bench_load.json", "knee.achieved_tok_per_step", "approx", 1e-6),
    ("bench_load.json", "knee.knee_frac", "approx", 1e-6),
    ("bench_load.json", "knee.model.step_time_us", "approx", 1e-6),
    ("bench_load.json", "overlap.validated", "eq", None),
    ("bench_load.json", "overlap.device_overlaps_schedule", "eq", None),
    # saturation-knee wall throughput + TTFT p99 at the fixed bursty
    # offered load: measured on a different box than CI, so only large
    # moves in the bad direction fail
    ("bench_load.json", "knee.tokens_per_s", "min_ratio", 0.25),
    ("bench_load.json", "trace_driven.async.ttft_ms.p99", "max_ratio", 8.0),
    # multi-turn conversation tree + n-way parallel sampling (PR 10):
    # transcripts, hit rates, prefill-token counts and block totals are
    # seeded/deterministic so CI holds them exactly; the warm/cold TTFT
    # ratio is wall-clock but SAME-RUN same-machine, so its band is
    # tighter than the cross-machine ones; group throughput gets the
    # usual wide cross-machine band
    ("bench_multiturn.json", "multiturn.parity", "eq", None),
    ("bench_multiturn.json", "multiturn.hit_rate_lift", "approx", 1e-9),
    ("bench_multiturn.json", "multiturn.warm.prefix_hit_rate", "approx", 1e-9),
    ("bench_multiturn.json", "multiturn.warm.prefill_tokens", "eq", None),
    ("bench_multiturn.json", "multiturn.cold.prefill_tokens", "eq", None),
    ("bench_multiturn.json", "multiturn.warm_turn_prefill_tokens", "eq", None),
    ("bench_multiturn.json", "multiturn.cold_turn_prefill_tokens", "eq", None),
    (
        "bench_multiturn.json",
        "multiturn.warm.prefix_decode_inserted_blocks",
        "eq",
        None,
    ),
    ("bench_multiturn.json", "multiturn.warm_over_cold_ttft", "max_ratio", 2.0),
    ("bench_multiturn.json", "multiturn.warm.tokens_per_s", "min_ratio", 0.25),
    ("bench_multiturn.json", "fork.sync.parity", "eq", None),
    ("bench_multiturn.json", "fork.async.parity", "eq", None),
    ("bench_multiturn.json", "fork.sync.group_blocks", "eq", None),
    ("bench_multiturn.json", "fork.sync.independent_blocks", "eq", None),
    ("bench_multiturn.json", "fork.sync.block_savings", "approx", 1e-6),
    ("bench_multiturn.json", "fork.sync.decode_tokens", "eq", None),
    ("bench_multiturn.json", "fork.async.decode_tokens", "eq", None),
    ("bench_multiturn.json", "fork.async.group_blocks", "eq", None),
    ("bench_multiturn.json", "fork.sync.tokens_per_s", "min_ratio", 0.25),
]


def lookup(tree, dotted):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check_one(kind, tol, base, got):
    if kind == "eq":
        return base == got, f"expected exactly {base}, got {got}"
    if kind == "approx":
        denom = max(abs(base), 1e-12)
        rel = abs(got - base) / denom
        return rel <= tol, f"|{got} - {base}| / {denom:.3g} = {rel:.3g} > {tol}"
    if kind == "min_ratio":
        return got >= base * tol, f"{got} < {tol} x baseline {base}"
    if kind == "max_ratio":
        return got <= base * tol, f"{got} > {tol} x baseline {base}"
    raise ValueError(kind)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=os.path.join(HERE, "artifacts"))
    ap.add_argument("--baselines", default=os.path.join(HERE, "baselines"))
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the current artifacts over the baselines and exit",
    )
    args = ap.parse_args()

    names = sorted({f for f, *_ in RULES})
    if args.update:
        missing = [
            n
            for n in names
            if not os.path.exists(os.path.join(args.artifacts, n))
        ]
        if missing:
            print(
                f"[FAIL] cannot update baselines: artifact(s) missing from "
                f"{args.artifacts}: {', '.join(missing)} — run the bench "
                f"first (make bench-smoke); no baseline was touched"
            )
            return 1
        os.makedirs(args.baselines, exist_ok=True)
        for name in names:
            src = os.path.join(args.artifacts, name)
            shutil.copy(src, os.path.join(args.baselines, name))
            print(f"baseline updated: {name}")
        return 0

    failures = 0
    for name in names:
        base_path = os.path.join(args.baselines, name)
        got_path = os.path.join(args.artifacts, name)
        if not os.path.exists(base_path):
            print(f"[FAIL] {name}: no committed baseline ({base_path})")
            failures += 1
            continue
        if not os.path.exists(got_path):
            print(f"[FAIL] {name}: bench artifact missing ({got_path})")
            failures += 1
            continue
        with open(base_path) as f:
            base = json.load(f)
        with open(got_path) as f:
            got = json.load(f)
        for fname, path, kind, tol in RULES:
            if fname != name:
                continue
            b, g = lookup(base, path), lookup(got, path)
            if b is None:
                # metric not in the committed baseline yet: advisory only
                print(f"[SKIP] {name}:{path} — not in baseline")
                continue
            if g is None:
                print(f"[FAIL] {name}:{path} — dropped from the artifact")
                failures += 1
                continue
            ok, detail = check_one(kind, tol, b, g)
            mark = "PASS" if ok else "FAIL"
            print(f"[{mark}] {name}:{path} ({kind}) — {detail if not ok else g}")
            failures += 0 if ok else 1
    if failures:
        print(f"\n{failures} bench regression(s) vs committed baselines")
        return 1
    print("\nno bench regressions vs committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
