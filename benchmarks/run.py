"""Benchmark driver: one module per paper table/figure + kernel + roofline.

    PYTHONPATH=src python -m benchmarks.run

Exit status is non-zero if any paper-claim check fails.
"""
import sys

from . import (
    bench_fig2_ordering,
    bench_fig3_ops_mem,
    bench_fig4_oi,
    bench_fig5_throughput,
    bench_fig6_energy,
    bench_kernels,
    bench_table1_params,
    roofline_report,
)

SUITES = [
    ("Table 1 — attention-layer param counts", bench_table1_params.run),
    ("Fig 2 — matmul ordering op counts", bench_fig2_ordering.run),
    ("Fig 3 — ops & memory accesses", bench_fig3_ops_mem.run),
    ("Fig 4 — operational intensity", bench_fig4_oi.run),
    ("Fig 5 — throughput vs compute/BW ratio", bench_fig5_throughput.run),
    ("Fig 6 — energy vs TOPS/W", bench_fig6_energy.run),
    ("Pallas kernels — oracle agreement + VMEM budgets", bench_kernels.run),
    ("Roofline report (single-pod artifacts)", lambda: roofline_report.run("16x16")),
    ("Roofline report (multi-pod artifacts)", lambda: roofline_report.run("2x16x16")),
]


def main() -> int:
    failures = []
    for name, fn in SUITES:
        print(f"\n{'='*72}\n{name}\n{'='*72}")
        try:
            ok = fn()
        except Exception:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            ok = False
        if not ok:
            failures.append(name)
    print(f"\n{'='*72}")
    if failures:
        print(f"{len(failures)} suite(s) FAILED: {failures}")
        return 1
    print(f"all {len(SUITES)} benchmark suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
