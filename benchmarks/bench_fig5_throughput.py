"""Paper Fig 5: attainable throughput of one attention layer vs the
platform's compute-to-bandwidth ratio (fixed 400 GB/s DRAM, batch=1),
three cache sizes.

Reproduced claims:
  * MLA_rc highest throughput except when compute is scarce relative to
    bandwidth (low ratio) — there MLA_ru wins;
  * a crossover ratio exists between ru and rc;
  * MHA throughput is highly cache-size sensitive; MLA is stable;
  * rc/ru compute the same function -> runtime-dispatchable (auto_dispatch).
"""
import numpy as np

from repro.core.schemes import PlatformPoint, auto_dispatch
from repro.hwmodel import attention_costs as ac
from repro.hwmodel import roofline as R

from .common import check, save, table

BW = 400e9
RATIOS = [0.5, 2, 8, 32, 128, 512, 2048]  # FLOP/s per B/s (ridge OI)
CACHES = [1024, 16384, 262144]
METHODS = ["mha_l", "mha_s", "mla_ru", "mla_rc"]


def throughput(method: str, ratio: float, L: int) -> float:
    plat = PlatformPoint(f"r{ratio}", peak_flops=ratio * BW, hbm_bw=BW)
    return R.throughput(R.decode_cost(method, cache_len=L), plat)


def run() -> bool:
    md_parts = [
        "# Fig 5 — layer throughput vs compute/bandwidth ratio "
        "(400 GB/s, B=1)\n"
    ]
    for L in CACHES:
        rows = [
            [r]
            + [f"{throughput(m, r, L):.3g}" for m in METHODS]
            + [max(METHODS, key=lambda m: throughput(m, r, L))]
            for r in RATIOS
        ]
        md_parts.append(
            f"\n## cache = {L}\n\n"
            + table(["ratio (FLOP/B)"] + METHODS + ["best"], rows)
        )
    md = "".join(md_parts)
    save("fig5_throughput.md", md)
    print(md)

    ok = True
    for L in CACHES:
        best_hi = max(METHODS, key=lambda m: throughput(m, 2048, L))
        ok &= check(
            f"L={L}: MLA_rc best on compute-rich platforms", best_hi == "mla_rc"
        )
    # ru > rc at sufficiently low ratio (paper's "uncommon case")
    lo = min(RATIOS)
    ok &= check(
        "MLA_ru beats rc at low compute/BW ratio",
        throughput("mla_ru", lo, 16384) > throughput("mla_rc", lo, 16384),
    )
    # crossover exists and auto_dispatch flips there
    ratios = np.geomspace(0.25, 4096, 200)
    flips = [
        auto_dispatch(
            ac.DSV3_MLA, PlatformPoint("x", r * BW, BW), 16384, candidates=("rc", "ru")
        )
        for r in ratios
    ]
    ok &= check(
        "auto_dispatch crossover ru->rc",
        "ru" in flips and "rc" in flips and flips.index("rc") > 0,
    )
    # MHA cache-sensitivity vs MLA stability at a typical ratio
    r = 128
    mha_spread = throughput("mha_s", r, 1024) / throughput("mha_s", r, 262144)
    mla_spread = throughput("mla_rc", r, 1024) / throughput("mla_rc", r, 262144)
    ok &= check(
        "MHA throughput cache-sensitive, MLA stable",
        mha_spread > 10 * mla_spread,
        f"mha x{mha_spread:.0f} vs mla x{mla_spread:.1f}",
    )
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
