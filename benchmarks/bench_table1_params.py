"""Paper Table 1: parameters of one attention layer — MLA vs MHA variants.

Claim reproduced EXACTLY: MLA 174M / MHA_derived 470M / MHA_scaled 172M.
"""
from repro.core import mla as M
from repro.hwmodel import attention_costs as ac

from .common import check, save, table


def run() -> bool:
    rows = [
        ["D_model", 7168, 7168, 4363],
        ["n_h", 128, 128, 128],
        ["D_Q,l", 1536, "-", "-"],
        ["D_KV,l", 512, "-", "-"],
        ["D_QK", 128, 128, 77],
        ["D_V", 128, 128, 77],
    ]
    mla = M.param_count(ac.DSV3_MLA, rope=False)
    mla_rope = M.param_count(ac.DSV3_MLA, rope=True)
    mha_l = ac.MHA_L.param_count()
    mha_s = ac.MHA_S.param_count()
    rows.append(
        [
            "#params (paper, no RoPE)",
            f"{mla/1e6:.1f}M",
            f"{mha_l/1e6:.1f}M",
            f"{mha_s/1e6:.1f}M",
        ]
    )
    rows.append(["#params (deployed, +RoPE head)", f"{mla_rope/1e6:.1f}M", "-", "-"])
    md = "# Table 1 — params per attention layer\n\n" + table(
        ["Parameter", "MLA", "MHA (derived)", "MHA (scaled)"], rows
    )
    save("table1_params.md", md)
    print(md)
    ok = check("MLA = 174M", round(mla / 1e6) == 174, f"{mla/1e6:.3f}M")
    ok &= check("MHA_l = 470M", round(mha_l / 1e6) == 470, f"{mha_l/1e6:.3f}M")
    ok &= check("MHA_s = 172M", round(mha_s / 1e6) == 172, f"{mha_s/1e6:.3f}M")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
