"""Paper Fig 4: operational intensity (ops/byte) vs sequence / cache length
with roofline corner points of known platforms.

Reproduced claims:
  * prefill: all methods high OI;
  * decode: MHA flat-low regardless of cache; MLA_ru OI grows with cache
    (weight cost amortizes); MLA_rc high OI, mildly cache-sensitive;
  * platform ridge points separate the methods (Edge TPU vs A17 Pro).
"""
from repro.hwmodel import roofline as R
from repro.hwmodel.platforms import PLATFORMS

from .common import check, save, table

METHODS = ["mha_l", "mha_s", "mla_ru", "mla_rc", "mla_seq"]
LENGTHS = [512, 2048, 8192, 32768, 131072, 524288]


def run() -> bool:
    rows = []
    for L in LENGTHS:
        d = {m: R.decode_cost(m, cache_len=L).oi for m in METHODS}
        p = R.prefill_cost("mla_rc", seq_len=min(L, 32768)).oi
        rows.append([L] + [f"{d[m]:.1f}" for m in METHODS] + [f"{p:.0f}"])
    ridge = {n: f"{pl.ridge_oi:.0f}" for n, pl in PLATFORMS.items()}
    md = (
        "# Fig 4 — decode operational intensity (ops/B) vs cache length\n\n"
        + table(["cache L"] + METHODS + ["(prefill mla)"], rows)
        + "\nPlatform ridge OIs (roofline corners): "
        + ", ".join(f"{k}={v}" for k, v in ridge.items())
        + "\n"
    )
    save("fig4_oi.md", md)
    print(md)

    oi = lambda m, L: R.decode_cost(m, cache_len=L).oi  # noqa: E731
    ok = check("MHA OI flat and low", max(oi("mha_l", L) for L in LENGTHS) < 2)
    ok &= check(
        "MLA_ru OI cache-dependent (x>20 over sweep)",
        oi("mla_ru", 524288) / oi("mla_ru", 512) > 20,
    )
    ok &= check(
        "MLA_rc OI high & stable (<3x over sweep)",
        oi("mla_rc", 524288) / oi("mla_rc", 512) < 3 and oi("mla_rc", 512) > 50,
    )
    edge = PLATFORMS["edge_tpu"]
    a17 = PLATFORMS["a17_pro"]
    ok &= check(
        "MLA_rc near Edge-TPU ridge, below A17 ridge (paper text)",
        oi("mla_rc", 8192) > 0.15 * edge.ridge_oi
        and oi("mla_rc", 8192) < a17.ridge_oi,
    )
    ok &= check(
        "prefill OI high for all methods",
        all(
            R.prefill_cost(m, seq_len=4096).oi > 500
            for m in ("mha_l", "mha_s", "mla_rc")
        ),
    )
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
