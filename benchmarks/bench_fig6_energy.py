"""Paper Fig 6: energy per attention layer vs on-chip efficiency (TOPS/W)
at fixed E_DRAM = 8 pJ/bit, batch=1.

Reproduced claims:
  * MLA_ru is the most robust across hardware efficiency points;
  * MLA_rc best-performing does NOT universally extend to energy;
  * MHA_s can win at small caches but its spread across caches is large.
"""
from repro.hwmodel import roofline as R
from repro.hwmodel.platforms import EnergyModel

from .common import check, save, table

TOPS_W = [0.5, 2, 8, 32, 128]
CACHES = [1024, 16384, 262144]
METHODS = ["mha_l", "mha_s", "mla_ru", "mla_rc"]


def energy(method: str, tw: float, L: int) -> float:
    em = EnergyModel(tops_per_w=tw)
    return R.energy_pj(R.decode_cost(method, cache_len=L), em)


def run() -> bool:
    md_parts = [
        "# Fig 6 — layer energy (pJ) vs on-chip TOPS/W "
        "(E_DRAM,bit = 8 pJ, B=1)\n"
    ]
    for L in CACHES:
        rows = [
            [tw]
            + [f"{energy(m, tw, L):.4g}" for m in METHODS]
            + [min(METHODS, key=lambda m: energy(m, tw, L))]
            for tw in TOPS_W
        ]
        md_parts.append(
            f"\n## cache = {L}\n\n" + table(["TOPS/W"] + METHODS + ["best"], rows)
        )
    md = "".join(md_parts)
    save("fig6_energy.md", md)
    print(md)

    ok = True

    # ru robustness: smaller energy spread than rc across the TOPS/W sweep
    # (paper: "MLA_ru is much more resistant to changes in the hardware
    # characteristics" — the comparison is against MLA_rc, whose recompute
    # FLOPs make it track e_op; MHA's robustness-to-TOPS/W is trivial, its
    # energy being DRAM-dominated, and its CACHE spread is the large one.)
    def spread(m, L):
        es = [energy(m, tw, L) for tw in TOPS_W]
        return max(es) / min(es)

    ok &= check(
        "MLA_ru more TOPS/W-robust than MLA_rc",
        spread("mla_ru", 262144) < spread("mla_rc", 262144),
        f"ru {spread('mla_ru', 262144):.2f} vs " f"rc {spread('mla_rc', 262144):.2f}",
    )
    # rc best-throughput does not imply best-energy at low efficiency
    ok &= check(
        "MLA_rc not universally best energy",
        any(
            energy("mla_rc", tw, 16384) > energy("mla_ru", tw, 16384) for tw in TOPS_W
        ),
    )
    # MHA_s can win at small cache for some design points...
    ok &= check(
        "MHA_s can win at small caches",
        any(
            min(METHODS, key=lambda m: energy(m, tw, 1024)) == "mha_s" for tw in TOPS_W
        ),
    )

    # ...but its spread across cache sizes is much larger than MLA's
    def cache_spread(m, tw=8):
        es = [energy(m, tw, L) for L in CACHES]
        return max(es) / min(es)

    ok &= check(
        "MHA cache-size energy spread >> MLA_rc's",
        cache_spread("mha_s") > 5 * cache_spread("mla_rc"),
        f"{cache_spread('mha_s'):.1f} vs {cache_spread('mla_rc'):.1f}",
    )
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
