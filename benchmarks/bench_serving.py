"""Serving benchmark: paged continuous batching (with and without the
radix prefix cache + chunked batched prefill) vs the contiguous
static-batch baseline, same request set.

Three runtimes over one shared-prefix request stream (every prompt opens
with the same system preamble, like production chat traffic):

  * contiguous  — what `launch/serve.py` did before PR 1: fixed batches,
    every slot gets the GLOBAL worst-case capacity, no request joins
    until the whole batch drains.
  * paged (PR-1) — continuous batching over the block pool, but every
    prompt is prefilled from scratch per-request (one jit retrace per
    prompt-length bucket) and no blocks are shared.
  * paged+prefix — this PR: radix prefix cache with copy-on-write block
    sharing (shared preamble blocks are ref-count-forked, not
    recomputed) and chunked batched prefill straight into the pool (one
    compiled prefill shape per chunk size, admitted requests prefill
    together).

Headline metrics: prefix hit rate, prefilled tokens (strictly fewer with
sharing), cumulative pool blocks allocated, prefill compiles (bounded by
chunk sizes, not prompt lengths), cache utilization; tokens/s on CPU is
directional only.  The modeled TTFT effect of the measured hit rate comes
from the closed-form prefix-hit term (hwmodel.attention_costs
.prefix_hit_savings / core.schemes.prefill_time).

The sharded row (PR 4) re-serves the prefix+chunked stream through a
(dp=2, model=2) mesh on a FORCED 8-device CPU backend (set below, before
jax initializes) and gates on token-identical outputs plus the modeled
per-device paged-byte shrink (hwmodel dp_shards) — the Stream-analysis
claim that DP scales the batch while per-device cache traffic stays flat.

The speculative rows (PR 5) re-serve the same stream with --spec-k
drafting: the identity-draft oracle (acceptance MUST be 100%, the
validity gate), a shallow:2 self-speculation draft (rejections + rewind
exercised), and the shallow draft on the 2x2 mesh.  All three must emit
token-identical outputs to the plain paged row; the modeled
mla_verify_cost break-even is printed next to the measured mean emitted
length and gated (accepted-length >= 1 amortization of cache-read bytes
per emitted token).

The telemetry rows (PR 7) re-serve the prefix+chunked stream (and the
identity-draft spec stream) with repro.obs armed and gate the subsystem
itself: outputs must be token-identical with tracing on, the emitted
Perfetto trace must validate (spans nest; every lifecycle + step phase
present), the roofline drift channel must cover every scheme the
dispatch used, and the disabled-mode instrumentation cost (measured by
microbenchmark) must stay under 2% of the mean step latency.  Artifacts:
trace_serving.json / metrics_serving.json / bench_drift.json (drift
ratios are gated against committed baselines in check_regression.py —
p50 ratio and p95/p50 spread are machine-speed-stable even though the
absolute CPU-vs-TPU-model ratio is huge).

The quantized row (PR 8) re-serves the prefix+chunked stream with the
latent pool stored int8 (per-token-row scales, in-kernel dequant,
exp-add AMLA rescaling) and gates greedy-token identity against the
wide-pool row, the modeled cache-byte shrink (<= 0.55x bf16), the attn
operational-intensity rise, and a kernel-vs-fp32-oracle max-logit-error
bound on a ragged random pool.

The load-harness rows (PR 9) run OPEN-LOOP: Poisson arrivals at a swept
rate (and a committed bursty trace schedule) that do not back off when
the engine saturates, all served by the async double-buffered engine.
TTFT/TPOT/queue-delay percentiles come from the PR-7 telemetry
histograms — no new timing code; achieved tokens/step and the
step-budget goodput are arithmetic over Request bookkeeping, so the
regression gate holds them exactly.  The sweep locates the saturation
knee and gates it against the decode roofline in step space (max_batch
tokens per fused step); the deepest-saturation run is traced and gated
on device_step spans (their own Perfetto track) wall-overlapping host
schedule spans — the overlap the sync engine cannot show.  The bursty
trace is served by BOTH engines and gated token-identical.  Artifact:
bench_load.json.

    PYTHONPATH=src python benchmarks/bench_serving.py --requests 12
    PYTHONPATH=src python benchmarks/bench_serving.py --shared-prefix-len 0
    PYTHONPATH=src python benchmarks/bench_serving.py --trace out.json
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# the sharded-vs-single-host row needs >= 4 devices; force 8 virtual CPU
# devices BEFORE jax initializes (a respected user/CI setting wins).
# Single-host rows stay token-identical (mesh=None work runs on device 0)
# but their WALL-CLOCK shifts: splitting the CPU into 8 one-thread
# devices slows every row ~15% vs the pre-PR-4 artifacts.  The forced
# count is recorded in the saved JSON so the perf trajectory reads as a
# topology change, not a code regression.
from repro.envflags import force_host_device_count

force_host_device_count(8)

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import common
import repro.configs as configs
import repro.models as models
from repro.core.schemes import prefill_time
from repro.hwmodel.attention_costs import mla_prefill_chunk_cost, prefix_hit_savings
from repro.hwmodel.platforms import PLATFORMS
from repro.launch.serve import _prepare_mla
from repro.nn import module as nnm
from repro.runtime import (
    PagedMLAEngine,
    Request,
    SamplingParams,
    blocks_for,
    make_prefill_step,
    make_serve_step,
)
from repro.runtime.steps import make_chunked_prefill_step


def make_requests(n, vocab, rng, shared_prefix_len=16):
    """Mixed prompt/gen lengths, Poisson arrivals; every prompt opens with
    the same ``shared_prefix_len``-token system preamble (0 disables)."""
    arrivals = np.floor(np.cumsum(rng.exponential(2.5, n))).astype(int)
    preamble = rng.integers(0, vocab, (shared_prefix_len,)).astype(np.int32)
    reqs = []
    for i in range(n):
        tlen = int(rng.choice([8, 16, 24, 32]))
        tail = rng.integers(0, vocab, (tlen,)).astype(np.int32)
        reqs.append(
            Request(
                rid=i,
                prompt=np.concatenate([preamble, tail]),
                max_new=int(rng.integers(4, 20)),
                arrival=int(arrivals[i]),
            )
        )
    return reqs


def run_contiguous(cfg, params, reqs, max_batch):
    """Static batching: fixed batches, global worst-case capacity, no
    admission until the running batch fully drains."""
    plen_max = max(r.plen for r in reqs)
    gen_max = max(r.max_new for r in reqs)
    capacity = plen_max + gen_max + 1
    params = _prepare_mla(params, cfg, "seq")
    prefill = make_prefill_step(
        cfg,
        None,
        batch=max_batch,
        capacity=capacity,
        compute_dtype=jnp.float32,
        scheme="seq",
    )
    step = make_serve_step(cfg, None, compute_dtype=jnp.float32, scheme="seq")
    util_sum, util_n, decode_tokens, steps = 0.0, 0, 0, 0
    prefill_tokens = 0
    outputs = {}
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), max_batch):
        batch = reqs[lo : lo + max_batch]
        B = len(batch)
        toks = np.zeros((max_batch, plen_max), np.int32)
        for b, r in enumerate(batch):  # right-align ragged prompts? no:
            toks[b, : r.plen] = r.prompt  # left-aligned, padded to plen_max
        logits, cache = prefill(params, jnp.asarray(toks))
        prefill_tokens += max_batch * plen_max  # padded slots pay too
        # NOTE: padded prompts make short requests see pad tokens — the
        # baseline's accuracy compromise; tokens are NOT compared against
        # the paged path here, only throughput/utilization are measured.
        pending = np.asarray(jnp.argmax(logits, -1))
        done_at = [r.max_new for r in batch]
        outs = [[int(pending[b])] for b in range(B)]
        n_steps = max(done_at)
        for i in range(n_steps - 1):
            logits, cache = step(params, jnp.asarray(pending), cache, plen_max + i)
            pending = np.asarray(jnp.argmax(logits, -1))
            live = 0
            for b in range(B):
                if len(outs[b]) < done_at[b]:
                    outs[b].append(int(pending[b]))
                    live += 1
            decode_tokens += live
            steps += 1
            # every slot reserves `capacity` tokens for the whole drain
            valid = sum(min(batch[b].plen + len(outs[b]), capacity) for b in range(B))
            util_sum += valid / (max_batch * capacity)
            util_n += 1
        for b, r in enumerate(batch):
            outputs[r.rid] = outs[b]
    wall = time.perf_counter() - t0
    return {
        "steps": steps,
        "decode_tokens": decode_tokens,
        "prefill_tokens": prefill_tokens,
        "tokens_per_s": decode_tokens / wall if wall else 0.0,
        "cache_utilization": util_sum / max(util_n, 1),
        "capacity_per_slot": capacity,
    }


def run_paged(
    cfg,
    params,
    reqs,
    args,
    *,
    prefix: bool,
    prefill_impl=None,
    mesh=None,
    spec_k=0,
    draft=None,
    telemetry=None,
    cache_dtype="bf16",
):
    """Paged runtime; ``prefix=False`` reproduces PR-1 (per-request
    prefill, no block sharing); ``prefill_impl='pallas'`` swaps the
    chunked prefill's gather view for the fused Pallas kernel; ``mesh``
    serves the same stream sharded (batch over 'data', heads over
    'model', pool replicated — runtime.steps); ``spec_k``/``draft`` turn
    on speculative decoding ('self' identity oracle or 'shallow:N'
    self-speculation — runtime.spec); ``telemetry`` (repro.obs.Telemetry)
    arms spans/metrics/drift and is finalized against the engine before
    returning."""
    bs = args.block_size
    # force block reuse
    num_blocks = 1 + sum(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs) // 2
    per_req = max(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs)
    draft_cfg = draft_params = None
    if spec_k:
        from repro.runtime.spec import parse_draft_spec

        draft_cfg, draft_params = parse_draft_spec(draft, cfg, params)
    eng = PagedMLAEngine(
        cfg,
        params,
        num_blocks=num_blocks,
        block_size=bs,
        max_batch=args.max_batch,
        max_blocks_per_req=per_req,
        compute_dtype=jnp.float32,
        scheme="auto",
        platform=PLATFORMS["tpu_v5e"],
        enable_prefix_cache=prefix,
        prefill_mode="chunked" if prefix else "per_request",
        prefill_impl=prefill_impl,
        prefill_chunk=args.prefill_chunk,
        mesh=mesh,
        spec_k=spec_k,
        draft_cfg=draft_cfg,
        draft_params=draft_params,
        telemetry=telemetry,
        cache_dtype=cache_dtype,
    )
    out = eng.run(
        [
            Request(
                rid=r.rid,
                prompt=r.prompt.copy(),
                max_new=r.max_new,
                arrival=r.arrival,
            )
            for r in reqs
        ],
        max_steps=args.steps,
    )
    out["num_blocks"] = num_blocks
    out["outputs"] = {r.rid: r.output for r in eng.sched.finished}
    if telemetry is not None:
        telemetry.finalize(eng)
    return out


def open_loop_requests(
    n, vocab, rate, *, body_seed, arrival_seed, shared_prefix_len=16
):
    """Open-loop request stream: Poisson arrivals at ``rate`` requests
    per engine step, INDEPENDENT of completions (the load does not back
    off when the engine saturates — that is what makes the knee visible).
    Request bodies come from ``body_seed`` so every rate in a sweep
    serves the identical work; only the arrival clock changes."""
    body = np.random.default_rng(body_seed)
    arr = np.random.default_rng(arrival_seed)
    arrivals = np.floor(np.cumsum(arr.exponential(1.0 / rate, n))).astype(int)
    preamble = body.integers(0, vocab, (shared_prefix_len,)).astype(np.int32)
    reqs = []
    for i in range(n):
        tlen = int(body.choice([8, 16, 24, 32]))
        tail = body.integers(0, vocab, (tlen,)).astype(np.int32)
        reqs.append(
            Request(
                rid=i,
                prompt=np.concatenate([preamble, tail]),
                max_new=int(body.integers(4, 20)),
                arrival=int(arrivals[i]),
            )
        )
    return reqs


def trace_requests(path, vocab, *, body_seed, shared_prefix_len=16):
    """Trace-driven arrivals: the committed schedule in ``path`` fixes
    (arrival step, prompt len, max_new) per request; token bodies are
    generated deterministically from ``body_seed``."""
    with open(path) as f:
        doc = json.load(f)
    body = np.random.default_rng(body_seed)
    preamble = body.integers(0, vocab, (shared_prefix_len,)).astype(np.int32)
    reqs = []
    for i, spec in enumerate(doc["requests"]):
        tail = body.integers(0, vocab, (spec["plen"],)).astype(np.int32)
        reqs.append(
            Request(
                rid=i,
                prompt=np.concatenate([preamble, tail]),
                max_new=int(spec["max_new"]),
                arrival=int(spec["arrival"]),
            )
        )
    return reqs


def run_load(
    cfg, params, reqs, args, *, engine_cls, trace=False, max_steps=6000, slo_steps=30
):
    """One open-loop load-harness run.  Latency percentiles come from the
    telemetry histograms (repro.obs — the spans/metrics PR 7 shipped),
    NOT from new timing code; the step-denominated metrics (achieved
    tokens/step, goodput against a step-budget SLO) are arithmetic over
    Request bookkeeping, so they are machine-speed-invariant and the
    regression gate can hold them exactly."""
    from repro.obs import Telemetry

    bs = args.block_size
    per_req = max(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs)
    # ample pool: the open-loop queue forms at the decode slots
    # (max_batch), not at block exhaustion
    num_blocks = 1 + (args.max_batch + 1) * per_req
    tel = Telemetry.on(trace=trace, metrics=True, drift=False)
    eng = engine_cls(
        cfg,
        params,
        num_blocks=num_blocks,
        block_size=bs,
        max_batch=args.max_batch,
        max_blocks_per_req=per_req,
        compute_dtype=jnp.float32,
        scheme="auto",
        platform=PLATFORMS["tpu_v5e"],
        enable_prefix_cache=True,
        prefill_mode="chunked",
        prefill_chunk=args.prefill_chunk,
        telemetry=tel,
    )
    out = eng.run(
        [
            Request(
                rid=r.rid,
                prompt=r.prompt.copy(),
                max_new=r.max_new,
                arrival=r.arrival,
            )
            for r in reqs
        ],
        max_steps=max_steps,
    )
    tel.finalize(eng)
    fin = eng.sched.finished
    lat = [r.finished_step - r.arrival for r in fin]
    row = {
        "steps": out["steps"],
        "decode_tokens": out["decode_tokens"],
        "finished": len(fin),
        "achieved_tok_per_step": out["decode_tokens"] / max(out["steps"], 1),
        "tokens_per_s": out["tokens_per_s"],
        "preemptions": out["preemptions"],
        "slo_steps": slo_steps,
        "goodput_slo": sum(1 for v in lat if v <= slo_steps) / max(len(reqs), 1),
        "latency_steps_p50": float(np.median(lat)) if lat else 0.0,
        "latency_steps_max": float(max(lat)) if lat else 0.0,
        "ttft_ms": tel.metrics.histogram("ttft_ms").summary(),
        "tpot_ms": tel.metrics.histogram("tpot_ms").summary(),
        "queue_delay_ms": tel.metrics.histogram("queue_delay_ms").summary(),
    }
    outputs = {r.rid: [int(t) for t in r.output] for r in fin}
    return row, outputs, tel


def bench_prefill_kernel(cfg, params, args):
    """Prefill-kernel row: ONE jitted chunked-prefill step over a paged
    pool with a resident prefix, gather path vs Pallas kernel —
    measured step latency (directional on CPU: the kernel runs in
    interpret mode there), logits parity, and the modeled off-chip bytes
    of each path at full scale (hwmodel.mla_prefill_chunk_cost)."""
    bs, B, C = args.block_size, args.max_batch, args.prefill_chunk
    rng = np.random.default_rng(args.seed + 2)
    nb = blocks_for(bs + C, bs) + 1  # resident block + chunk + slack
    num_blocks = 1 + B * nb
    pool0 = models.init_paged_cache(cfg, num_blocks, bs, jnp.float32)
    ids = list(range(1, num_blocks))
    bt = np.asarray([[ids.pop(0) for _ in range(nb)] for _ in range(B)], np.int32)
    lens = np.full((B,), bs, np.int32)  # one block already resident
    nv = np.full((B,), C, np.int32)
    tokens = rng.integers(0, cfg.vocab, (B, C)).astype(np.int32)
    out = {}
    for name, impl in (("gather", "ref"), ("pallas", "kernel")):
        step = make_chunked_prefill_step(
            cfg, None, compute_dtype=jnp.float32, impl=impl
        )
        logits, _ = step(
            params,
            jnp.asarray(tokens),
            jax.tree.map(jnp.copy, pool0),
            jnp.asarray(bt),
            jnp.asarray(lens),
            jnp.asarray(nv),
        )  # warmup
        jax.block_until_ready(logits)
        reps, t0 = 3, time.perf_counter()
        for _ in range(reps):
            lg, _ = step(
                params,
                jnp.asarray(tokens),
                jax.tree.map(jnp.copy, pool0),
                jnp.asarray(bt),
                jnp.asarray(lens),
                jnp.asarray(nv),
            )
            jax.block_until_ready(lg)
        out[name] = {
            "step_ms": (time.perf_counter() - t0) / reps * 1e3,
            "compiles": 1,
            "logits": np.asarray(logits),
        }
    # modeled full-scale cost of each path (one DeepSeek-V2 layer)
    mla = configs.full("deepseek-v2-236b").mla_config()
    kw = dict(seq_len=1024, chunk=128, paged_block=128, batch=B)
    for name in ("gather", "pallas"):
        c = mla_prefill_chunk_cost(mla, impl=name, **kw)
        attn_by = c.breakdown["B:cache_read"] + c.breakdown.get(
            "B:gather_materialize", c.breakdown.get("B:block_table", 0.0)
        )
        out[name].update(
            model_bytes=c.bytes,
            model_flops=c.flops,
            attn_oi=c.breakdown["attn_scores_pv"] / attn_by,
        )
    return out


def quant_oracle_err(cfg, args):
    """Kernel-vs-fp32-oracle accuracy probe for the quantized pool: one
    paged decode step over a random ragged int8 pool, Pallas kernel with
    in-register dequant + exp-add rescaling vs the dense fp32 reference
    on the SAME pre-quantization latents.  Returns the max |logit err|
    of the quantized kernel and, as a floor, of the unquantized kernel
    (so the gate measures quantization error, not kernel error)."""
    from repro.core import cache as cachelib
    from repro.kernels import ref
    from repro.kernels.ops import mla_decode_paged_attention

    mla = cfg.mla_config()
    Dl, Dr, H = mla.kv_lora_rank, mla.qk_rope_dim, mla.n_heads
    B, bs = args.max_batch, args.block_size
    nb, N = 6, 1 + args.max_batch * 6
    rng = np.random.default_rng(args.seed + 3)
    q = jnp.asarray(rng.normal(size=(B, H, Dl + Dr)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(N, bs, Dl)), jnp.float32)
    krope = jnp.asarray(rng.normal(size=(N, bs, Dr)), jnp.float32)
    bt = jnp.asarray(
        1 + np.arange(B * nb).reshape(B, nb) % (N - 1), jnp.int32
    )
    idx = jnp.asarray(rng.integers(bs, nb * bs, (B,)), jnp.int32)
    oracle = ref.mla_decode_paged_ref(q, ckv, krope, bt, idx)
    ckv_q, ckv_s = cachelib.quantize_latent(ckv, 127.0, jnp.int8)
    kr_q, kr_s = cachelib.quantize_latent(krope, 127.0, jnp.int8)
    got_q = mla_decode_paged_attention(
        q, ckv_q, kr_q, bt, idx, impl="pallas",
        ckv_scales=ckv_s, krope_scales=kr_s, rescale="exp_add",
    )
    got_f = mla_decode_paged_attention(
        q, ckv, krope, bt, idx, impl="pallas", rescale="exp_add"
    )
    return (
        float(jnp.max(jnp.abs(got_q - oracle))),
        float(jnp.max(jnp.abs(got_f - oracle))),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument(
        "--shared-prefix-len",
        type=int,
        default=16,
        help="tokens of common system preamble (0 disables)",
    )
    ap.add_argument("--steps", type=int, default=400, help="paged-engine step budget")
    ap.add_argument(
        "--spec-k",
        type=int,
        default=2,
        help="draft window of the speculative-decode rows",
    )
    ap.add_argument(
        "--trace",
        default="",
        help="also export the telemetry row's Perfetto trace "
        "to this path (the trace is always saved to "
        "benchmarks/artifacts/trace_serving.json)",
    )
    ap.add_argument(
        "--load-rates",
        default="0.05,0.125,0.25,0.5",
        help="open-loop sweep: Poisson arrival rates in requests per "
        "engine step (comma list, ascending)",
    )
    ap.add_argument(
        "--load-requests",
        type=int,
        default=10,
        help="requests per open-loop sweep point",
    )
    ap.add_argument(
        "--arrival-trace",
        default=os.path.join(os.path.dirname(__file__), "data", "arrival_trace.json"),
        help="trace-driven arrival schedule for the load harness "
        "(committed JSON: arrival step + plen + max_new per request)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(
        jax.random.PRNGKey(args.seed), models.model_defs(cfg), jnp.float32
    )
    rng = np.random.default_rng(args.seed + 1)
    reqs = make_requests(args.requests, cfg.vocab, rng, args.shared_prefix_len)

    print("== contiguous static batching (baseline) ==")
    base = run_contiguous(
        cfg,
        params,
        [Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new) for r in reqs],
        args.max_batch,
    )
    print(
        f"  {base['decode_tokens']} decode tokens, "
        f"{base['tokens_per_s']:.1f} tok/s, utilization "
        f"{base['cache_utilization']:.3f} "
        f"(every slot reserves {base['capacity_per_slot']} tokens)"
    )

    print("== paged, PR-1 (per-request prefill, no sharing) ==")
    pr1 = run_paged(cfg, params, reqs, args, prefix=False)
    print(
        f"  {pr1['decode_tokens']:.0f} decode tokens, "
        f"{pr1['prefill_tokens']:.0f} prefilled, "
        f"{pr1['total_blocks_allocated']:.0f} blocks allocated, "
        f"{pr1['prefill_compiles']:.0f} prefill compiles"
    )

    print("== paged + radix prefix cache + chunked prefill (this PR) ==")
    pp = run_paged(cfg, params, reqs, args, prefix=True)
    print(
        f"  {pp['decode_tokens']:.0f} decode tokens, "
        f"{pp['prefill_tokens']:.0f} prefilled "
        f"(hit rate {pp['prefix_hit_rate']:.2f}), "
        f"{pp['total_blocks_allocated']:.0f} blocks allocated, "
        f"{pp['prefill_compiles']:.0f} prefill compile "
        f"(chunk={args.prefill_chunk}), "
        f"{pp['prefix_evictions']:.0f} evictions"
    )

    print("== paged + prefix + Pallas prefill kernel (no gather) ==")
    pk = run_paged(cfg, params, reqs, args, prefix=True, prefill_impl="pallas")
    print(
        f"  {pk['decode_tokens']:.0f} decode tokens, "
        f"{pk['prefill_tokens']:.0f} prefilled, "
        f"{pk['prefill_compiles']:.0f} prefill compile"
    )

    print("== paged + prefix, SHARDED (dp=2, model=2; forced 8-dev CPU) ==")
    if jax.device_count() < 4:
        # only reachable when a user/CI XLA_FLAGS forces a smaller count
        # (the top-of-file default forces 8) — fail with the fix, not a
        # raw mesh-construction traceback mid-bench
        sys.exit(
            f"sharded row needs >= 4 devices, found "
            f"{jax.device_count()}: your XLA_FLAGS forces a smaller "
            f"host_platform_device_count — raise it to >= 4 or unset "
            f"it to accept the bench default of 8"
        )
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2), ("data", "model"))
    t0 = time.perf_counter()
    pm = run_paged(cfg, params, reqs, args, prefix=True, mesh=mesh)
    pm_wall = time.perf_counter() - t0
    print(
        f"  {pm['decode_tokens']:.0f} decode tokens on "
        f"{mesh.devices.size} devices in {pm_wall:.1f}s (CPU, "
        f"directional), {pm['prefill_tokens']:.0f} prefilled, "
        f"{pm['prefill_compiles']:.0f} prefill compile"
    )

    print("== paged + prefix + SPECULATIVE decode (PR 5) ==")
    sk = args.spec_k
    ss = run_paged(cfg, params, reqs, args, prefix=True, spec_k=sk, draft="self")
    print(
        f"  self-draft oracle : {ss['decode_tokens']:.0f} decode tokens "
        f"in {ss['spec_rounds']:.0f} rounds "
        f"({ss['spec_mean_emitted']:.2f} tok/round, accept rate "
        f"{ss['spec_accept_rate']:.2f})"
    )
    sh = run_paged(cfg, params, reqs, args, prefix=True, spec_k=sk, draft="shallow:2")
    print(
        f"  shallow:2 draft   : {sh['decode_tokens']:.0f} decode tokens "
        f"in {sh['spec_rounds']:.0f} rounds "
        f"({sh['spec_mean_emitted']:.2f} tok/round, accept rate "
        f"{sh['spec_accept_rate']:.2f})"
    )
    sm = run_paged(
        cfg,
        params,
        reqs,
        args,
        prefix=True,
        spec_k=sk,
        draft="shallow:2",
        mesh=make_mesh((2, 2), ("data", "model")),
    )
    print(
        f"  shallow:2 (2x2)   : {sm['decode_tokens']:.0f} decode tokens "
        f"in {sm['spec_rounds']:.0f} rounds "
        f"({sm['spec_mean_emitted']:.2f} tok/round)"
    )
    # modeled amortization at the measured accepted length (full scale).
    # The draft is NOT modeled as free: a shallow:2 self-speculation draft
    # runs k sequential 2-layer decode steps per round, so each drafted
    # token costs ~(draft layers / target layers) of a full decode step —
    # the break-even E* the gate compares against includes that.
    from repro.hwmodel.attention_costs import mla_verify_cost, spec_break_even

    full_cfg = configs.full("deepseek-v2-236b")
    mla_full = full_cfg.mla_config()
    draft_frac = 2 / full_cfg.n_layers
    be = spec_break_even(
        mla_full,
        scheme="seq",
        cache_len=4096,
        k=sk,
        batch=args.max_batch,
        paged_block=128,
        draft_bytes_frac=draft_frac,
    )
    e_meas = sh["spec_mean_emitted"]
    vc = mla_verify_cost(
        mla_full,
        scheme="seq",
        cache_len=4096,
        k=sk,
        batch=args.max_batch,
        paged_block=128,
    )
    rd_per_tok = vc.breakdown["B:cache_read"] / max(e_meas, 1e-9)
    from repro.hwmodel.attention_costs import mla_decode_cost as _mdc

    dc = _mdc(
        mla_full,
        scheme="seq",
        cache_len=4096 + sk + 1,
        batch=args.max_batch,
        paged_block=128,
    )
    print(
        f"  modeled (1 layer, L=4096, k={sk}): verify round = "
        f"{vc.bytes / 1e6:.1f} MB vs decode step "
        f"{dc.bytes / 1e6:.1f} MB -> break-even E* = "
        f"{be['break_even_emitted']:.2f} tokens/round (incl. draft at "
        f"{draft_frac:.3f} of a decode step per drafted token); "
        f"measured E = {e_meas:.2f} -> cache-read "
        f"{rd_per_tok / 1e6:.1f} MB/token vs "
        f"{dc.breakdown['B:cache_read'] / 1e6:.1f} plain"
    )

    print("== paged + prefix, telemetry armed (PR 7) ==")
    from repro.obs import (
        OFF_TELEMETRY,
        PID_ENGINE,
        PID_REQUESTS,
        Telemetry,
        validate_trace,
    )

    tel = Telemetry.on(trace=True, metrics=True, drift=True)
    pt = run_paged(cfg, params, reqs, args, prefix=True, telemetry=tel)
    # a second armed run over the spec stream so the draft/verify phases
    # and the drift channel's "verify" kind are exercised too.
    tel_s = Telemetry.on(trace=True, metrics=False, drift=True)
    st = run_paged(
        cfg, params, reqs, args, prefix=True, spec_k=sk, draft="self", telemetry=tel_s
    )
    trace = tel.tracer.to_dict()
    trace_spec = tel_s.tracer.to_dict()
    trace_problems = validate_trace(trace) + validate_trace(trace_spec)

    def span_names(tr, pid):
        return {
            e["name"]
            for e in tr["traceEvents"]
            if e.get("pid") == pid and e["ph"] in ("X", "i")
        }

    phase_names = span_names(trace, PID_ENGINE)
    spec_phase_names = span_names(trace_spec, PID_ENGINE)
    life_names = span_names(trace, PID_REQUESTS)
    cov = tel.drift.check_coverage(pt["schemes_used"], kinds=("decode",))
    cov += tel_s.drift.check_coverage(st["schemes_used"], kinds=("verify",))
    # one combined drift report (decode/prefill rows from the plain run,
    # verify/draft-era rows from the spec run) — this is the artifact the
    # regression gate holds against committed baselines.
    tel.drift.rows.extend(tel_s.drift.rows)
    drift_report = tel.drift.report()
    ttft = tel.metrics.histogram("ttft_ms").summary()
    # disabled-mode cost: per-hook price of the null tracer times a
    # generous hooks-per-step count, against the UNTRACED row's mean
    # step latency (ISSUE 7 acceptance: < 2%).
    n_null = 200_000
    null_span = OFF_TELEMETRY.tracer.span
    t0 = time.perf_counter()
    for _ in range(n_null):
        with null_span("step"):
            pass
    null_per_hook = (time.perf_counter() - t0) / n_null
    hooks_per_step = 16
    pp_wall = pp["decode_tokens"] / max(pp["tokens_per_s"], 1e-9)
    step_mean_s = pp_wall / max(pp["steps"], 1)
    overhead_frac = null_per_hook * hooks_per_step / max(step_mean_s, 1e-9)
    print(
        f"  trace: {len(trace['traceEvents'])} events "
        f"(+{len(trace_spec['traceEvents'])} spec run), "
        f"{len(trace_problems)} validation problems"
    )
    print(f"  step phases seen: {sorted(phase_names | spec_phase_names)}")
    print(
        f"  drift: {drift_report['rows']} rows over "
        f"{sorted(drift_report['kinds'])} -> time ratio p50 "
        f"{drift_report['summary']['time_ratio_p50']:.3g}, spread "
        f"{drift_report['summary']['spread']:.2f} "
        f"(CPU wall vs TPU-v5e model; gate watches p50 + spread only)"
    )
    print(
        f"  TTFT p50 {ttft['p50']:.1f} / p95 {ttft['p95']:.1f} ms; "
        f"null-telemetry cost {overhead_frac:.3%} of a mean step "
        f"({null_per_hook * 1e9:.0f} ns/hook x {hooks_per_step} hooks)"
    )
    if args.trace:
        print(f"  trace exported to {tel.tracer.export(args.trace)}")

    print("== paged + prefix, QUANTIZED int8 latent pool (PR 8) ==")
    qp = run_paged(cfg, params, reqs, args, prefix=True, cache_dtype="int8")
    q_err, f_err = quant_oracle_err(cfg, args)
    from repro.core.cache import cache_element_bytes
    from repro.hwmodel.attention_costs import mla_decode_cost, rescale_multiplies

    mla_full = configs.full("deepseek-v2-236b").mla_config()
    qdkw = dict(scheme="seq", cache_len=4096, batch=args.max_batch, paged_block=128)
    cw8 = cache_element_bytes(mla_full.kv_lora_rank, mla_full.qk_rope_dim, 2, "int8")
    cb16 = mla_decode_cost(mla_full, **qdkw)
    cq8 = mla_decode_cost(mla_full, cache_dtype_bytes=cw8, **qdkw)

    def attn_oi(c):
        return (c.breakdown["attn_scores"] + c.breakdown["attn_out"]) / (
            c.breakdown["B:cache_read"] + c.breakdown["B:block_table"]
        )

    rd_ratio = cq8.breakdown["B:cache_read"] / cb16.breakdown["B:cache_read"]
    tok_ratio = qp["cache_token_bytes"] / pp["cache_token_bytes"]
    mul_classic = rescale_multiplies(
        mla_full, cache_len=4096, batch=args.max_batch, paged_block=128,
        rescale="mul",
    )
    mul_amla = rescale_multiplies(
        mla_full, cache_len=4096, batch=args.max_batch, paged_block=128,
        rescale="exp_add",
    )
    print(
        f"  {qp['decode_tokens']:.0f} decode tokens at "
        f"{qp['tokens_per_s']:.1f} tok/s (bf16 pool: "
        f"{pp['tokens_per_s']:.1f}); pool "
        f"{qp['cache_token_bytes']:.0f} B/token/stack vs "
        f"{pp['cache_token_bytes']:.0f} ({tok_ratio:.2f}x)"
    )
    print(
        f"  modeled (1 layer, L=4096): cache read "
        f"{cb16.breakdown['B:cache_read'] / 1e6:.1f} -> "
        f"{cq8.breakdown['B:cache_read'] / 1e6:.1f} MB/step "
        f"({rd_ratio:.2f}x), attn OI {attn_oi(cb16):.0f} -> "
        f"{attn_oi(cq8):.0f} FLOP/B; exp-add rescale multiplies "
        f"{mul_classic:.3g} -> {mul_amla:.0f}"
    )
    print(
        f"  fp32-oracle max |err|: int8 kernel {q_err:.3e} "
        f"(unquantized kernel floor {f_err:.3e})"
    )

    print("== prefill-kernel step: gather view vs in-place Pallas ==")
    kb = bench_prefill_kernel(cfg, params, args)
    for name in ("gather", "pallas"):
        r = kb[name]
        print(
            f"  {name:7s}: {r['step_ms']:8.2f} ms/step (CPU, "
            f"directional), modeled {r['model_bytes'] / 1e6:.0f} MB/layer "
            f"at L=1024 C=128 bs=128, attn OI {r['attn_oi']:.0f} FLOP/B, "
            f"{r['compiles']} compile"
        )

    # modeled TTFT effect of the measured hit rate (full-scale config)
    mla = configs.full("deepseek-v2-236b").mla_config()
    plat = PLATFORMS["tpu_v5e"]
    L = 1024
    P = int(round(L * pp["prefix_hit_rate"]))
    if 0 < P < L:
        t0 = prefill_time(mla, plat, L)
        t1 = prefill_time(mla, plat, L, cached_prefix=P)
        sav = prefix_hit_savings(mla, seq_len=L, cached_prefix=P)
        print(
            f"  modeled TTFT (1 layer, L={L}, hit {P} tokens): "
            f"{t0 * 1e6:.0f} -> {t1 * 1e6:.0f} us "
            f"({t0 / t1:.2f}x; {sav['flops_frac']:.0%} FLOPs, "
            f"{sav['bytes_frac']:.0%} bytes saved)"
        )

    gain = pp["cache_utilization"] / max(base["cache_utilization"], 1e-9)

    print("== open-loop SLO load harness, async engine (PR 9) ==")
    from repro.core.schemes import step_time
    from repro.runtime import AsyncPagedMLAEngine
    from repro.runtime.engine import TID_DEVICE

    rates = [float(r) for r in args.load_rates.split(",")]
    sweep = {}
    trace_load = None
    for ri, rate in enumerate(rates):
        reqs_r = open_loop_requests(
            args.load_requests,
            cfg.vocab,
            rate,
            body_seed=args.seed + 101,
            arrival_seed=args.seed + 201 + ri,
            shared_prefix_len=args.shared_prefix_len,
        )
        # the deepest-saturation point doubles as the overlap probe: arm
        # the tracer so the device-stream track is recorded
        row, _, tel_r = run_load(
            cfg,
            params,
            reqs_r,
            args,
            engine_cls=AsyncPagedMLAEngine,
            trace=(ri == len(rates) - 1),
        )
        if ri == len(rates) - 1:
            trace_load = tel_r.tracer.to_dict()
        mean_new = sum(r.max_new for r in reqs_r) / len(reqs_r)
        row["rate"] = rate
        row["offered_tok_per_step"] = rate * mean_new
        sweep[f"r{ri}"] = row
        print(
            f"  rate {rate:.3f} req/step (offered "
            f"{row['offered_tok_per_step']:.2f} tok/step): achieved "
            f"{row['achieved_tok_per_step']:.2f} tok/step, goodput "
            f"{row['goodput_slo']:.2f} @ {row['slo_steps']}-step SLO, "
            f"TTFT p99 {row['ttft_ms'].get('p99', 0):.0f} ms, "
            f"latency p50 {row['latency_steps_p50']:.0f} steps"
        )
    # saturation knee: the decode roofline in step space is max_batch
    # tokens/step (one fused decode+sample step serves <= max_batch
    # rows) — locate the sweep point that gets closest to it
    achieved = [sweep[f"r{i}"]["achieved_tok_per_step"] for i in range(len(rates))]
    knee_i = int(np.argmax(achieved))
    ceiling = float(args.max_batch)
    mla = cfg.mla_config()
    plen_typ = 16 + args.shared_prefix_len
    t_model = step_time(
        "seq",
        mla,
        PLATFORMS["tpu_v5e"],
        cache_len=plen_typ + 16,
        batch=args.max_batch,
        paged_block=args.block_size,
    )
    knee = {
        "rate": rates[knee_i],
        "achieved_tok_per_step": achieved[knee_i],
        "decode_tokens": sweep[f"r{knee_i}"]["decode_tokens"],
        "tokens_per_s": sweep[f"r{knee_i}"]["tokens_per_s"],
        "ceiling_tok_per_step": ceiling,
        "knee_frac": achieved[knee_i] / ceiling,
        "model": {
            "platform": "tpu_v5e",
            "step_time_us": t_model * 1e6,
            "predicted_tok_per_s": args.max_batch / t_model,
        },
    }
    print(
        f"  knee @ rate {knee['rate']:.3f}: "
        f"{knee['achieved_tok_per_step']:.2f} of {ceiling:.0f} tok/step "
        f"roofline ceiling ({knee['knee_frac']:.2f}); modeled tpu_v5e "
        f"step {t_model * 1e9:.0f} ns -> "
        f"{knee['model']['predicted_tok_per_s'] / 1e3:.1f}k tok/s"
    )
    # trace-driven arrivals: committed burst schedule, sync-vs-async
    # token parity is the double-buffer acceptance gate
    reqs_t = trace_requests(
        args.arrival_trace,
        cfg.vocab,
        body_seed=args.seed + 101,
        shared_prefix_len=args.shared_prefix_len,
    )
    ld_sync, out_sync, _ = run_load(
        cfg, params, reqs_t, args, engine_cls=PagedMLAEngine
    )
    ld_async, out_async, _ = run_load(
        cfg, params, reqs_t, args, engine_cls=AsyncPagedMLAEngine
    )
    ld_async["parity"] = out_sync == out_async
    print(
        f"  trace-driven ({len(reqs_t)} reqs, bursty): async "
        f"{ld_async['achieved_tok_per_step']:.2f} tok/step over "
        f"{ld_async['steps']:.0f} steps, TTFT p99 "
        f"{ld_async['ttft_ms'].get('p99', 0):.0f} ms, sync parity "
        f"{ld_async['parity']}"
    )
    # host/device overlap: the async tick's device_step spans live on
    # their own track and must wall-overlap a host schedule span — with
    # the sync engine those phases are strictly serialized
    load_trace_problems = validate_trace(trace_load)
    xs = [
        e
        for e in trace_load["traceEvents"]
        if e.get("ph") == "X" and e["pid"] == PID_ENGINE
    ]
    dev_spans = [e for e in xs if e["tid"] == TID_DEVICE and e["name"] == "device_step"]
    sch_spans = [e for e in xs if e["tid"] == 0 and e["name"] == "schedule"]
    load_overlap = any(
        d["ts"] < s["ts"] + s["dur"] and s["ts"] < d["ts"] + d["dur"]
        for d in dev_spans
        for s in sch_spans
    )
    print(
        f"  overlap probe: {len(dev_spans)} device-stream spans, "
        f"{len(load_trace_problems)} trace problems, device_step "
        f"overlaps host schedule: {load_overlap}"
    )

    def paged_row(label, row):
        return [
            label,
            int(row["decode_tokens"]),
            int(row["prefill_tokens"]),
            int(row["total_blocks_allocated"]),
            int(row["prefill_compiles"]),
            f"{row['cache_utilization']:.3f}",
            f"{row['prefix_hit_rate']:.2f}",
        ]

    def spec_table_row(label, row):
        return [
            label,
            int(row["spec_rounds"]),
            f"{row['spec_mean_emitted']:.2f}",
            f"{row['spec_accept_rate']:.2f}",
            int(row["spec_drafted"]),
            int(row["spec_compiles"]),
        ]

    rows = [
        [
            "contiguous",
            base["decode_tokens"],
            base["prefill_tokens"],
            "-",
            "-",
            f"{base['cache_utilization']:.3f}",
            "-",
        ],
        paged_row("paged (PR-1)", pr1),
        paged_row("paged+prefix", pp),
        paged_row("paged+prefix+pallas", pk),
        paged_row("paged+prefix (2x2 mesh)", pm),
        paged_row("paged+prefix, int8 pool", qp),
        paged_row(f"paged+prefix+spec k={sk} (self)", ss),
        paged_row(f"paged+prefix+spec k={sk} (shallow:2)", sh),
    ]
    md_s = common.table(
        ["spec row", "rounds", "tok/round", "accept rate", "drafted", "spec compiles"],
        [
            spec_table_row("self oracle", ss),
            spec_table_row("shallow:2", sh),
            spec_table_row("shallow:2 (2x2 mesh)", sm),
        ],
    )
    md = common.table(
        [
            "runtime",
            "decode tok",
            "prefill tok",
            "blocks alloc",
            "prefill compiles",
            "cache util",
            "hit rate",
        ],
        rows,
    )
    print("\n" + md)
    print(md_s)
    md_k = common.table(
        [
            "prefill path",
            "step ms (CPU)",
            "modeled MB/layer",
            "attn OI (FLOP/B)",
            "compiles",
        ],
        [
            [
                n,
                f"{kb[n]['step_ms']:.2f}",
                f"{kb[n]['model_bytes'] / 1e6:.0f}",
                f"{kb[n]['attn_oi']:.0f}",
                kb[n]["compiles"],
            ]
            for n in ("gather", "pallas")
        ],
    )
    print(md_k)

    ok = True
    ok &= common.check(
        "paged utilization beats contiguous",
        pp["cache_utilization"] > base["cache_utilization"],
        f"{pp['cache_utilization']:.3f} vs {base['cache_utilization']:.3f}",
    )
    ok &= common.check(
        "mid-generation admission happened", pp["mid_gen_admissions"] > 0
    )
    ok &= common.check(
        "identical outputs with and without prefix sharing",
        pr1["outputs"] == pp["outputs"],
    )
    if args.shared_prefix_len:
        ok &= common.check(
            "prefix hit rate > 0",
            pp["prefix_hit_rate"] > 0,
            f"{pp['prefix_hit_rate']:.2f}",
        )
        ok &= common.check(
            "prefix sharing prefills strictly fewer tokens",
            pp["prefill_tokens"] < pr1["prefill_tokens"],
            f"{pp['prefill_tokens']:.0f} vs {pr1['prefill_tokens']:.0f}",
        )
        ok &= common.check(
            "prefix sharing allocates fewer pool blocks",
            pp["total_blocks_allocated"] < pr1["total_blocks_allocated"],
            f"{pp['total_blocks_allocated']:.0f} vs "
            f"{pr1['total_blocks_allocated']:.0f}",
        )
    ok &= common.check(
        "chunked prefill compiles are bounded (1 chunk size)",
        pp["prefill_compiles"] == 1,
        f"{pp['prefill_compiles']:.0f} vs {pr1['prefill_compiles']:.0f} "
        f"per-plen buckets",
    )
    ok &= common.check(
        "Pallas prefill outputs token-identical to the gather path",
        pk["outputs"] == pp["outputs"],
    )
    ok &= common.check(
        "Pallas prefill compiles stay bounded (1 chunk size)",
        pk["prefill_compiles"] == 1,
        f"{pk['prefill_compiles']:.0f}",
    )
    ok &= common.check(
        "prefill-step logits parity (gather vs Pallas)",
        np.allclose(
            kb["gather"]["logits"], kb["pallas"]["logits"], atol=1e-4, rtol=1e-4
        ),
    )
    ok &= common.check(
        "modeled prefill bytes: in-place paged reads < materialized gather",
        kb["pallas"]["model_bytes"] < kb["gather"]["model_bytes"],
        f"{kb['pallas']['model_bytes'] / 1e6:.0f} vs "
        f"{kb['gather']['model_bytes'] / 1e6:.0f} MB/layer",
    )
    ok &= common.check(
        "modeled attention intensity rises with the kernel",
        kb["pallas"]["attn_oi"] > kb["gather"]["attn_oi"],
        f"{kb['pallas']['attn_oi']:.0f} vs {kb['gather']['attn_oi']:.0f} FLOP/B",
    )
    # ---- sharded row gates: same tokens, DP-scaled per-device bytes ----
    ok &= common.check(
        "sharded (2x2 mesh) outputs token-identical to single host",
        pm["outputs"] == pp["outputs"],
    )
    ok &= common.check(
        "sharded prefill compiles stay bounded (1 chunk size)",
        pm["prefill_compiles"] == 1,
        f"{pm['prefill_compiles']:.0f}",
    )
    from repro.hwmodel.attention_costs import DSV3_MLA, mla_decode_cost

    dkw = dict(scheme="seq", cache_len=4096, batch=8, paged_block=128)
    c1 = mla_decode_cost(DSV3_MLA, **dkw)
    c2 = mla_decode_cost(DSV3_MLA, dp_shards=2, **dkw)
    dp_ok = all(
        abs(c2.breakdown[t] - c1.breakdown[t] / 2) < 1e-6
        for t in ("B:cache_read", "B:cache_write", "B:block_table")
    )
    ok &= common.check(
        "modeled per-device paged bytes shrink by the DP factor (weights stay whole)",
        dp_ok and c2.breakdown["B:w_common"] == c1.breakdown["B:w_common"],
        f"cache_read {c1.breakdown['B:cache_read'] / 1e6:.1f} -> "
        f"{c2.breakdown['B:cache_read'] / 1e6:.1f} MB/step/device at dp=2",
    )
    # ---- speculative-decode gates (ISSUE 5 acceptance) -----------------
    ok &= common.check(
        "spec decode (self oracle) outputs token-identical to plain paged",
        ss["outputs"] == pp["outputs"],
    )
    ok &= common.check(
        "spec decode (shallow draft) outputs token-identical to plain",
        sh["outputs"] == pp["outputs"],
    )
    ok &= common.check(
        "spec decode (shallow, 2x2 mesh) outputs token-identical to plain",
        sm["outputs"] == pp["outputs"],
    )
    ok &= common.check(
        "identity draft is fully accepted (the machinery oracle)",
        ss["spec_accept_rate"] == 1.0 and ss["spec_mean_emitted"] > 2.0,
        f"accept {ss['spec_accept_rate']:.2f}, "
        f"{ss['spec_mean_emitted']:.2f} tok/round",
    )
    ok &= common.check(
        "accepted length clears the modeled break-even (amortization)",
        sh["spec_mean_emitted"] >= 1.0
        and sh["spec_mean_emitted"] >= be["break_even_emitted"],
        f"measured E {sh['spec_mean_emitted']:.2f} vs modeled E* "
        f"{be['break_even_emitted']:.2f}",
    )
    ok &= common.check(
        "verify round amortizes cache-read bytes per emitted token",
        rd_per_tok <= dc.breakdown["B:cache_read"] + 1e-6,
        f"{rd_per_tok / 1e6:.1f} vs {dc.breakdown['B:cache_read'] / 1e6:.1f} MB/token",
    )
    ok &= common.check(
        "spec rounds emit more tokens per engine step than plain decode",
        ss["spec_mean_emitted"] > 1.0 and ss["steps"] < pp["steps"],
        f"{ss['steps']:.0f} vs {pp['steps']:.0f} steps",
    )
    ok &= common.check(
        "spec compiles stay bounded (1 verify + 1 draft step; "
        "2 prefill chunk shapes: target + draft)",
        ss["spec_compiles"] <= 2
        and sh["spec_compiles"] <= 2
        and sh["prefill_compiles"] == 2,
        f"{sh['spec_compiles']:.0f} spec / {sh['prefill_compiles']:.0f} prefill",
    )
    # ---- telemetry gates (ISSUE 7 acceptance) --------------------------
    ok &= common.check(
        "outputs token-identical with telemetry armed (plain + spec)",
        pt["outputs"] == pp["outputs"] and st["outputs"] == ss["outputs"],
    )
    ok &= common.check(
        "Perfetto trace validates (nesting, required keys)",
        not trace_problems,
        "; ".join(trace_problems[:3]),
    )
    ok &= common.check(
        "every request-lifecycle phase has a span",
        {"arrival", "queued", "prefill", "decode", "finish"} <= life_names,
        f"saw {sorted(life_names)}",
    )
    ok &= common.check(
        "every step phase has a span (draft/verify from the spec run)",
        {"step", "schedule", "prefill", "prefill_chunk", "device_step", "host_sample"}
        <= phase_names
        and {"draft", "verify"} <= spec_phase_names,
        f"plain {sorted(phase_names)} spec {sorted(spec_phase_names)}",
    )
    ok &= common.check(
        "drift report covers every dispatched scheme", not cov, "; ".join(cov)
    )
    ok &= common.check(
        "drift records decode, prefill and verify kinds",
        {"decode", "prefill", "verify"} <= set(drift_report["kinds"]),
        f"{sorted(drift_report['kinds'])}",
    )
    ok &= common.check(
        "TTFT/TPOT histograms cover the finished requests",
        ttft["count"] == len(pt["outputs"])
        and tel.metrics.histogram("queue_delay_ms").count == len(pt["outputs"]),
        f"{ttft['count']} vs {len(pt['outputs'])}",
    )
    ok &= common.check(
        "EngineStats parity: metrics mirror engine.summary() exactly",
        tel.metrics.engine_summary
        == {k: v for k, v in pt.items() if k not in ("num_blocks", "outputs")}
        and tel.metrics.counter("engine.steps").value == pt["steps"],
    )
    ok &= common.check(
        "disabled-mode telemetry cost < 2% of a mean step",
        overhead_frac < 0.02,
        f"{overhead_frac:.3%} ({null_per_hook * 1e9:.0f} ns/hook)",
    )
    # ---- quantized-pool gates (ISSUE 8 acceptance) ----------------------
    ok &= common.check(
        "int8 pool outputs greedy-token-identical to the bf16 pool",
        qp["outputs"] == pp["outputs"],
    )
    ok &= common.check(
        "int8 pool stores <= 0.55x the bytes/token of the wide pool",
        tok_ratio <= 0.55,
        f"{qp['cache_token_bytes']:.0f} vs {pp['cache_token_bytes']:.0f} "
        f"B/token ({tok_ratio:.2f}x)",
    )
    ok &= common.check(
        "modeled decode cache-read bytes shrink <= 0.55x at int8",
        rd_ratio <= 0.55,
        f"{rd_ratio:.4f}",
    )
    ok &= common.check(
        "modeled attention intensity rises with the quantized pool",
        attn_oi(cq8) > attn_oi(cb16),
        f"{attn_oi(cb16):.0f} -> {attn_oi(cq8):.0f} FLOP/B",
    )
    ok &= common.check(
        "exp-add rescaling removes the online-softmax multiply term",
        mul_amla == 0.0 and mul_classic > 0,
        f"{mul_classic:.3g} -> {mul_amla:.0f} multiplies/step",
    )
    ok &= common.check(
        "int8 kernel tracks the fp32 oracle within the committed bound",
        q_err <= 0.05 and f_err <= 1e-4,
        f"int8 {q_err:.3e} (floor {f_err:.3e}) vs bound 5e-2",
    )
    ok &= common.check(
        "int8 serving throughput holds up (CPU, directional)",
        qp["tokens_per_s"] >= 0.4 * pp["tokens_per_s"],
        f"{qp['tokens_per_s']:.1f} vs {pp['tokens_per_s']:.1f} tok/s",
    )

    # ---- load-harness gates (ISSUE 9 acceptance) ------------------------
    ok &= common.check(
        "async engine token-identical to sync on the bursty trace",
        ld_async["parity"],
    )
    ok &= common.check(
        "open-loop sweep drains every request at every rate",
        all(sweep[f"r{i}"]["finished"] == args.load_requests for i in range(len(rates)))
        and ld_async["finished"] == len(reqs_t),
    )
    ok &= common.check(
        "saturation knee sits inside the roofline band",
        0.5 <= knee["knee_frac"] <= 1.0 + 1e-9,
        f"{knee['achieved_tok_per_step']:.2f} of {ceiling:.0f} tok/step "
        f"({knee['knee_frac']:.2f}; decode roofline = max_batch "
        f"tokens per fused step)",
    )
    ok &= common.check(
        "offered load crosses the knee (the sweep actually saturates)",
        sweep[f"r{len(rates) - 1}"]["offered_tok_per_step"] > ceiling
        and achieved[-1] >= 0.8 * max(achieved),
        f"offered {sweep[f'r{len(rates) - 1}']['offered_tok_per_step']:.2f} "
        f"vs ceiling {ceiling:.0f} tok/step",
    )
    ok &= common.check(
        "goodput degrades monotonically-ish past the knee",
        sweep[f"r{len(rates) - 1}"]["goodput_slo"] <= sweep["r0"]["goodput_slo"] + 1e-9,
        f"{sweep['r0']['goodput_slo']:.2f} -> "
        f"{sweep[f'r{len(rates) - 1}']['goodput_slo']:.2f}",
    )
    ok &= common.check(
        "load-harness TTFT/TPOT come from the telemetry histograms",
        ld_async["ttft_ms"]["count"] == len(reqs_t)
        and ld_async["tpot_ms"]["count"] == len(reqs_t),
        f"{ld_async['ttft_ms']['count']} / {ld_async['tpot_ms']['count']} "
        f"of {len(reqs_t)}",
    )
    ok &= common.check(
        "async load trace validates (device-stream track nests)",
        not load_trace_problems,
        "; ".join(load_trace_problems[:3]),
    )
    ok &= common.check(
        "device_step spans overlap host schedule spans (double-buffering "
        "visible in the trace)",
        load_overlap,
        f"{len(dev_spans)} device spans x {len(sch_spans)} schedule spans",
    )

    pp_save = {k: v for k, v in pp.items() if k != "outputs"}
    pr1_save = {k: v for k, v in pr1.items() if k != "outputs"}
    pk_save = {k: v for k, v in pk.items() if k != "outputs"}
    pm_save = {k: v for k, v in pm.items() if k != "outputs"}
    pm_save["devices"] = int(mesh.devices.size)
    pm_save["wall_s"] = pm_wall
    pm_save["model_dp_bytes"] = {
        "dp1_cache_read": c1.breakdown["B:cache_read"],
        "dp2_cache_read": c2.breakdown["B:cache_read"],
        "weights": c1.breakdown["B:w_common"] + c1.breakdown["B:w_scheme"],
    }
    qp_save = {k: v for k, v in qp.items() if k != "outputs"}
    qp_save["oracle_max_err"] = q_err
    qp_save["oracle_max_err_unquantized"] = f_err
    qp_save["model"] = {
        "cache_read_bf16": cb16.breakdown["B:cache_read"],
        "cache_read_int8": cq8.breakdown["B:cache_read"],
        "cache_read_ratio": rd_ratio,
        "attn_oi_bf16": attn_oi(cb16),
        "attn_oi_int8": attn_oi(cq8),
        "token_bytes_ratio": tok_ratio,
        "rescale_multiplies_mul": mul_classic,
        "rescale_multiplies_exp_add": mul_amla,
    }
    kb_save = {n: {k: v for k, v in kb[n].items() if k != "logits"} for n in kb}
    spec_keys = (
        "spec_rounds",
        "spec_drafted",
        "spec_accepted",
        "spec_accept_rate",
        "spec_mean_emitted",
        "spec_compiles",
        "decode_tokens",
        "steps",
        "prefill_compiles",
    )
    spec_save = {}
    for name, row in (("self", ss), ("shallow", sh), ("shallow_mesh", sm)):
        spec_save[name] = {k: row[k] for k in spec_keys}
    spec_save["model"] = {
        "k": sk,
        "verify_bytes": vc.bytes,
        "decode_bytes": dc.bytes,
        "draft_bytes_frac": draft_frac,
        "break_even_emitted": be["break_even_emitted"],
        "amortization_at_full_accept": be["amortization_at_full_accept"],
        "cache_read_per_token_at_measured_E": rd_per_tok,
        "cache_read_per_token_plain": dc.breakdown["B:cache_read"],
    }
    # ---- PR 10: multi-turn conversation tree + n-way parallel sampling --
    print("== multi-turn conversation tree: decode-block reuse (PR 10) ==")

    # Both PR-10 sections compare runs whose PREFILL batches differ by
    # construction (one forked prefill vs four independent ones; warm
    # cache-hit suffixes vs cold full prompts).  MoE capacity overflow is
    # the one op in the stack whose per-token result depends on the REST
    # of the batch (which tokens drop is a function of every co-batched
    # token's routing), so token-identity gates across batch shapes need
    # drop-free capacity: C >= T at capacity_factor = E / top_k.  Every
    # other op — attention, dense FFN, the expert einsums themselves, the
    # expert-major combine — is bitwise row-independent.
    cfg_nodrop = dataclasses.replace(
        cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)

    def run_conversations(warm: bool):
        """Serve the same 3-turn x 4-conversation tree on one engine.
        ``warm=False`` pins the PR-9 serving behaviour — block-granular
        PROMPT matching only (no decode-block registration, no partial
        tails, FCFS admission) — so the lift is attributable to PR 10."""
        kw = {} if warm else dict(
            decode_block_reuse=False, partial_match=False, admission="fcfs"
        )
        eng = PagedMLAEngine(
            cfg_nodrop,
            params,
            num_blocks=96,
            block_size=args.block_size,
            max_batch=args.max_batch,
            max_blocks_per_req=16,
            compute_dtype=jnp.float32,
            scheme="seq",
            enable_prefix_cache=True,
            prefill_mode="chunked",
            prefill_chunk=args.prefill_chunk,
            **kw,
        )
        rng_mt = np.random.default_rng(args.seed + 31)
        # gen spans whole blocks (20 tokens, bs=8 -> 2 boundary crossings
        # per turn) and the user suffix is short (4 tokens), so warm
        # follow-up turns re-hit most of their own generation
        n_convs, n_turns, gen = 4, 3, 20
        hist = [
            rng_mt.integers(0, cfg.vocab, (16,)).astype(np.int32)
            for _ in range(n_convs)
        ]
        transcripts, per_turn, rid = [], [], 0
        for _t in range(n_turns):
            reqs_t = [
                Request(
                    rid=rid + c,
                    prompt=hist[c].copy(),
                    sampling=SamplingParams(max_tokens=gen),
                )
                for c in range(n_convs)
            ]
            rid += n_convs
            pf0 = eng.stats.prefill_tokens
            eng.run(reqs_t, max_steps=args.steps)
            by = {r.rid: r for r in eng.sched.finished}
            ttfts = []
            for c in range(n_convs):
                fr = by[reqs_t[c].rid]
                out = [int(x) for x in fr.output]
                transcripts.append(out)
                ttfts.append((fr.first_tok_t - fr.submit_t) * 1e3)
                # next turn: full history + the assistant reply + 4 fresh
                # "user" tokens (the conversation-tree generator)
                hist[c] = np.concatenate(
                    [
                        hist[c],
                        np.asarray(out, np.int32),
                        rng_mt.integers(0, cfg.vocab, (4,)).astype(np.int32),
                    ]
                )
            per_turn.append(
                {
                    "prefill_tokens": int(eng.stats.prefill_tokens - pf0),
                    "ttft_ms_p50": float(np.median(ttfts)),
                }
            )
        summ = eng.summary()
        row = {
            k: summ[k]
            for k in (
                "prefix_hit_rate",
                "prefix_hit_tokens",
                "prefix_partial_hits",
                "prefix_decode_inserted_blocks",
                "prefill_tokens",
                "decode_tokens",
                "total_blocks_allocated",
                "tokens_per_s",
            )
        }
        row["per_turn"] = per_turn
        return row, transcripts

    mt_warm, tx_warm = run_conversations(warm=True)
    mt_cold, tx_cold = run_conversations(warm=False)
    mt = {
        "warm": mt_warm,
        "cold": mt_cold,
        "parity": tx_warm == tx_cold,
        "hit_rate_lift": mt_warm["prefix_hit_rate"] - mt_cold["prefix_hit_rate"],
        "warm_turn_prefill_tokens": sum(
            r["prefill_tokens"] for r in mt_warm["per_turn"][1:]
        ),
        "cold_turn_prefill_tokens": sum(
            r["prefill_tokens"] for r in mt_cold["per_turn"][1:]
        ),
        "warm_over_cold_ttft": float(
            np.mean([r["ttft_ms_p50"] for r in mt_warm["per_turn"][1:]])
            / np.mean([r["ttft_ms_p50"] for r in mt_cold["per_turn"][1:]])
        ),
    }
    print(
        f"  warm: hit rate {mt_warm['prefix_hit_rate']:.2f} "
        f"({mt_warm['prefix_decode_inserted_blocks']:.0f} decode blocks "
        f"registered), cold (PR-9): {mt_cold['prefix_hit_rate']:.2f}"
    )
    print(
        f"  follow-up turns prefill {mt['warm_turn_prefill_tokens']} vs "
        f"{mt['cold_turn_prefill_tokens']} tokens; TTFT ratio "
        f"{mt['warm_over_cold_ttft']:.2f}"
    )

    print("== n=4 parallel sampling: one prefill + CoW fork (PR 10) ==")

    def run_fork(engine_cls):
        """One n=4 fork group per prompt vs 4 independent seeded requests
        on the same rids: tokens must be identical, blocks strictly
        fewer."""
        kwf = dict(
            num_blocks=64,
            block_size=args.block_size,
            max_batch=4,
            max_blocks_per_req=8,
            compute_dtype=jnp.float32,
            scheme="seq",
            prefill_mode="chunked",
            prefill_chunk=args.prefill_chunk,
            temperature=0.9,
            top_k=8,
            sample_seed=args.seed,
        )
        rng_f = np.random.default_rng(args.seed + 61)
        prompts = [
            rng_f.integers(0, cfg.vocab, (16,)).astype(np.int32)
            for _ in range(3)
        ]
        ge = engine_cls(cfg_nodrop, params, **kwf)
        ge.run(
            [
                Request(
                    rid=4 * i,
                    prompt=p.copy(),
                    arrival=2 * i,
                    sampling=SamplingParams(max_tokens=10, n=4),
                )
                for i, p in enumerate(prompts)
            ],
            max_steps=args.steps,
        )
        ie = engine_cls(cfg_nodrop, params, **kwf)
        ie.run(
            [
                Request(
                    rid=4 * i + j,
                    prompt=p.copy(),
                    arrival=2 * i,
                    sampling=SamplingParams(max_tokens=10),
                )
                for i, p in enumerate(prompts)
                for j in range(4)
            ],
            max_steps=args.steps,
        )
        gout = {r.rid: [int(t) for t in r.output] for r in ge.sched.finished}
        iout = {r.rid: [int(t) for t in r.output] for r in ie.sched.finished}
        gs, ins = ge.summary(), ie.summary()
        return {
            "parity": gout == iout,
            "group_blocks": gs["total_blocks_allocated"],
            "independent_blocks": ins["total_blocks_allocated"],
            "block_savings": 1.0
            - gs["total_blocks_allocated"] / ins["total_blocks_allocated"],
            "fork_groups": gs["fork_groups"],
            "fork_children": gs["fork_children"],
            "decode_tokens": gs["decode_tokens"],
            "prefill_tokens": gs["prefill_tokens"],
            "tokens_per_s": gs["tokens_per_s"],
        }

    fk_sync = run_fork(PagedMLAEngine)
    fk_async = run_fork(AsyncPagedMLAEngine)
    for name, row in (("sync", fk_sync), ("async", fk_async)):
        print(
            f"  {name}: {row['fork_groups']:.0f} groups x4, "
            f"{row['group_blocks']:.0f} vs {row['independent_blocks']:.0f} "
            f"blocks ({row['block_savings']:.0%} saved), parity="
            f"{row['parity']}, {row['tokens_per_s']:.1f} tok/s"
        )

    ok &= common.check(
        "multi-turn transcripts identical, warm vs PR-9 cold", mt["parity"]
    )
    ok &= common.check(
        "multi-turn hit-rate lift from decode-block reuse",
        mt["hit_rate_lift"] > 0.1,
        f"{mt_warm['prefix_hit_rate']:.2f} vs {mt_cold['prefix_hit_rate']:.2f}",
    )
    ok &= common.check(
        "decode blocks actually registered in the trie",
        mt_warm["prefix_decode_inserted_blocks"] > 0
        and mt_cold["prefix_decode_inserted_blocks"] == 0,
        f"{mt_warm['prefix_decode_inserted_blocks']:.0f}",
    )
    ok &= common.check(
        "warm follow-up turns prefill under half the cold tokens",
        mt["warm_turn_prefill_tokens"] * 2 < mt["cold_turn_prefill_tokens"],
        f"{mt['warm_turn_prefill_tokens']} vs "
        f"{mt['cold_turn_prefill_tokens']}",
    )
    ok &= common.check(
        "warm-turn TTFT cut vs cold cache",
        mt["warm_over_cold_ttft"] < 0.9,
        f"ratio {mt['warm_over_cold_ttft']:.2f}",
    )
    for name, row in (("sync", fk_sync), ("async", fk_async)):
        ok &= common.check(
            f"fork n=4 token-identical to 4 independent requests ({name})",
            row["parity"],
        )
        ok &= common.check(
            f"fork group allocates strictly fewer blocks ({name})",
            row["group_blocks"] < row["independent_blocks"],
            f"{row['group_blocks']:.0f} vs {row['independent_blocks']:.0f}",
        )

    common.save(
        "bench_multiturn.json",
        {
            "multiturn": mt,
            "fork": {"sync": fk_sync, "async": fk_async},
        },
    )

    common.save(
        "bench_serving.json",
        {
            "contiguous": base,
            "paged": pr1_save,
            "paged_prefix": pp_save,
            "paged_prefix_pallas": pk_save,
            "paged_mesh": pm_save,
            "paged_quant": qp_save,
            "paged_spec": spec_save,
            "util_gain": gain,
            "jax_device_count": jax.device_count(),
        },
    )
    common.save("bench_prefill_kernel.json", kb_save)
    # load-harness artifact (PR 9): the open-loop sweep, the located
    # knee vs the roofline ceiling, and the trace-driven parity row —
    # check_regression.py holds the step-denominated fields exactly and
    # the wall-clock ones with wide ratio bands.
    common.save(
        "bench_load.json",
        {
            "rates": rates,
            "requests_per_rate": args.load_requests,
            "sweep": sweep,
            "knee": knee,
            "trace_driven": {
                "sync": ld_sync,
                "async": ld_async,
                "trace_file": os.path.basename(args.arrival_trace),
            },
            "overlap": {
                "validated": not load_trace_problems,
                "device_spans": len(dev_spans),
                "schedule_spans": len(sch_spans),
                "device_overlaps_schedule": load_overlap,
            },
        },
    )
    # telemetry artifacts (PR 7): the Perfetto trace of the armed run,
    # the metrics snapshot, and the drift report the regression gate
    # diffs against benchmarks/baselines/bench_drift.json.
    common.save("trace_serving.json", trace)
    common.save("metrics_serving.json", tel.metrics.to_dict())
    common.save(
        "bench_drift.json",
        {
            "report": drift_report,
            "overhead": {
                "null_ns_per_hook": null_per_hook * 1e9,
                "hooks_per_step": hooks_per_step,
                "frac_of_mean_step": overhead_frac,
            },
            "ttft_ms": ttft,
            "tpot_ms": tel.metrics.histogram("tpot_ms").summary(),
        },
    )
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
