"""Serving benchmark: paged continuous batching (with and without the
radix prefix cache + chunked batched prefill) vs the contiguous
static-batch baseline, same request set.

Three runtimes over one shared-prefix request stream (every prompt opens
with the same system preamble, like production chat traffic):

  * contiguous  — what `launch/serve.py` did before PR 1: fixed batches,
    every slot gets the GLOBAL worst-case capacity, no request joins
    until the whole batch drains.
  * paged (PR-1) — continuous batching over the block pool, but every
    prompt is prefilled from scratch per-request (one jit retrace per
    prompt-length bucket) and no blocks are shared.
  * paged+prefix — this PR: radix prefix cache with copy-on-write block
    sharing (shared preamble blocks are ref-count-forked, not
    recomputed) and chunked batched prefill straight into the pool (one
    compiled prefill shape per chunk size, admitted requests prefill
    together).

Headline metrics: prefix hit rate, prefilled tokens (strictly fewer with
sharing), cumulative pool blocks allocated, prefill compiles (bounded by
chunk sizes, not prompt lengths), cache utilization; tokens/s on CPU is
directional only.  The modeled TTFT effect of the measured hit rate comes
from the closed-form prefix-hit term (hwmodel.attention_costs
.prefix_hit_savings / core.schemes.prefill_time).

    PYTHONPATH=src python benchmarks/bench_serving.py --requests 12
    PYTHONPATH=src python benchmarks/bench_serving.py --shared-prefix-len 0
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import common
import repro.configs as configs
import repro.models as models
from repro.core.schemes import prefill_time
from repro.hwmodel.attention_costs import prefix_hit_savings
from repro.hwmodel.platforms import PLATFORMS
from repro.launch.serve import _prepare_mla
from repro.nn import module as nnm
from repro.runtime import (PagedMLAEngine, Request, blocks_for,
                           make_prefill_step, make_serve_step)


def make_requests(n, vocab, rng, shared_prefix_len=16):
    """Mixed prompt/gen lengths, Poisson arrivals; every prompt opens with
    the same ``shared_prefix_len``-token system preamble (0 disables)."""
    arrivals = np.floor(np.cumsum(rng.exponential(2.5, n))).astype(int)
    preamble = rng.integers(0, vocab, (shared_prefix_len,)).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab,
                            (int(rng.choice([8, 16, 24, 32])),)
                            ).astype(np.int32)
        reqs.append(Request(
            rid=i,
            prompt=np.concatenate([preamble, tail]),
            max_new=int(rng.integers(4, 20)),
            arrival=int(arrivals[i])))
    return reqs


def run_contiguous(cfg, params, reqs, max_batch):
    """Static batching: fixed batches, global worst-case capacity, no
    admission until the running batch fully drains."""
    plen_max = max(r.plen for r in reqs)
    gen_max = max(r.max_new for r in reqs)
    capacity = plen_max + gen_max + 1
    params = _prepare_mla(params, cfg, "seq")
    prefill = make_prefill_step(cfg, None, batch=max_batch,
                                capacity=capacity,
                                compute_dtype=jnp.float32, scheme="seq")
    step = make_serve_step(cfg, None, compute_dtype=jnp.float32,
                           scheme="seq")
    util_sum, util_n, decode_tokens, steps = 0.0, 0, 0, 0
    prefill_tokens = 0
    outputs = {}
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), max_batch):
        batch = reqs[lo:lo + max_batch]
        B = len(batch)
        toks = np.zeros((max_batch, plen_max), np.int32)
        for b, r in enumerate(batch):   # right-align ragged prompts? no:
            toks[b, :r.plen] = r.prompt  # left-aligned, padded to plen_max
        logits, cache = prefill(params, jnp.asarray(toks))
        prefill_tokens += max_batch * plen_max   # padded slots pay too
        # NOTE: padded prompts make short requests see pad tokens — the
        # baseline's accuracy compromise; tokens are NOT compared against
        # the paged path here, only throughput/utilization are measured.
        pending = np.asarray(jnp.argmax(logits, -1))
        done_at = [r.max_new for r in batch]
        outs = [[int(pending[b])] for b in range(B)]
        n_steps = max(done_at)
        for i in range(n_steps - 1):
            logits, cache = step(params, jnp.asarray(pending), cache,
                                 plen_max + i)
            pending = np.asarray(jnp.argmax(logits, -1))
            live = 0
            for b in range(B):
                if len(outs[b]) < done_at[b]:
                    outs[b].append(int(pending[b]))
                    live += 1
            decode_tokens += live
            steps += 1
            # every slot reserves `capacity` tokens for the whole drain
            valid = sum(min(batch[b].plen + len(outs[b]), capacity)
                        for b in range(B))
            util_sum += valid / (max_batch * capacity)
            util_n += 1
        for b, r in enumerate(batch):
            outputs[r.rid] = outs[b]
    wall = time.perf_counter() - t0
    return {
        "steps": steps, "decode_tokens": decode_tokens,
        "prefill_tokens": prefill_tokens,
        "tokens_per_s": decode_tokens / wall if wall else 0.0,
        "cache_utilization": util_sum / max(util_n, 1),
        "capacity_per_slot": capacity,
    }


def run_paged(cfg, params, reqs, args, *, prefix: bool):
    """Paged runtime; ``prefix=False`` reproduces PR-1 (per-request
    prefill, no block sharing)."""
    bs = args.block_size
    num_blocks = 1 + sum(blocks_for(r.plen + r.max_new + 1, bs)
                         for r in reqs) // 2   # force block reuse
    per_req = max(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs)
    eng = PagedMLAEngine(
        cfg, params, num_blocks=num_blocks, block_size=bs,
        max_batch=args.max_batch, max_blocks_per_req=per_req,
        compute_dtype=jnp.float32, scheme="auto",
        platform=PLATFORMS["tpu_v5e"],
        enable_prefix_cache=prefix,
        prefill_mode="chunked" if prefix else "per_request",
        prefill_chunk=args.prefill_chunk)
    out = eng.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                           max_new=r.max_new, arrival=r.arrival)
                   for r in reqs], max_steps=args.steps)
    out["num_blocks"] = num_blocks
    out["outputs"] = {r.rid: r.output for r in eng.sched.finished}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--shared-prefix-len", type=int, default=16,
                    help="tokens of common system preamble (0 disables)")
    ap.add_argument("--steps", type=int, default=400,
                    help="paged-engine step budget")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(args.seed),
                             models.model_defs(cfg), jnp.float32)
    rng = np.random.default_rng(args.seed + 1)
    reqs = make_requests(args.requests, cfg.vocab, rng,
                         args.shared_prefix_len)

    print("== contiguous static batching (baseline) ==")
    base = run_contiguous(cfg, params,
                          [Request(rid=r.rid, prompt=r.prompt.copy(),
                                   max_new=r.max_new) for r in reqs],
                          args.max_batch)
    print(f"  {base['decode_tokens']} decode tokens, "
          f"{base['tokens_per_s']:.1f} tok/s, utilization "
          f"{base['cache_utilization']:.3f} "
          f"(every slot reserves {base['capacity_per_slot']} tokens)")

    print("== paged, PR-1 (per-request prefill, no sharing) ==")
    pr1 = run_paged(cfg, params, reqs, args, prefix=False)
    print(f"  {pr1['decode_tokens']:.0f} decode tokens, "
          f"{pr1['prefill_tokens']:.0f} prefilled, "
          f"{pr1['total_blocks_allocated']:.0f} blocks allocated, "
          f"{pr1['prefill_compiles']:.0f} prefill compiles")

    print("== paged + radix prefix cache + chunked prefill (this PR) ==")
    pp = run_paged(cfg, params, reqs, args, prefix=True)
    print(f"  {pp['decode_tokens']:.0f} decode tokens, "
          f"{pp['prefill_tokens']:.0f} prefilled "
          f"(hit rate {pp['prefix_hit_rate']:.2f}), "
          f"{pp['total_blocks_allocated']:.0f} blocks allocated, "
          f"{pp['prefill_compiles']:.0f} prefill compile "
          f"(chunk={args.prefill_chunk}), "
          f"{pp['prefix_evictions']:.0f} evictions")

    # modeled TTFT effect of the measured hit rate (full-scale config)
    mla = configs.full("deepseek-v2-236b").mla_config()
    plat = PLATFORMS["tpu_v5e"]
    L = 1024
    P = int(round(L * pp["prefix_hit_rate"]))
    if 0 < P < L:
        t0, t1 = (prefill_time(mla, plat, L),
                  prefill_time(mla, plat, L, cached_prefix=P))
        sav = prefix_hit_savings(mla, seq_len=L, cached_prefix=P)
        print(f"  modeled TTFT (1 layer, L={L}, hit {P} tokens): "
              f"{t0 * 1e6:.0f} -> {t1 * 1e6:.0f} us "
              f"({t0 / t1:.2f}x; {sav['flops_frac']:.0%} FLOPs, "
              f"{sav['bytes_frac']:.0%} bytes saved)")

    gain = pp["cache_utilization"] / max(base["cache_utilization"], 1e-9)
    rows = [
        ["contiguous", base["decode_tokens"], base["prefill_tokens"],
         "-", "-", f"{base['cache_utilization']:.3f}", "-"],
        ["paged (PR-1)", int(pr1["decode_tokens"]),
         int(pr1["prefill_tokens"]), int(pr1["total_blocks_allocated"]),
         int(pr1["prefill_compiles"]), f"{pr1['cache_utilization']:.3f}",
         "0.00"],
        ["paged+prefix", int(pp["decode_tokens"]),
         int(pp["prefill_tokens"]), int(pp["total_blocks_allocated"]),
         int(pp["prefill_compiles"]), f"{pp['cache_utilization']:.3f}",
         f"{pp['prefix_hit_rate']:.2f}"],
    ]
    md = common.table(
        ["runtime", "decode tok", "prefill tok", "blocks alloc",
         "prefill compiles", "cache util", "hit rate"], rows)
    print("\n" + md)

    ok = True
    ok &= common.check("paged utilization beats contiguous",
                 pp["cache_utilization"] > base["cache_utilization"],
                 f"{pp['cache_utilization']:.3f} vs "
                 f"{base['cache_utilization']:.3f}")
    ok &= common.check("mid-generation admission happened",
                        pp["mid_gen_admissions"] > 0)
    ok &= common.check("identical outputs with and without prefix sharing",
                       pr1["outputs"] == pp["outputs"])
    if args.shared_prefix_len:
        ok &= common.check("prefix hit rate > 0",
                           pp["prefix_hit_rate"] > 0,
                           f"{pp['prefix_hit_rate']:.2f}")
        ok &= common.check(
            "prefix sharing prefills strictly fewer tokens",
            pp["prefill_tokens"] < pr1["prefill_tokens"],
            f"{pp['prefill_tokens']:.0f} vs {pr1['prefill_tokens']:.0f}")
        ok &= common.check(
            "prefix sharing allocates fewer pool blocks",
            pp["total_blocks_allocated"] < pr1["total_blocks_allocated"],
            f"{pp['total_blocks_allocated']:.0f} vs "
            f"{pr1['total_blocks_allocated']:.0f}")
    ok &= common.check(
        "chunked prefill compiles are bounded (1 chunk size)",
        pp["prefill_compiles"] == 1,
        f"{pp['prefill_compiles']:.0f} vs {pr1['prefill_compiles']:.0f} "
        f"per-plen buckets")
    pp_save = {k: v for k, v in pp.items() if k != "outputs"}
    pr1_save = {k: v for k, v in pr1.items() if k != "outputs"}
    common.save("bench_serving.json", {"contiguous": base, "paged": pr1_save,
                                       "paged_prefix": pp_save,
                                       "util_gain": gain})
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
