"""Serving benchmark: paged continuous batching vs the contiguous
static-batch baseline, same request set.

The contiguous baseline is what `launch/serve.py` did before this PR:
requests are grouped into fixed batches, every slot gets the GLOBAL
worst-case capacity (max prompt + max gen), and no request joins until the
whole batch drains.  The paged runtime admits mid-generation and allocates
block-granular capacity, so the same pool serves more live tokens —
``cache utilization`` (valid tokens / reserved token slots, time-averaged)
is the headline metric; tokens/s on CPU is directional only.

    PYTHONPATH=src python benchmarks/bench_serving.py --requests 12
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import common
import repro.configs as configs
import repro.models as models
from repro.hwmodel.platforms import PLATFORMS
from repro.launch.serve import _prepare_mla
from repro.nn import module as nnm
from repro.runtime import (PagedMLAEngine, Request, blocks_for,
                           make_prefill_step, make_serve_step)


def make_requests(n, vocab, rng):
    """Mixed prompt/gen lengths, Poisson arrivals (quantized prompts)."""
    arrivals = np.floor(np.cumsum(rng.exponential(2.5, n))).astype(int)
    reqs = []
    for i in range(n):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab,
                                (int(rng.choice([8, 16, 24, 32])),)
                                ).astype(np.int32),
            max_new=int(rng.integers(4, 20)),
            arrival=int(arrivals[i])))
    return reqs


def run_contiguous(cfg, params, reqs, max_batch):
    """Static batching: fixed batches, global worst-case capacity, no
    admission until the running batch fully drains."""
    plen_max = max(r.plen for r in reqs)
    gen_max = max(r.max_new for r in reqs)
    capacity = plen_max + gen_max + 1
    params = _prepare_mla(params, cfg, "seq")
    prefill = make_prefill_step(cfg, None, batch=max_batch,
                                capacity=capacity,
                                compute_dtype=jnp.float32, scheme="seq")
    step = make_serve_step(cfg, None, compute_dtype=jnp.float32,
                           scheme="seq")
    util_sum, util_n, decode_tokens, steps = 0.0, 0, 0, 0
    outputs = {}
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), max_batch):
        batch = reqs[lo:lo + max_batch]
        B = len(batch)
        toks = np.zeros((max_batch, plen_max), np.int32)
        for b, r in enumerate(batch):   # right-align ragged prompts? no:
            toks[b, :r.plen] = r.prompt  # left-aligned, padded to plen_max
        logits, cache = prefill(params, jnp.asarray(toks))
        # NOTE: padded prompts make short requests see pad tokens — the
        # baseline's accuracy compromise; tokens are NOT compared against
        # the paged path here, only throughput/utilization are measured.
        pending = np.asarray(jnp.argmax(logits, -1))
        done_at = [r.max_new for r in batch]
        outs = [[int(pending[b])] for b in range(B)]
        n_steps = max(done_at)
        for i in range(n_steps - 1):
            logits, cache = step(params, jnp.asarray(pending), cache,
                                 plen_max + i)
            pending = np.asarray(jnp.argmax(logits, -1))
            live = 0
            for b in range(B):
                if len(outs[b]) < done_at[b]:
                    outs[b].append(int(pending[b]))
                    live += 1
            decode_tokens += live
            steps += 1
            # every slot reserves `capacity` tokens for the whole drain
            valid = sum(min(batch[b].plen + len(outs[b]), capacity)
                        for b in range(B))
            util_sum += valid / (max_batch * capacity)
            util_n += 1
        for b, r in enumerate(batch):
            outputs[r.rid] = outs[b]
    wall = time.perf_counter() - t0
    return {
        "steps": steps, "decode_tokens": decode_tokens,
        "tokens_per_s": decode_tokens / wall if wall else 0.0,
        "cache_utilization": util_sum / max(util_n, 1),
        "capacity_per_slot": capacity,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--steps", type=int, default=400,
                    help="paged-engine step budget")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(args.seed),
                             models.model_defs(cfg), jnp.float32)
    rng = np.random.default_rng(args.seed + 1)
    reqs = make_requests(args.requests, cfg.vocab, rng)

    print("== contiguous static batching (baseline) ==")
    base = run_contiguous(cfg, params,
                          [Request(rid=r.rid, prompt=r.prompt.copy(),
                                   max_new=r.max_new) for r in reqs],
                          args.max_batch)
    print(f"  {base['decode_tokens']} decode tokens, "
          f"{base['tokens_per_s']:.1f} tok/s, utilization "
          f"{base['cache_utilization']:.3f} "
          f"(every slot reserves {base['capacity_per_slot']} tokens)")

    print("== paged continuous batching ==")
    bs = args.block_size
    num_blocks = 1 + sum(blocks_for(r.plen + r.max_new + 1, bs)
                         for r in reqs) // 2   # force block reuse
    per_req = max(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs)
    eng = PagedMLAEngine(cfg, params, num_blocks=num_blocks, block_size=bs,
                         max_batch=args.max_batch, max_blocks_per_req=per_req,
                         compute_dtype=jnp.float32, scheme="auto",
                         platform=PLATFORMS["tpu_v5e"])
    paged = eng.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                             max_new=r.max_new, arrival=r.arrival)
                     for r in reqs], max_steps=args.steps)
    print(f"  {paged['decode_tokens']:.0f} decode tokens, "
          f"{paged['tokens_per_s']:.1f} tok/s, utilization "
          f"{paged['cache_utilization']:.3f}, "
          f"{paged['mid_gen_admissions']:.0f} mid-gen admissions, "
          f"pool {num_blocks - 1} x {bs}")

    gain = paged["cache_utilization"] / max(base["cache_utilization"], 1e-9)
    rows = [
        ["contiguous", base["decode_tokens"], f"{base['tokens_per_s']:.1f}",
         f"{base['cache_utilization']:.3f}", "-"],
        ["paged", int(paged["decode_tokens"]), f"{paged['tokens_per_s']:.1f}",
         f"{paged['cache_utilization']:.3f}", f"{gain:.2f}x"],
    ]
    md = common.table(
        ["runtime", "decode tokens", "tok/s", "cache util", "util gain"],
        rows)
    print("\n" + md)
    common.check("paged utilization beats contiguous",
                 paged["cache_utilization"] > base["cache_utilization"],
                 f"{paged['cache_utilization']:.3f} vs "
                 f"{base['cache_utilization']:.3f}")
    common.check("mid-generation admission happened",
                 paged["mid_gen_admissions"] > 0)
    common.save("bench_serving.json", {"contiguous": base, "paged": paged,
                                       "util_gain": gain})


if __name__ == "__main__":
    main()
