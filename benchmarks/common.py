"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
from typing import List

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def save(name: str, payload) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, name)
    with open(path, "w") as f:
        if name.endswith(".json"):
            json.dump(payload, f, indent=1)
        else:
            f.write(payload)
    return path


def table(headers: List[str], rows: List[List]) -> str:
    """Markdown table."""

    def fmt(x):
        if isinstance(x, float):
            return f"{x:.4g}"
        return str(x)

    out = [
        "| " + " | ".join(headers) + " |",
        "|" + "|".join("---" for _ in headers) + "|",
    ]
    for r in rows:
        out.append("| " + " | ".join(fmt(x) for x in r) + " |")
    return "\n".join(out) + "\n"


def check(name: str, ok: bool, detail: str = "") -> bool:
    mark = "PASS" if ok else "FAIL"
    print(f"  [{mark}] {name}" + (f" — {detail}" if detail else ""))
    return ok
