"""§Roofline report: aggregate the dry-run artifacts into the per-cell
three-term roofline table (EXPERIMENTS.md consumes this output).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train shapes;
             2*N(_active)*D for inference shapes (fwd only).
The MODEL_FLOPS / HLO_FLOPS ratio flags remat/recompute waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs, models
from repro.configs.shapes import SHAPES

from .common import save, table

DRY = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def active_params(cfg) -> float:
    """Active params per token (MoE: routed top-k + shared only)."""
    total = models.param_count(cfg)
    if not cfg.n_experts:
        return total
    # routed expert params NOT active: (E - top_k)/E of the routed bank
    plan = cfg.layer_plan()
    n_moe = sum(1 for s in (plan[0] + plan[1] * plan[2] + plan[3]) if s.ffn == "moe")
    routed = n_moe * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
    active_routed = routed * cfg.top_k / cfg.n_experts
    return total - routed + active_routed


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.full(arch)
    sh = SHAPES[shape_name]
    n = active_params(cfg)
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len
    return 2.0 * n * sh.global_batch  # decode: 1 token/seq


def load_cells(mesh_tag: str):
    cells = {}
    for path in glob.glob(os.path.join(DRY, f"{mesh_tag}_*.json")):
        d = json.load(open(path))
        pol = d.get("policy") or "-"
        cells[(d["arch"], d["shape"], d.get("scheme"), pol)] = d
    return cells


def run(mesh_tag: str = "16x16") -> bool:
    cells = load_cells(mesh_tag)
    if not cells:
        print(
            f"[roofline] no dry-run artifacts for mesh {mesh_tag}; run "
            "PYTHONPATH=src python -m repro.launch.dryrun first"
        )
        return True
    rows = []
    for (arch, shape, scheme, pol), d in sorted(cells.items()):
        mf = model_flops(arch, shape)
        hlo = d["hlo_flops_per_chip"] * d["chips"]
        rows.append(
            [
                arch,
                shape,
                scheme or "-",
                pol,
                f"{d['t_compute']:.3e}",
                f"{d['t_memory']:.3e}",
                f"{d['t_collective']:.3e}",
                d["bound"],
                f"{d['roofline_fraction']:.3f}",
                f"{mf / max(hlo, 1):.2f}",
                d.get("hbm_residency_gib", "-"),
            ]
        )
    md = (
        f"# Roofline — per (arch x shape), mesh {mesh_tag}, TPU v5e "
        "(197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n\n"
        + table(
            [
                "arch",
                "shape",
                "scheme",
                "policy",
                "t_compute",
                "t_memory",
                "t_collective",
                "bound",
                "roofline frac",
                "model/HLO flops",
                "HBM res GiB",
            ],
            rows,
        )
    )
    # skipped cells
    skip_rows = []
    for arch in configs.ARCHS:
        for s, why in configs.skip_shapes(arch).items():
            skip_rows.append([arch, s, why])
    if skip_rows:
        md += "\n## Skipped cells\n\n" + table(
            ["arch", "shape", "reason"], skip_rows
        )
    save(f"roofline_{mesh_tag}.md", md)
    print(md)
    return True


if __name__ == "__main__":
    import sys

    tag = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    raise SystemExit(0 if run(tag) else 1)
