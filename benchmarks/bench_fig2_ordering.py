"""Paper Fig 2: op counts of the score-chain product orders in decode.

Orders of  Q_l . W_up^Q . W_up^{K,T} . C^T :
  1->2->3 (left-to-right, factored)       = our 'seq'
  1->3->2 (naive: up-project the cache)
  2->1->3 (absorb recompute)              = MLA_rc
  ru      (absorb precomputed)            = MLA_ru

Reproduced claims: the naive order is catastrophically worse and scales
with L; the absorbed orders converge for long caches.  DOCUMENTED
DISCREPANCY (EXPERIMENTS.md §Fig2): under pure op counting at batch=1 the
factored 1->2->3 is never above 2->1->3 (the +2*H*Q*dn*K recompute term);
the paper's "rc is best" emerges once DRAM bytes are priced in (Fig 5),
because rc keeps the absorbed product on-chip at identical weight traffic.
"""
from repro.hwmodel import attention_costs as ac

from .common import check, save, table

ORDERS = ["123", "132", "213", "ru"]
LENGTHS = [128, 1024, 8192, 65536, 524288]


def run() -> bool:
    rows = []
    for L in LENGTHS:
        costs = {o: ac.score_chain_ops(ac.DSV3_MLA, o, L) for o in ORDERS}
        rows.append(
            [L] + [f"{costs[o]:.3g}" for o in ORDERS] + [min(costs, key=costs.get)]
        )
    md = "# Fig 2 — score-chain op counts by multiplication order (B=1)\n\n" + table(
        ["cache len L"] + ORDERS + ["argmin"], rows
    )
    save("fig2_ordering.md", md)
    print(md)
    ok = True
    for L in (8192, 65536, 524288):
        costs = {o: ac.score_chain_ops(ac.DSV3_MLA, o, L) for o in ORDERS}
        ok &= check(f"L={L}: naive(132) worst", costs["132"] == max(costs.values()))
    big = {o: ac.score_chain_ops(ac.DSV3_MLA, o, 4_000_000) for o in ORDERS}
    ok &= check(
        "absorbed orders converge at large L",
        abs(big["123"] - big["213"]) / big["123"] < 0.05,
    )
    ok &= check(
        "seq (123) <= rc (213) in pure ops [documented discrepancy]",
        all(
            ac.score_chain_ops(ac.DSV3_MLA, "123", L)
            <= ac.score_chain_ops(ac.DSV3_MLA, "213", L)
            for L in LENGTHS
        ),
    )
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
