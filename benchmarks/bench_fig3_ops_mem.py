"""Paper Fig 3: total operations and off-chip memory accesses of one
attention layer (batch=1), prefill and decode, for the four methods.

Reproduced claims:
  * accesses for MHA and MLA_rc start equal-ish, MLA_rc scales far better
    with L (smaller cache dimension);
  * MLA_rc trades additional computations for reduced memory accesses.
"""
from repro.hwmodel import roofline as R

from .common import check, save, table

METHODS = ["mha_l", "mha_s", "mla_ru", "mla_rc"]
LENGTHS = [256, 2048, 16384, 131072]


def run() -> bool:
    rows_d, rows_p = [], []
    for L in LENGTHS:
        d = {m: R.decode_cost(m, cache_len=L, with_softmax=False) for m in METHODS}
        p = {m: R.prefill_cost(m, seq_len=L) for m in METHODS}
        rows_d.append([L] + [f"{d[m].flops:.3g} / {d[m].bytes:.3g}" for m in METHODS])
        rows_p.append([L] + [f"{p[m].flops:.3g} / {p[m].bytes:.3g}" for m in METHODS])
    md = (
        "# Fig 3 — ops / off-chip bytes per layer (B=1)\n\n## decode\n\n"
        + table(["cache L"] + METHODS, rows_d)
        + "\n## prefill\n\n"
        + table(["seq L"] + METHODS, rows_p)
    )
    save("fig3_ops_mem.md", md)
    print(md)

    ok = True
    small = {m: R.decode_cost(m, cache_len=256, with_softmax=False) for m in METHODS}
    big = {m: R.decode_cost(m, cache_len=131072, with_softmax=False) for m in METHODS}
    growth_mha = big["mha_l"].bytes - small["mha_l"].bytes
    growth_rc = big["mla_rc"].bytes - small["mla_rc"].bytes
    ok &= check(
        "MLA_rc byte growth << MHA byte growth (smaller cache dim)",
        growth_rc < growth_mha / 20,
        f"{growth_rc:.3g} vs {growth_mha:.3g}",
    )
    ok &= check(
        "MLA_rc: more flops, fewer bytes than MLA_ru",
        big["mla_rc"].flops > big["mla_ru"].flops
        and big["mla_rc"].bytes < big["mla_ru"].bytes,
    )
    ok &= check(
        "decode accesses comparable at small L (MHA vs MLA_rc)",
        0.1 < small["mla_rc"].bytes / small["mha_s"].bytes < 10,
    )
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
