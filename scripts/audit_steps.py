#!/usr/bin/env python
"""Static hot-path auditor CLI — drives repro.analysis.audit + jaxlint.

Compiles (never executes) every hot-path step factory and checks the
optimized HLO / jaxpr invariants: donation aliasing, pallas gather budget,
dtype discipline, and roofline conformance against hwmodel.attention_costs.
Exits non-zero when any unsuppressed finding remains.

Usage:
    python scripts/audit_steps.py                      # single-device matrix
    python scripts/audit_steps.py --matrix mesh        # forced-8-device matrix
    python scripts/audit_steps.py --matrix all --json out.json
    python scripts/audit_steps.py --lint-only          # AST pass only

``--matrix mesh`` (and ``all``) force ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` BEFORE jax initializes — run it
in a fresh process (the Makefile ``audit`` lane and tests/test_audit.py
both spawn it that way).
"""

import argparse
import json
import os
import sys


def parse_args(argv):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[1])
    p.add_argument(
        "--matrix",
        choices=("single", "mesh", "all", "none"),
        default="single",
        help="which step matrix to compile (mesh forces 8 host devices)",
    )
    p.add_argument(
        "--lint",
        action="store_true",
        default=None,
        help="run the jaxlint AST pass (default: on for single/all)",
    )
    p.add_argument(
        "--no-lint", dest="lint", action="store_false", help="skip jaxlint"
    )
    p.add_argument(
        "--lint-only",
        action="store_true",
        help="shorthand for --matrix none --lint",
    )
    p.add_argument(
        "--lint-root",
        default=None,
        help="directory tree for jaxlint (default: src/repro next to repo root)",
    )
    p.add_argument("--json", default=None, help="write findings as JSON here")
    return p.parse_args(argv)


def main(argv=None):
    args = parse_args(sys.argv[1:] if argv is None else argv)
    if args.lint_only:
        args.matrix, args.lint = "none", True
    if args.lint is None:
        args.lint = args.matrix in ("single", "all", "none")

    if args.matrix in ("mesh", "all"):
        # must land before jax (imported transitively below) initializes
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(repo_root, "src"))
    from repro.analysis import audit, jaxlint

    findings = []
    specs = []
    if args.matrix in ("single", "all"):
        specs += audit.single_device_matrix()
    if args.matrix in ("mesh", "all"):
        specs += audit.mesh_matrix()
    for spec in specs:
        print(f"[audit] compiling {spec.where}", flush=True)
        findings += audit.audit_step(spec)
    if args.lint:
        root = args.lint_root or os.path.join(repo_root, "src", "repro")
        print(f"[audit] jaxlint over {root}", flush=True)
        findings += jaxlint.lint_tree(root)

    kept, suppressed = audit.split_allowlisted(findings)
    for f in suppressed:
        print(f"[audit] suppressed (allowlist): {f}")
    for f in kept:
        print(f"[audit] FINDING {f}")
    print(
        f"[audit] {len(specs)} cells compiled, {len(kept)} findings, "
        f"{len(suppressed)} suppressed"
    )
    if args.json:
        payload = {
            "findings": [vars(f) for f in kept],
            "suppressed": [vars(f) for f in suppressed],
            "cells": [s.where for s in specs],
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
    return 1 if kept else 0


if __name__ == "__main__":
    sys.exit(main())
