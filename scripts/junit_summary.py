"""Summarize pytest junit XML files into a markdown table (the CI job
summary): one row per test lane (fast / kernel / mesh / audit), with
suite-size counts, so a shrinking suite is visible in the PR UI instead of
hiding behind a green check.

    python scripts/junit_summary.py reports/junit-*.xml

Appends to $GITHUB_STEP_SUMMARY when set (the Actions job-summary file),
always prints to stdout.  The lane name is parsed from the file name
(junit-<lane>.xml).  Exits non-zero if any parsed lane reports failures
or errors, or if a named file is missing — a lane whose XML vanished is
a lane that silently stopped running.
"""

from __future__ import annotations

import os
import sys
import xml.etree.ElementTree as ET


def lane_name(path):
    stem = os.path.splitext(os.path.basename(path))[0]
    return stem[len("junit-") :] if stem.startswith("junit-") else stem


def parse(path):
    root = ET.parse(path).getroot()
    suites = [root] if root.tag == "testsuite" else list(root)
    out = {"tests": 0, "failures": 0, "errors": 0, "skipped": 0, "time": 0.0}
    for s in suites:
        for key in ("tests", "failures", "errors", "skipped"):
            out[key] += int(s.get(key, 0))
        out["time"] += float(s.get("time", 0.0))
    out["passed"] = out["tests"] - out["failures"] - out["errors"] - out["skipped"]
    return out


def main(paths):
    if not paths:
        print("usage: junit_summary.py <junit-*.xml> [...]", file=sys.stderr)
        return 2
    rows, bad = [], 0
    for path in paths:
        if not os.path.exists(path):
            rows.append([lane_name(path), "-", "-", "-", "-", "-", "MISSING"])
            bad += 1
            continue
        r = parse(path)
        broken = r["failures"] + r["errors"]
        bad += broken
        rows.append(
            [
                lane_name(path),
                str(r["tests"]),
                str(r["passed"]),
                str(r["failures"]),
                str(r["errors"]),
                str(r["skipped"]),
                f"{r['time']:.0f}s",
            ]
        )
    header = ["lane", "tests", "passed", "failures", "errors", "skipped", "time"]
    lines = [
        "### Test suite per lane",
        "",
        "| " + " | ".join(header) + " |",
        "|" + "|".join("---" for _ in header) + "|",
    ]
    lines += ["| " + " | ".join(r) + " |" for r in rows]
    md = "\n".join(lines) + "\n"
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
