"""Docs gate (`make docs-check`, CI `docs` job): fail on stale documentation.

Three checks, all static — no jax import, so the CI job needs nothing
but a Python interpreter:

  links      every intra-repo markdown link in README.md and docs/*.md
             resolves to an existing file (anchors and external URLs
             are skipped; a rename that orphans a link fails here).
  readme     every ``--flag`` defined by launch/serve.py's argparse
             appears in README.md — the flag table cannot silently
             fall behind the CLI.
  docstrings every argparse flag of the serving CLIs (launch/serve.py,
             examples/serve_mla.py) is mentioned in that module's own
             docstring — the long-form docs ride in the files and this
             pins them to the code (tests/test_docs.py runs the same
             functions inside tier 1).

Flags are collected by ast-walking the source for ``add_argument``
calls, so the check never imports (or executes) the CLIs.
"""

from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# CLIs whose module docstring must document every argparse flag.
DOCSTRING_CLIS = (
    os.path.join("src", "repro", "launch", "serve.py"),
    os.path.join("examples", "serve_mla.py"),
)

SERVE_CLI = os.path.join("src", "repro", "launch", "serve.py")

_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")


def md_files():
    """README.md plus everything under docs/, repo-relative paths."""
    out = ["README.md"]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                out.append(os.path.join("docs", name))
    return out


def check_links():
    """Every intra-repo markdown link resolves.  Returns problem strings."""
    problems = []
    for rel in md_files():
        path = os.path.join(ROOT, rel)
        base = os.path.dirname(path)
        with open(path) as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            dest = os.path.normpath(os.path.join(base, target.split("#", 1)[0]))
            if not os.path.exists(dest):
                problems.append(f"{rel}: broken link -> {target}")
    return problems


def argparse_flags(rel_path):
    """All ``--flag`` strings passed to add_argument in the file (by ast)."""
    with open(os.path.join(ROOT, rel_path)) as f:
        tree = ast.parse(f.read())
    flags = []
    for node in ast.walk(tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "add_argument"
        ):
            continue
        for arg in node.args:
            if isinstance(arg, ast.Constant) and str(arg.value).startswith("--"):
                flags.append(arg.value)
    return flags


def _missing_flags(flags, text):
    return [
        flag
        for flag in flags
        if not re.search(r"(?<![\w-])" + re.escape(flag) + r"(?![\w-])", text)
    ]


def check_readme_flags():
    """Every launch/serve.py flag appears in README.md."""
    with open(os.path.join(ROOT, "README.md")) as f:
        text = f.read()
    return [
        f"README.md: launch/serve.py flag missing from the flag table: {flag}"
        for flag in _missing_flags(argparse_flags(SERVE_CLI), text)
    ]


def check_docstring_parity():
    """Every CLI flag is mentioned in its module's own docstring."""
    problems = []
    for rel in DOCSTRING_CLIS:
        with open(os.path.join(ROOT, rel)) as f:
            doc = ast.get_docstring(ast.parse(f.read())) or ""
        for flag in _missing_flags(argparse_flags(rel), doc):
            problems.append(f"{rel}: flag {flag} missing from the module docstring")
    return problems


def main():
    problems = check_links() + check_readme_flags() + check_docstring_parity()
    for p in problems:
        print(f"[FAIL] {p}")
    if problems:
        print(f"\n{len(problems)} docs problem(s)")
        return 1
    n_links = sum(
        len(_LINK_RE.findall(open(os.path.join(ROOT, rel)).read()))
        for rel in md_files()
    )
    n_flags = len(argparse_flags(SERVE_CLI))
    print(
        f"docs check: {len(md_files())} markdown files, {n_links} links, "
        f"{n_flags} serve.py flags covered — all good"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
