"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward + one train step on CPU, assert output
shapes and absence of NaNs; check prefill/decode consistency for one arch
per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
import repro.models as models
from repro.nn import module as nnm
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step, TrainStepConfig

ARCHS = C.ARCHS


def _batch(cfg, B=2, L=24, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b = {"tokens": jax.random.randint(ks[0], (B, L), 0, cfg.vocab),
         "labels": jax.random.randint(ks[1], (B, L), 0, cfg.vocab)}
    if cfg.family in ("vlm", "encdec"):
        P = cfg.n_patches if cfg.family == "vlm" else cfg.n_frames
        b["embeds"] = jax.random.normal(ks[2], (B, P, cfg.d_model),
                                        jnp.float32) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCHS + ["deepseek-v3-671b"])
def test_smoke_forward_shapes_no_nan(arch):
    cfg = C.smoke(arch)
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    b = _batch(cfg)
    logits, aux = models.forward(params, cfg, b["tokens"],
                                 embeds=b.get("embeds"),
                                 compute_dtype=jnp.float32)
    P = 0
    if cfg.family == "vlm":
        P = cfg.n_patches
    assert logits.shape == (2, b["tokens"].shape[1] + P, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert set(aux) == {"balance", "z_loss", "dropped_frac"}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = C.smoke(arch)
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step, _ = make_train_step(cfg, None, opt_cfg,
                              TrainStepConfig(compute_dtype=jnp.float32))
    b = _batch(cfg)
    before = [np.asarray(x) for x in jax.tree.leaves(params)]  # pre-donation
    p1, o1, m1 = step(params, opt, b)
    assert np.isfinite(float(m1["loss"]))
    p2, o2, m2 = step(p1, o1, _batch(cfg, seed=1))
    assert np.isfinite(float(m2["loss"]))
    # params actually changed
    delta = max(float(np.max(np.abs(np.asarray(a) - b2)))
                for a, b2 in zip(jax.tree.leaves(p2), before))
    assert delta > 0


@pytest.mark.parametrize("arch", [
    "gemma3-1b",              # dense, local:global windows
    "deepseek-v2-236b",       # MLA + MoE (the paper's arch)
    "jamba-1.5-large-398b",   # hybrid mamba/attn/MoE
    "xlstm-350m",             # pure SSM
    "whisper-medium",         # encoder-decoder
    "internvl2-26b",          # VLM with patch prefix
])
def test_prefill_decode_match_forward(arch):
    """prefill(tokens) + decode(t) logits == teacher-forced forward.

    MoE capacity is made non-binding (capacity_factor=64): token-drop
    patterns legitimately differ between a 26-token forward and a 1-token
    decode, which is capacity semantics, not an equivalence bug."""
    import dataclasses
    cfg = dataclasses.replace(C.smoke(arch), capacity_factor=64.0)
    params = nnm.init_params(jax.random.PRNGKey(1), models.model_defs(cfg),
                             jnp.float32)
    b = _batch(cfg, B=2, L=12, seed=2)
    toks = b["tokens"]
    logits, _ = models.forward(params, cfg, toks, embeds=b.get("embeds"),
                               compute_dtype=jnp.float32)
    last, cache = models.prefill(params, cfg, toks, embeds=b.get("embeds"),
                                 capacity=32, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               atol=3e-4, rtol=1e-4)
    # one decode step == forward over L+1 tokens
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    # decode index counts CACHED positions: VLM caches patches + text
    index = toks.shape[1] + (cfg.n_patches if cfg.family == "vlm" else 0)
    step_logits, cache = models.decode_step(
        params, cfg, nxt, cache, index, compute_dtype=jnp.float32)
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    logits2, _ = models.forward(params, cfg, toks2, embeds=b.get("embeds"),
                                compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(logits2[:, -1]), atol=3e-4,
                               rtol=1e-4)


def test_full_configs_param_counts():
    """FULL configs match the published model sizes (±10%)."""
    expect = {
        "jamba-1.5-large-398b": 398e9, "gemma3-1b": 1.0e9,
        "granite-34b": 34e9, "phi3-mini-3.8b": 3.8e9,
        "starcoder2-7b": 7e9, "deepseek-v2-236b": 236e9,
        "granite-moe-1b-a400m": 1.3e9, "whisper-medium": 0.77e9,
        "internvl2-26b": 19.3e9,  # backbone only; ViT is stubbed
        "deepseek-v3-671b": 671e9,
    }
    for arch, want in expect.items():
        got = models.param_count(C.full(arch))
        assert abs(got - want) / want < 0.4, (arch, got, want)


def test_xlstm_param_count_soft():
    got = models.param_count(C.full("xlstm-350m"))
    assert 3e8 < got < 6e8


def test_layer_plans_cover_all_layers():
    for arch in ARCHS:
        cfg = C.full(arch)
        if cfg.family == "encdec":
            continue
        prefix, period, n, suffix = cfg.layer_plan()
        assert len(prefix) + len(period) * n + len(suffix) == cfg.n_layers


def test_gemma3_local_global_pattern():
    cfg = C.full("gemma3-1b")
    prefix, period, n, suffix = cfg.layer_plan()
    assert len(period) == 6 and n == 4
    wins = [s.window for s in period]
    assert wins[:5] == [512] * 5 and wins[5] is None


def test_jamba_interleave_pattern():
    cfg = C.full("jamba-1.5-large-398b")
    _, period, n, _ = cfg.layer_plan()
    assert len(period) == 8 and n == 9
    mixers = [s.mixer for s in period]
    assert mixers.count("attn") == 1 and mixers[3] == "attn"
    assert [s.ffn for s in period].count("moe") == 4
