"""Dry-run machinery on a small (2,2) mesh in a SUBPROCESS (the forced
device count must be set before jax initializes, and the main pytest
process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.launch import specs as S
from repro.launch.dryrun import analyze_compiled
from repro.runtime.steps import make_train_step, make_serve_step, TrainStepConfig
from repro.optim import AdamWConfig
from repro import configs, models

mesh = make_mesh((2, 2), ("data", "model"))
cfg = configs.smoke("deepseek-v2-236b")

# train cell
sf, _ = make_train_step(cfg, mesh, AdamWConfig(),
                        TrainStepConfig(impl="chunked", loss_chunk=8))
batch = {"tokens": S.sds((4, 32), jnp.int32), "labels": S.sds((4, 32), jnp.int32)}
low = sf.lower(S.param_specs(cfg), S.opt_specs(cfg, AdamWConfig()), batch)
comp = low.compile()
train = analyze_compiled(low, comp, 4)

# decode cell
cache = jax.eval_shape(lambda: models.init_cache(cfg, 4, 64, jnp.bfloat16))
fn = make_serve_step(cfg, mesh, scheme="rc")(cache, 4)
low2 = fn.lower(S.param_specs(cfg), S.sds((4,), jnp.int32), cache,
                S.sds((), jnp.int32))
comp2 = low2.compile()
dec = analyze_compiled(low2, comp2, 4)
print(json.dumps({"train": train, "decode": dec}))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_train_cell_compiles_and_counts(results):
    t = results["train"]
    assert t["hlo_flops_per_chip"] > 1e6
    assert t["hlo_bytes_per_chip"] > 1e6
    assert t["bound"] in ("compute", "memory", "collective")
    assert t["t_compute"] > 0 and t["t_memory"] > 0


def test_train_has_collectives(results):
    """FSDP + TP sharding must produce collective traffic."""
    assert results["train"]["collective_bytes_per_chip"] > 0
    assert results["train"]["collective_by_kind"]


def test_decode_cell_compiles(results):
    d = results["decode"]
    assert d["hlo_flops_per_chip"] > 0
    assert d["mem_argument_size_in_bytes"] > 0


def test_roofline_terms_consistent(results):
    from repro.hwmodel.platforms import TPU_V5E_PEAK_FLOPS
    t = results["train"]
    assert t["t_compute"] == pytest.approx(
        t["hlo_flops_per_chip"] / TPU_V5E_PEAK_FLOPS, rel=1e-6)
