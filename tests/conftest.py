import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS); keep math deterministic.
os.environ.setdefault("JAX_ENABLE_X64", "0")
