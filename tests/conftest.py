import contextlib
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS); keep math deterministic.
os.environ.setdefault("JAX_ENABLE_X64", "0")

import pytest  # noqa: E402  (path shim must run first)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "kernel: Pallas kernel oracle-parity tests — execute (not skip) on "
        "CPU via pl.pallas_call(interpret=True); ci.yml runs them as a "
        "dedicated step (`make test-kernels`)")
    config.addinivalue_line(
        "markers",
        "mesh: multi-device sharded-serving parity tests — execute (not "
        "skip) on CPU-only boxes: the CI `mesh` job and `make test-mesh` "
        "force XLA_FLAGS=--xla_force_host_platform_device_count=8, and "
        "the suites' subprocess drivers force it themselves so plain "
        "`make test` covers them too")
    config.addinivalue_line(
        "markers",
        "audit: static hot-path auditor suite — compiles (never executes) "
        "every serve-step cell and checks donation/gather/dtype/roofline "
        "invariants on the optimized HLO, plus the jaxlint AST pass and "
        "injected-violation regressions; the CI `audit` job and `make "
        "test-audit` run it as its own lane (mesh cells go through a "
        "subprocess that forces 8 host devices itself)")


@pytest.fixture
def interpret_mode():
    """Force Pallas kernels onto the interpreter so the oracle-parity
    suites EXECUTE in CPU CI instead of skipping.

    Newer jax exposes ``pltpu.force_tpu_interpret_mode()``; older
    versions (the baked-in toolchain) do not, but every kernel wrapper in
    repro.kernels defaults ``interpret=None`` -> True on the CPU backend,
    so the fixture degrades to a no-op there — asserted by the suites
    themselves, which pass ``interpret=True`` explicitly at the kernel
    level and rely on the backend default at the ops/engine level."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        cm = pltpu.force_tpu_interpret_mode()
    except (ImportError, AttributeError):
        cm = contextlib.nullcontext()
    with cm:
        yield
