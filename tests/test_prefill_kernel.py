"""Oracle-parity suite for the paged chunked-prefill Pallas kernel
(kernels/mla_prefill.py) — every kernel path is pinned against TWO
independent references:

  1. the pure-jnp oracle ``ref.mla_prefill_paged_ref`` (kernel level);
  2. the PR-2 gather path (``core.mla.mla_prefill_chunk_paged`` with
     impl='gather'), which itself is pinned against the contiguous
     MHA-mode prefill in tests/test_prefix_cache.py (core level);

sweeping schemes x chunk sizes x ragged lengths, plus adversarial block
tables: interleaved null blocks, lengths exactly on a block boundary,
single-token tail chunks, and chunks larger than the remaining prompt.
Everything runs (not skips) on CPU via pl.pallas_call(interpret=True) —
the ``kernel`` marker wires the module into its own CI step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cachelib
from repro.core import mla as mlalib
from repro.core.schemes import prefill_time
from repro.hwmodel import attention_costs as ac
from repro.hwmodel.platforms import PLATFORMS
from repro.kernels import ref
from repro.kernels.mla_decode import mla_decode_paged_kernel
from repro.kernels.mla_prefill import mla_prefill_paged_kernel
from repro.nn import module as nnm

pytestmark = pytest.mark.kernel

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}

MCFG = mlalib.MLAConfig(d_model=64, n_heads=4, q_lora_rank=48,
                        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    rng = np.random.default_rng(seed)
    q = rand(ks[0], (B, C, H, Dl + Dr), dtype)
    ckv = rand(ks[1], (N, bs, Dl), dtype)
    krope = rand(ks[2], (N, bs, Dr), dtype)
    bt = jnp.asarray(rng.integers(0, N, (B, nb)), jnp.int32)
    return q, ckv, krope, bt


# ---------------------------------------------------------- kernel level ---


@pytest.mark.parametrize("B,C,H,Dl,Dr,bs,nb,N,lengths,n_valid", [
    (1, 4, 4, 32, 8, 4, 2, 4, [0], [4]),        # first chunk of a prompt
    (3, 6, 4, 32, 8, 4, 8, 16, [0, 5, 11], [6, 3, 0]),   # ragged + idle row
    (2, 8, 8, 64, 16, 8, 3, 8, [8, 15], [8, 1]),  # boundary start + 1-tail
    (2, 5, 4, 32, 8, 16, 2, 6, [0, 27], [5, 5]),  # big blocks, deep start
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_kernel_vs_oracle(B, C, H, Dl, Dr, bs, nb, N, lengths,
                                  n_valid, dtype, interpret_mode):
    q, ckv, krope, bt = _inputs(B, C, H, Dl, Dr, bs, nb, N, dtype=dtype)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    out = mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                   interpret=True)
    want = ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths, n_valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("block_q", [1, 2, 3, 8])
def test_prefill_kernel_query_tiling(block_q):
    """C-query tiles: every block_q (incl. non-dividing -> padded tiles)
    reproduces the untiled kernel and the oracle."""
    B, C, H, Dl, Dr, bs, nb, N = 2, 7, 4, 32, 8, 4, 6, 12
    q, ckv, krope, bt = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=3)
    lengths = jnp.asarray([2, 9], jnp.int32)
    n_valid = jnp.asarray([7, 4], jnp.int32)
    want = ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths, n_valid)
    out = mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                   block_q=block_q, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_prefill_kernel_padding_rows_are_zero():
    """Rows past n_valid (and whole idle requests) return EXACT zeros —
    the contract that keeps kernel/oracle parity assertable everywhere."""
    B, C, H, Dl, Dr, bs, nb, N = 2, 6, 4, 32, 8, 4, 4, 8
    q, ckv, krope, bt = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=4)
    lengths = jnp.asarray([3, 0], jnp.int32)
    n_valid = jnp.asarray([2, 0], jnp.int32)
    out = np.asarray(mla_prefill_paged_kernel(q, ckv, krope, bt, lengths,
                                              n_valid, interpret=True))
    assert (out[0, 2:] == 0).all()
    assert (out[1] == 0).all()
    want = np.asarray(ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths,
                                                n_valid))
    assert (want[0, 2:] == 0).all() and (want[1] == 0).all()


def test_prefill_kernel_ignores_unreferenced_pages():
    """Poisoning pool blocks outside the table must not change results."""
    B, C, H, Dl, Dr, bs, nb, N = 1, 4, 4, 32, 8, 4, 3, 8
    q, _, _, _ = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=5)
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    ckv = rand(ks[0], (N, bs, Dl))
    krope = rand(ks[1], (N, bs, Dr))
    bt = jnp.asarray([[2, 5, 1]], jnp.int32)
    lengths = jnp.asarray([6], jnp.int32)
    n_valid = jnp.asarray([4], jnp.int32)
    out = mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                   interpret=True)
    poisoned = [i for i in range(N) if i not in (1, 2, 5)]
    out_p = mla_prefill_paged_kernel(
        q, ckv.at[jnp.asarray(poisoned)].set(1e4),
        krope.at[jnp.asarray(poisoned)].set(1e4), bt, lengths, n_valid,
        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p), atol=1e-6)


def test_prefill_kernel_chunk1_equals_decode_kernel():
    """Cross-kernel triangle: a single-token chunk at position L (latent
    already in the pool) must agree with the paged flash-DECODE kernel at
    indices == L — the prefill kernel really is its multi-query sibling."""
    B, H, Dl, Dr, bs, nb, N = 3, 4, 32, 8, 4, 6, 14
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = rand(ks[0], (B, H, Dl + Dr))
    ckv = rand(ks[1], (N, bs, Dl))
    krope = rand(ks[2], (N, bs, Dr))
    rng = np.random.default_rng(7)
    bt = jnp.asarray(rng.integers(1, N, (B, nb)), jnp.int32)
    lengths = jnp.asarray([0, 7, 20], jnp.int32)
    dec = mla_decode_paged_kernel(q, ckv, krope, bt, lengths, interpret=True)
    pre = mla_prefill_paged_kernel(q[:, None], ckv, krope, bt, lengths,
                                   jnp.ones((B,), jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(pre[:, 0]), np.asarray(dec),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------- adversarial tables ----


def test_adversarial_interleaved_null_blocks():
    """Null-block entries interleaved with stale allocated blocks BEYOND
    the valid extent: masking must make both invisible."""
    B, C, H, Dl, Dr, bs, nb, N = 1, 4, 4, 32, 8, 4, 6, 10
    q, ckv, krope, _ = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=8)
    # resident extent: 2 blocks (lengths+n_valid = 8); beyond it the
    # table interleaves null entries with stale (poisoned) blocks.
    bt_clean = jnp.asarray([[3, 7, 0, 0, 0, 0]], jnp.int32)
    bt_dirty = jnp.asarray([[3, 7, 0, 9, 0, 4]], jnp.int32)
    lengths = jnp.asarray([4], jnp.int32)
    n_valid = jnp.asarray([4], jnp.int32)
    ckv_p = ckv.at[jnp.asarray([9, 4])].set(1e4)
    krope_p = krope.at[jnp.asarray([9, 4])].set(1e4)
    want = ref.mla_prefill_paged_ref(q, ckv, krope, bt_clean, lengths,
                                     n_valid)
    for table, c, r in ((bt_clean, ckv, krope), (bt_dirty, ckv_p, krope_p)):
        out = mla_prefill_paged_kernel(q, c, r, table, lengths, n_valid,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("lengths,n_valid", [
    ([4], [4]),      # chunk ends EXACTLY on a block boundary
    ([8], [4]),      # chunk starts AND ends on block boundaries
    ([7], [1]),      # single-token tail chunk crossing into a new block
    ([3], [1]),      # single-token tail chunk inside a block
])
def test_adversarial_block_boundaries(lengths, n_valid):
    B, C, H, Dl, Dr, bs, nb, N = 1, 4, 4, 32, 8, 4, 4, 9
    q, ckv, krope, bt = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=9)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    out = mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                   interpret=True)
    want = ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths, n_valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_adversarial_chunk_larger_than_remaining_prompt():
    """C much larger than every request's remaining prompt (the common
    last-chunk shape): the garbage tail must not leak into valid rows."""
    B, C, H, Dl, Dr, bs, nb, N = 3, 16, 4, 32, 8, 4, 8, 26
    q, ckv, krope, bt = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=10)
    lengths = jnp.asarray([0, 6, 13], jnp.int32)
    n_valid = jnp.asarray([2, 5, 3], jnp.int32)
    out = mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                   interpret=True)
    want = ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths, n_valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    for b in range(B):
        assert (np.asarray(out)[b, int(n_valid[b]):] == 0).all()


# ------------------------------------------------------------ core level ---


def _filled_pool(params, lengths, bs, nb, N, seed=1):
    """Pool + scrambled block table with per-request token history already
    resident (the state a mid-prompt chunk sees)."""
    rng = np.random.default_rng(seed)
    B = len(lengths)
    bt = jnp.asarray(rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb),
                     jnp.int32)
    pool = cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                       MCFG.qk_rope_dim, jnp.float32)
    for b in range(B):
        L = int(lengths[b])
        if not L:
            continue
        x = jnp.asarray(rng.standard_normal((1, L, MCFG.d_model)) * 0.1,
                        jnp.float32)
        ckv, krope = mlalib._kv_latent(params, MCFG, x,
                                       jnp.arange(L, dtype=jnp.int32)[None])
        for t in range(L):
            pool = cachelib.update_latent_paged(
                pool, bt[b:b + 1], jnp.asarray([t], jnp.int32),
                ckv[:, t], krope[:, t])
    return pool, bt


@pytest.mark.parametrize("scheme", ["seq", "rc", "ru"])
@pytest.mark.parametrize("chunk", [1, 3, 8])
def test_kernel_matches_gather_path(scheme, chunk):
    """THE acceptance criterion: impl='pallas' allclose to the PR-2
    gather path (and thereby to the contiguous MHA-mode prefill, pinned
    in tests/test_prefix_cache.py) for every absorption scheme, at
    ragged lengths, with identical pool contents after the step."""
    bs, nb, N = 4, 8, 40
    lengths = np.asarray([0, 5, 11], np.int32)
    B = len(lengths)
    params = nnm.init_params(jax.random.PRNGKey(0), mlalib.mla_defs(MCFG),
                             jnp.float32)
    params = mlalib.prepare_serving(params, MCFG, "ru")
    pool, bt = _filled_pool(params, lengths, bs, nb, N)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((B, chunk, MCFG.d_model)) * 0.1,
                    jnp.float32)
    n_valid = jnp.asarray([chunk, max(chunk - 1, 1), 0], jnp.int32)
    og, pg = mlalib.mla_prefill_chunk_paged(
        params, MCFG, x, dict(pool), bt, jnp.asarray(lengths), n_valid,
        scheme=scheme, impl="gather")
    op, pp = mlalib.mla_prefill_chunk_paged(
        params, MCFG, x, dict(pool), bt, jnp.asarray(lengths), n_valid,
        scheme=scheme, impl="pallas")
    for b in range(B):
        v = int(n_valid[b])
        np.testing.assert_allclose(np.asarray(og[b, :v]),
                                   np.asarray(op[b, :v]),
                                   atol=3e-5, rtol=3e-5)
    for leaf in ("ckv", "krope"):
        np.testing.assert_allclose(np.asarray(pg[leaf]), np.asarray(pp[leaf]),
                                   atol=1e-6)


def test_naive_scheme_falls_back_to_gather():
    """'naive' (the paper's strawman) has no kernel path: impl='pallas'
    must still compute the same function via the gather view."""
    bs, nb, N = 4, 4, 16
    lengths = np.asarray([3], np.int32)
    params = nnm.init_params(jax.random.PRNGKey(1), mlalib.mla_defs(MCFG),
                             jnp.float32)
    pool, bt = _filled_pool(params, lengths, bs, nb, N)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (1, 4, MCFG.d_model)) * 0.1, jnp.float32)
    nv = jnp.asarray([4], jnp.int32)
    for impl in ("gather", "pallas"):
        o_n, _ = mlalib.mla_prefill_chunk_paged(
            params, MCFG, x, dict(pool), bt, jnp.asarray(lengths), nv,
            scheme="naive", impl=impl)
        o_s, _ = mlalib.mla_prefill_chunk_paged(
            params, MCFG, x, dict(pool), bt, jnp.asarray(lengths), nv,
            scheme="seq", impl=impl)
        np.testing.assert_allclose(np.asarray(o_n), np.asarray(o_s),
                                   atol=3e-5, rtol=3e-5)


def test_bad_impl_raises():
    params = nnm.init_params(jax.random.PRNGKey(0), mlalib.mla_defs(MCFG),
                             jnp.float32)
    pool = cachelib.paged_latent_cache(4, 4, MCFG.kv_lora_rank,
                                       MCFG.qk_rope_dim, jnp.float32)
    with pytest.raises(ValueError, match="prefill impl"):
        mlalib.mla_prefill_chunk_paged(
            params, MCFG, jnp.zeros((1, 2, MCFG.d_model)), pool,
            jnp.asarray([[1]], jnp.int32), jnp.asarray([0], jnp.int32),
            jnp.asarray([2], jnp.int32), impl="cuda")


# ------------------------------------------------------ hypothesis sweep ---


def test_prefill_kernel_oracle_property():
    """Hypothesis-driven sweep: random pool geometry, scrambled tables,
    ragged lengths/n_valid and query tilings all agree with the oracle."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dep: property-based sweeps")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def drive(data):
        B = data.draw(st.integers(1, 3), label="B")
        C = data.draw(st.integers(1, 6), label="C")
        H = data.draw(st.sampled_from([1, 2, 4]), label="H")
        bs = data.draw(st.sampled_from([2, 4, 8]), label="bs")
        nb = data.draw(st.integers(1, 4), label="nb")
        Dl, Dr = 16, 8
        N = data.draw(st.integers(2, 8), label="N")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        cap = nb * bs
        lengths, n_valid = [], []
        for b in range(B):
            ln = data.draw(st.integers(0, max(cap - 1, 0)), label=f"len{b}")
            nv = data.draw(st.integers(0, min(C, cap - ln)), label=f"nv{b}")
            lengths.append(ln), n_valid.append(nv)
        block_q = data.draw(st.integers(0, C), label="block_q")
        q, ckv, krope, bt = _inputs(B, C, H, Dl, Dr, bs, nb, N, seed=seed)
        lengths = jnp.asarray(lengths, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        out = mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                       block_q=block_q, interpret=True)
        want = ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths, n_valid)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    drive()


# ----------------------------------------------------------------- hwmodel -


def test_prefill_chunk_cost_pallas_beats_gather():
    """The roofline point of the PR: replacing the materialized gather
    with in-place paged reads strictly cuts bytes (and masked-score
    FLOPs), raising the attention term's arithmetic intensity."""
    kw = dict(seq_len=512, chunk=64, paged_block=128, batch=2)
    g = ac.mla_prefill_chunk_cost(ac.DSV3_MLA, impl="gather", **kw)
    p = ac.mla_prefill_chunk_cost(ac.DSV3_MLA, impl="pallas", **kw)
    assert p.bytes < g.bytes
    assert p.flops <= g.flops
    # arithmetic intensity of the ATTENTION term (the projections
    # dominate whole-layer FLOPs at short chunks and would mask it):
    # the gather path moves 3x the pool bytes (gather read + view write
    # + attention re-read) for no additional useful work.
    g_attn_oi = g.breakdown["attn_scores_pv"] / (
        g.breakdown["B:cache_read"] + g.breakdown["B:gather_materialize"])
    p_attn_oi = p.breakdown["attn_scores_pv"] / (
        p.breakdown["B:cache_read"] + p.breakdown["B:block_table"])
    assert p_attn_oi > g_attn_oi
    assert "B:gather_materialize" in g.breakdown
    assert "B:block_table" in p.breakdown
    assert "B:gather_materialize" not in p.breakdown
    # early chunks only stream the resident extent: the in-place read
    # total is strictly below n_chunks * full-extent
    n_chunks = 512 // 64
    full = 2 * 512 * (512 + 0) * 2          # B * W * K * w at rope=False
    assert p.breakdown["B:cache_read"] < n_chunks * full
    # a cached prefix cuts both paths
    ph = ac.mla_prefill_chunk_cost(ac.DSV3_MLA, impl="pallas",
                                   cached_prefix=256, **kw)
    assert ph.flops < p.flops and ph.bytes < p.bytes


def test_prefill_time_reflects_chunk_impl():
    plat = PLATFORMS["tpu_v5e"]
    t_gather = prefill_time(ac.DSV3_MLA, plat, 2048, chunk=128,
                            paged_block=128, impl="gather")
    t_pallas = prefill_time(ac.DSV3_MLA, plat, 2048, chunk=128,
                            paged_block=128, impl="pallas")
    t_plain = prefill_time(ac.DSV3_MLA, plat, 2048)
    assert t_pallas < t_gather          # the kernel's whole point
    assert t_plain <= t_pallas          # paging + chunking is never free
