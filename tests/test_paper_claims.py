"""Validation of the reproduction against the paper's own numbers/claims.

Table 1 (exact):   MLA 174M, MHA_l 470M, MHA_s 172M params / attention layer
Fig 2 (ordering):  1->3->2 (naive) worst at long L; absorbed orders converge
Fig 3 (ops/bytes): MLA_rc trades extra ops for fewer bytes vs MLA_ru
Fig 4 (OI):        MHA flat-low; MLA_ru cache-dependent; MLA_rc high/stable
Fig 5 (dispatch):  rc wins on compute-rich platforms, ru when compute-poor
"""
from repro.core import mla as M
from repro.core.schemes import PlatformPoint, auto_dispatch
from repro.hwmodel import attention_costs as ac
from repro.hwmodel import roofline as R


def test_table1_param_counts_exact():
    assert round(M.param_count(ac.DSV3_MLA, rope=False) / 1e6) == 174
    assert round(ac.MHA_L.param_count() / 1e6) == 470
    assert round(ac.MHA_S.param_count() / 1e6) == 172


def test_fig2_naive_order_worst_at_long_cache():
    for L in (4096, 32768, 131072):
        costs = {o: ac.score_chain_ops(ac.DSV3_MLA, o, L)
                 for o in ("123", "132", "213", "ru")}
        assert costs["132"] == max(costs.values())
    # absorbed orders converge to the same asymptote (attention-dominated)
    big = 4_000_000
    c = {o: ac.score_chain_ops(ac.DSV3_MLA, o, big) for o in ("123", "213")}
    assert abs(c["123"] - c["213"]) / c["123"] < 0.05


def test_fig2_seq_order_never_more_ops_than_rc():
    """Our op accounting: the factored 1->2->3 never exceeds 2->1->3 at
    batch=1.  (The paper's Fig-2 'rc is best' conclusion emerges on the
    two-term roofline where rc's identical BYTES but on-chip absorb matter
    — documented discrepancy, EXPERIMENTS.md §Fig2.)"""
    for L in (128, 4096, 131072):
        assert ac.score_chain_ops(ac.DSV3_MLA, "123", L) <= \
            ac.score_chain_ops(ac.DSV3_MLA, "213", L)


def test_fig3_rc_trades_ops_for_bytes_vs_ru():
    for L in (1024, 16384, 131072):
        rc = ac.mla_decode_cost(ac.DSV3_MLA, scheme="rc", cache_len=L)
        ru = ac.mla_decode_cost(ac.DSV3_MLA, scheme="ru", cache_len=L)
        assert rc.flops > ru.flops      # rc recomputes the absorbed matrix
        assert rc.bytes < ru.bytes      # ru streams it from DRAM


def test_fig3_mla_bytes_scale_better_than_mha():
    """Cache bytes/token: latent (D_kvl + D_r) << 2 * n_h * D_qk (MHA)."""
    small = R.decode_cost("mla_rc", cache_len=1024)
    big = R.decode_cost("mla_rc", cache_len=131072)
    small_m = R.decode_cost("mha_l", cache_len=1024)
    big_m = R.decode_cost("mha_l", cache_len=131072)
    assert (big.bytes - small.bytes) < (big_m.bytes - small_m.bytes) / 20


def test_fig4_oi_trends():
    L = (1024, 16384, 131072)
    mha = [R.decode_cost("mha_l", cache_len=l).oi for l in L]
    mha_s = [R.decode_cost("mha_s", cache_len=l).oi for l in L]
    ru = [R.decode_cost("mla_ru", cache_len=l).oi for l in L]
    rc = [R.decode_cost("mla_rc", cache_len=l).oi for l in L]
    # MHA: consistently low OI regardless of cache size (paper: "flat")
    assert max(mha) < 2 and max(mha_s) < 2
    assert max(mha) / min(mha) < 1.5
    # MLA_ru: OI strongly cache-size dependent
    assert ru[-1] / ru[0] > 10
    # MLA_rc: significantly higher OI, mild sensitivity
    assert min(rc) > 20 * max(mha)
    assert rc[-1] / rc[0] < 2.5


def test_fig4_prefill_oi_high_everywhere():
    for m in ("mla_rc", "mha_l", "mha_s"):
        assert R.prefill_cost(m, seq_len=4096).oi > 500


def test_fig5_dispatch_crossover():
    """rc on compute-rich platforms; ru only when compute is scarce
    relative to bandwidth (the paper's 'uncommon case')."""
    rich = PlatformPoint("rich", 200e12, 400e9)     # 500 FLOP/B ridge
    poor = PlatformPoint("poor", 0.5e12, 400e9)     # 1.25 FLOP/B ridge
    L = 8192
    t = lambda s, p: max(
        ac.mla_decode_cost(ac.DSV3_MLA, scheme=s, cache_len=L).flops / p.peak_flops,
        ac.mla_decode_cost(ac.DSV3_MLA, scheme=s, cache_len=L).bytes / p.hbm_bw)
    assert t("rc", rich) < t("ru", rich)
    assert t("ru", poor) < t("rc", poor)
    assert auto_dispatch(ac.DSV3_MLA, poor, L, candidates=("rc", "ru")) == "ru"
    assert auto_dispatch(ac.DSV3_MLA, rich, L, candidates=("rc", "ru")) == "rc"


def test_beyond_paper_seq_dominates_two_term():
    """Our beyond-paper scheme: 'seq' has rc's bytes with fewer FLOPs, so it
    weakly dominates rc at every design point (DESIGN.md §4)."""
    for L in (1024, 32768, 262144):
        seq = ac.mla_decode_cost(ac.DSV3_MLA, scheme="seq", cache_len=L)
        rc = ac.mla_decode_cost(ac.DSV3_MLA, scheme="rc", cache_len=L)
        assert seq.bytes == rc.bytes
        assert seq.flops <= rc.flops


def test_mla_cache_bytes_per_token():
    from repro.core.cache import bytes_per_token_dense, bytes_per_token_latent
    # DeepSeek-V2/V3: 576 latent dims * 2 B = 1152 B/token/layer vs
    # MHA 128 heads * 128 * 2 * 2 B = 65536 B — a 56.9x reduction.
    lat = bytes_per_token_latent(512, 64)
    dense = bytes_per_token_dense(128, 128)
    assert lat == 1152 and dense == 65536
    assert dense / lat > 50
