"""MLA core: execution-scheme equivalence (the paper's central claim that
rc/ru/naive/seq "implement the same algorithm with identical weights"),
prefill/decode consistency, and weight absorption — including a hypothesis
property sweep over dimensions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep: property-based sweeps")
from hypothesis import given, settings, strategies as st

from repro.core import cache as cachelib
from repro.core import mla as M
from repro.nn import module as nnm

CFG = M.MLAConfig(d_model=96, n_heads=4, q_lora_rank=32, kv_lora_rank=24,
                  qk_nope_dim=12, qk_rope_dim=8, v_head_dim=12)


def setup(cfg=CFG, seed=0, B=2, L=9):
    params = nnm.init_params(jax.random.PRNGKey(seed), M.mla_defs(cfg),
                             jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (B, L, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    return params, x, pos


def decode_all(params, cfg, x, scheme, capacity=None):
    B, L, _ = x.shape
    cap = capacity or L
    params = M.prepare_serving(params, cfg, scheme)
    cache = cachelib.latent_cache(B, cap, cfg.kv_lora_rank, cfg.qk_rope_dim,
                                  jnp.float32)
    outs = []
    for t in range(L):
        y, cache = M.mla_decode(params, cfg, x[:, t], cache, t, scheme=scheme)
        outs.append(y)
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("scheme", M.SCHEMES)
def test_decode_matches_prefill(scheme):
    params, x, pos = setup()
    want, _ = M.mla_prefill(params, CFG, x, pos)
    got = decode_all(params, CFG, x, scheme)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_all_schemes_agree_pairwise():
    params, x, _ = setup(seed=7)
    outs = {s: decode_all(params, CFG, x, s) for s in M.SCHEMES}
    for s in ("seq", "rc", "ru"):
        np.testing.assert_allclose(np.asarray(outs[s]),
                                   np.asarray(outs["naive"]), atol=2e-5)


def test_capacity_larger_than_len():
    """Cache capacity > sequence length must not change results."""
    params, x, pos = setup()
    want, _ = M.mla_prefill(params, CFG, x, pos)
    got = decode_all(params, CFG, x, "rc", capacity=x.shape[1] + 13)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_absorb_is_product():
    params, _, _ = setup()
    w = M.absorb_qk(params, CFG)
    want = jnp.einsum("qhn,khn->hqk",
                      params["w_uq"][:, :, :CFG.qk_nope_dim], params["w_uk"])
    np.testing.assert_allclose(np.asarray(w), np.asarray(want), atol=1e-6)
    assert w.shape == (CFG.n_heads, CFG.q_lora_rank, CFG.kv_lora_rank)


@settings(max_examples=15, deadline=None)
@given(
    n_heads=st.sampled_from([1, 2, 4]),
    q_lora=st.sampled_from([8, 16, 40]),
    kv_lora=st.sampled_from([8, 24]),
    dn=st.sampled_from([4, 16]),
    dr=st.sampled_from([2, 8]),
    L=st.integers(min_value=1, max_value=7),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_scheme_equivalence_property(n_heads, q_lora, kv_lora, dn, dr, L, seed):
    """Property: for ANY dims, all four schemes compute the same function."""
    cfg = M.MLAConfig(d_model=32, n_heads=n_heads, q_lora_rank=q_lora,
                      kv_lora_rank=kv_lora, qk_nope_dim=dn, qk_rope_dim=dr,
                      v_head_dim=dn)
    params, x, pos = setup(cfg, seed=seed % 100, B=1, L=L)
    want, _ = M.mla_prefill(params, cfg, x, pos)
    for scheme in ("seq", "rc", "ru"):
        got = decode_all(params, cfg, x, scheme)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5)


def test_param_count_closed_form_matches_defs():
    # closed form counts projection weights only; the defs additionally
    # carry the two rmsnorm scales (q_lora_rank + kv_lora_rank entries).
    diff = nnm.count_params(M.mla_defs(CFG)) - M.param_count(CFG, rope=True)
    assert diff == CFG.q_lora_rank + CFG.kv_lora_rank
