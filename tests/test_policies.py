"""Sharding-policy equivalence on a tiny multi-device mesh (subprocess:
the forced device count must precede jax init).

Covers the §Perf hillclimb features:
  * serve_2dtp (resident-weight 2D serving TP + seq-sharded latent cache)
    computes the same prefill/decode function as the unsharded reference;
  * GQA head padding preserves the train step exactly (loss + updated
    params) for head counts that do not divide the TP axis;
  * policy='dp' trains identically to unsharded.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro import configs, models
from repro.nn import module as nnm, sharding as shd
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import (make_prefill_step, make_serve_step,
                                 make_train_step, TrainStepConfig)

mesh = make_mesh((4, 4), ("data", "model"))
out = {}

# ---- serve_2dtp functional equivalence (MLA + MoE arch) -------------------
cfg = configs.smoke("deepseek-v2-236b")
params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                         jnp.float32)
B, L, cap = 4, 12, 24
toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab)
pre0 = make_prefill_step(cfg, None, batch=B, capacity=cap,
                         compute_dtype=jnp.float32, scheme="rc")
st0 = make_serve_step(cfg, None, compute_dtype=jnp.float32, scheme="rc")
lg0, c0 = pre0(params, toks)
nxt = jnp.argmax(lg0, -1).astype(jnp.int32)
out0, _ = st0(params, nxt, c0, L)
rules = shd.make_rules(mesh, mode="serve_2dtp", cfg=cfg)
p = jax.tree.map(jax.device_put, params,
                 shd.param_shardings(models.model_defs(cfg), mesh, rules))
pre = make_prefill_step(cfg, mesh, batch=B, capacity=cap,
                        compute_dtype=jnp.float32, scheme="rc",
                        policy="serve_2dtp")
lg, c = pre(p, toks)
step = make_serve_step(cfg, mesh, compute_dtype=jnp.float32, scheme="rc",
                       policy="serve_2dtp")(
    jax.eval_shape(lambda: models.init_cache(cfg, B, cap, jnp.float32)),
    B, cap)
o2, _ = step(p, nxt, c, L)
out["2dtp_prefill_err"] = float(jnp.max(jnp.abs(lg - lg0)))
out["2dtp_decode_err"] = float(jnp.max(jnp.abs(o2 - out0)))
out["2dtp_tokens_match"] = bool(
    (jnp.argmax(lg, -1) == jnp.argmax(lg0, -1)).all()
    and (jnp.argmax(o2, -1) == jnp.argmax(out0, -1)).all())

# ---- head padding + dp policy train equivalence ---------------------------
for arch, policy in (("starcoder2-7b", "train"), ("gemma3-1b", "train"),
                     ("xlstm-350m", "dp")):
    cfg = configs.smoke(arch)
    opt_cfg = AdamWConfig(lr=1e-3)
    def fresh():
        pp = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
        return pp, adamw_init(pp, opt_cfg)
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 24), 0,
                                      cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 24), 0,
                                      cfg.vocab)}
    s0, _ = make_train_step(cfg, None, opt_cfg,
                            TrainStepConfig(compute_dtype=jnp.float32,
                                            impl="chunked"))
    p0, o0 = fresh()
    p0n, _, m0 = s0(p0, o0, b)
    s1, _ = make_train_step(cfg, mesh, opt_cfg,
                            TrainStepConfig(compute_dtype=jnp.float32,
                                            impl="chunked"), policy=policy)
    p1, o1 = fresh()
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg)
    sh = shd.param_shardings(models.model_defs(cfg), mesh, rules)
    p1 = jax.tree.map(jax.device_put, p1, sh)
    o1 = {"step": o1["step"], "mu": jax.tree.map(jax.device_put, o1["mu"], sh),
          "nu": jax.tree.map(jax.device_put, o1["nu"], sh)}
    p1n, _, m1 = s1(p1, o1, b)
    err = max(float(jnp.max(jnp.abs(jnp.asarray(a) - jnp.asarray(c))))
              for a, c in zip(jax.tree.leaves(p0n), jax.tree.leaves(p1n)))
    out[f"{arch}_loss_delta"] = abs(float(m0["loss"]) - float(m1["loss"]))
    out[f"{arch}_param_err"] = err

print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_serve_2dtp_equivalent(results):
    assert results["2dtp_prefill_err"] < 2e-2
    assert results["2dtp_decode_err"] < 2e-2
    assert results["2dtp_tokens_match"]


@pytest.mark.parametrize("arch", ["starcoder2-7b", "gemma3-1b", "xlstm-350m"])
def test_policy_train_step_equivalent(results, arch):
    assert results[f"{arch}_loss_delta"] < 1e-4
    assert results[f"{arch}_param_err"] < 1e-3
