"""chunked_attention ("XLA-flash") vs dense reference: forward + backward,
GQA/window/ragged sweeps + hypothesis property test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep: property-based sweeps")
from hypothesis import given, settings, strategies as st

from repro.core.attention import gqa_attention, gqa_decode
from repro.core.chunked_attention import chunked_attention


def mk(B, Lq, Lk, H, Hkv, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Lq, H, D))
    k = jax.random.normal(ks[1], (B, Lk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Lk, Hkv, D))
    return q, k, v


@pytest.mark.parametrize("causal,window,bq", [
    (True, None, 16), (True, 8, 32), (False, None, 16), (True, None, 7),
])
def test_fwd_and_grads(causal, window, bq):
    q, k, v = mk(2, 52, 52, 4, 2, 16)
    out = chunked_attention(q, k, v, causal, window, 0, None, bq)
    want = gqa_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    g = jax.grad(lambda *a: chunked_attention(*a, causal, window, 0, None,
                                              bq).sum(), (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: gqa_attention(*a, causal=causal,
                                           window=window).sum(), (0, 1, 2))(
        q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(
    Lq=st.integers(min_value=1, max_value=40),
    H=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2]),
    D=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    bq=st.sampled_from([4, 16, 64]),
    seed=st.integers(min_value=0, max_value=99),
)
def test_property_matches_ref(Lq, H, G, D, causal, bq, seed):
    Hq = H * G
    q, k, v = mk(1, Lq, Lq, Hq, H, D, seed)
    out = chunked_attention(q, k, v, causal, None, 0, None, bq)
    want = gqa_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


def test_decode_matches_full_attention():
    """gqa_decode over a cache == last row of full causal attention."""
    q, k, v = mk(2, 12, 12, 4, 2, 16, seed=3)
    full = gqa_attention(q, k, v, causal=True)
    out = gqa_decode(q[:, -1], k, v, index=11)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_decode_window():
    q, k, v = mk(1, 20, 20, 2, 1, 8, seed=4)
    full = gqa_attention(q, k, v, causal=True, window=5)
    out = gqa_decode(q[:, -1], k, v, index=19, window=5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_bf16_accumulation_stability():
    """bf16 inputs with fp32 accumulation: no NaN, bounded error vs fp32."""
    q, k, v = mk(1, 64, 64, 4, 4, 32, seed=5)
    out16 = chunked_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                              v.astype(jnp.bfloat16), True, None, 0, None, 16)
    out32 = chunked_attention(q, k, v, True, None, 0, None, 16)
    assert not bool(jnp.isnan(out16.astype(jnp.float32)).any())
    err = jnp.max(jnp.abs(out16.astype(jnp.float32) - out32))
    assert float(err) < 0.05
