"""Async double-buffered engine == synchronous engine, token for token.

The PR-9 acceptance suite: AsyncPagedMLAEngine dispatches the fused
decode+sample step for tick N and schedules tick N+1 (admission, block
growth, CoW drain) before the token ids ever reach the host.  Because
the sampling PRNG folds (request id, absolute position) — never batch
composition or wall-clock — the reordering must be invisible in the
tokens:

  * greedy and seeded temperature/top-k parity vs PagedMLAEngine on
    staggered-arrival streams, WITH recompute preemption forced (the
    in-flight token of a preempted victim is folded into its replayed
    prompt — the fix-up path is exercised, not mocked);
  * stop sequences truncate token-identically in both engines, and the
    sync engine's stop output equals its own no-stop output truncated
    at the match (token-exact semantics, not just parity);
  * spec_k > 0 delegates to the synchronous draft/verify tick and stays
    token-identical;
  * the async trace nests cleanly (validate_trace) AND shows the
    overlap that is the point of the refactor: a device_step span on
    the device-stream track wall-overlapping a host schedule span;
  * a `mesh` marked subprocess parity run (forced host device count).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.nn import module as nnm
from repro.obs import Telemetry
from repro.obs.trace import PID_ENGINE, validate_trace
from repro.runtime import (AsyncPagedMLAEngine, PagedMLAEngine, Request,
                           blocks_for)
from repro.runtime.engine import TID_DEVICE
from repro.runtime.spec import parse_draft_spec

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _mkreqs(cfg, specs, *, seed=3, stop=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (p,)).astype(np.int32),
                    max_new=g, arrival=a,
                    stop=[list(map(int, s)) for s in (stop or [])])
            for i, (p, g, a) in enumerate(specs)]


def _run(engine_cls, cfg, params, specs, *, num_blocks=24, stop=None,
         seed=3, scheme="seq", telemetry=None, **kw):
    reqs = _mkreqs(cfg, specs, seed=seed, stop=stop)
    per = max(blocks_for(r.plen + r.max_new + 1, 8) for r in reqs)
    eng = engine_cls(cfg, params, num_blocks=num_blocks, block_size=8,
                     max_batch=2, max_blocks_per_req=per,
                     compute_dtype=jnp.float32, scheme=scheme,
                     prefill_chunk=8, telemetry=telemetry, **kw)
    eng.run(reqs)
    assert len(eng.sched.finished) == len(specs)
    return eng, {r.rid: (tuple(r.output), r.finish_reason)
                 for r in eng.sched.finished}


SPECS = [(12, 9, 0), (9, 7, 0), (17, 8, 1), (8, 10, 2)]
# long generations + tiny pool: forces recompute preemption mid-stream
TIGHT = dict(specs=[(10, 30, 0), (10, 30, 0), (10, 26, 4)], num_blocks=9)


# ------------------------------------------------------------- parity ----


def test_async_greedy_parity(smoke_model):
    cfg, params = smoke_model
    _, sync = _run(PagedMLAEngine, cfg, params, SPECS)
    _, async_ = _run(AsyncPagedMLAEngine, cfg, params, SPECS)
    assert sync == async_


def test_async_seeded_sampling_parity(smoke_model):
    cfg, params = smoke_model
    kw = dict(temperature=0.8, top_k=5, sample_seed=7)
    _, sync = _run(PagedMLAEngine, cfg, params, SPECS, **kw)
    _, async_ = _run(AsyncPagedMLAEngine, cfg, params, SPECS, **kw)
    assert sync == async_


@pytest.mark.parametrize("kw", [
    {},                                             # greedy
    dict(temperature=0.9, top_k=7, sample_seed=5),  # seeded
], ids=["greedy", "seeded"])
def test_async_preemption_parity(smoke_model, kw):
    cfg, params = smoke_model
    es, sync = _run(PagedMLAEngine, cfg, params, **TIGHT, **kw)
    ea, async_ = _run(AsyncPagedMLAEngine, cfg, params, **TIGHT, **kw)
    # the claim is about the REPLAY path: both engines must actually
    # preempt, and the async fix-up (fold the in-flight token into the
    # victim's prompt) must reproduce the sync tokens exactly
    assert es.stats.preemptions > 0
    assert ea.stats.preemptions == es.stats.preemptions
    assert sync == async_


def test_async_spec_decode_parity(smoke_model):
    cfg, params = smoke_model
    dcfg, dparams = parse_draft_spec("self", cfg, params)
    kw = dict(num_blocks=14, spec_k=2, draft_cfg=dcfg, draft_params=dparams)
    es, sync = _run(PagedMLAEngine, cfg, params, SPECS, **kw)
    ea, async_ = _run(AsyncPagedMLAEngine, cfg, params, SPECS, **kw)
    assert es.stats.spec_rounds > 0
    assert sync == async_


# ------------------------------------------------------- stop sequences ----


def _truncate_at(seq, stop):
    """Reference semantics: cut at the FIRST completed stop match."""
    for i in range(len(seq) - len(stop) + 1):
        if list(seq[i:i + len(stop)]) == list(stop):
            return tuple(seq[:i])
    return tuple(seq)


def test_stop_sequences_token_exact(smoke_model):
    cfg, params = smoke_model
    specs = [(12, 8, 0), (9, 8, 1)]
    _, free = _run(PagedMLAEngine, cfg, params, specs)
    stop = [list(free[0][0][2:4])]   # 2-gram from rid 0's own stream
    _, sync = _run(PagedMLAEngine, cfg, params, specs, stop=stop)
    _, async_ = _run(AsyncPagedMLAEngine, cfg, params, specs, stop=stop)
    assert sync == async_
    # token-exact semantics: the stopped output IS the free-running
    # output truncated at the FIRST match, and the match itself is hidden
    assert sync[0][1] == "stop"
    assert sync[0][0] == _truncate_at(free[0][0], stop[0])


def test_stop_sequence_across_spec_rounds(smoke_model):
    cfg, params = smoke_model
    specs = [(12, 10, 0), (9, 8, 1)]
    dcfg, dparams = parse_draft_spec("self", cfg, params)
    kw = dict(num_blocks=20, spec_k=2, draft_cfg=dcfg, draft_params=dparams)
    _, free = _run(PagedMLAEngine, cfg, params, specs, **kw)
    stop = [list(free[0][0][3:5])]
    _, sync = _run(PagedMLAEngine, cfg, params, specs, stop=stop, **kw)
    _, async_ = _run(AsyncPagedMLAEngine, cfg, params, specs, stop=stop, **kw)
    assert sync == async_
    # a spec round may emit several tokens past the match in one tick;
    # everything after the stop must be discarded, match hidden
    assert sync[0][1] == "stop"
    assert sync[0][0] == _truncate_at(free[0][0], stop[0])


@pytest.mark.parametrize("scheme", ["seq", "rc", "ru"])
def test_stop_sequences_across_schemes(smoke_model, scheme):
    cfg, params = smoke_model
    specs = [(12, 8, 0), (9, 8, 1)]
    _, free = _run(PagedMLAEngine, cfg, params, specs, scheme=scheme)
    stop = [list(free[0][0][2:4])]
    _, sync = _run(PagedMLAEngine, cfg, params, specs, scheme=scheme,
                   stop=stop)
    _, async_ = _run(AsyncPagedMLAEngine, cfg, params, specs, scheme=scheme,
                     stop=stop)
    assert sync == async_ and sync[0][1] == "stop"


# --------------------------------------------------------------- trace ----


def test_async_trace_nests_and_overlaps(smoke_model):
    cfg, params = smoke_model
    tel = Telemetry.on(trace=True, metrics=False, drift=False)
    _run(AsyncPagedMLAEngine, cfg, params, SPECS, telemetry=tel)
    trace = tel.tracer.to_dict()
    assert validate_trace(trace) == []
    evs = [e for e in trace["traceEvents"]
           if e["ph"] == "X" and e["pid"] == PID_ENGINE]
    device = [e for e in evs
              if e["tid"] == TID_DEVICE and e["name"] == "device_step"]
    sched = [e for e in evs if e["tid"] == 0 and e["name"] == "schedule"]
    assert device and sched
    # the point of the refactor: device execution overlaps host
    # scheduling in wall time (they live on different tracks, so the
    # nesting validator above cannot be what makes this pass)
    assert any(d["ts"] < s["ts"] + s["dur"] and s["ts"] < d["ts"] + d["dur"]
               for d in device for s in sched)


# ---------------------------------------------------------------- mesh ----


MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro import configs, models
from repro.launch.mesh import make_mesh
from repro.nn import module as nnm
from repro.runtime import (AsyncPagedMLAEngine, PagedMLAEngine, Request,
                           blocks_for)

cfg = configs.smoke("deepseek-v2-236b")
params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                         jnp.float32)
mesh = make_mesh((2, 1), ("data", "model"))

def run(cls, kw):
    rng = np.random.default_rng(3)
    specs = [(12, 9, 0), (9, 7, 0), (17, 8, 1), (8, 10, 2)]
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (p,)).astype(np.int32),
                    max_new=g, arrival=a)
            for i, (p, g, a) in enumerate(specs)]
    per = max(blocks_for(r.plen + r.max_new + 1, 8) for r in reqs)
    eng = cls(cfg, params, num_blocks=24, block_size=8, max_batch=2,
              max_blocks_per_req=per, compute_dtype=jnp.float32,
              scheme="seq", prefill_chunk=8, mesh=mesh, **kw)
    eng.run(reqs)
    return {r.rid: list(map(int, r.output)) for r in eng.sched.finished}

out = {}
for label, kw in (("greedy", {}),
                  ("seeded", dict(temperature=0.8, top_k=5, sample_seed=7))):
    out[label] = (run(PagedMLAEngine, kw), run(AsyncPagedMLAEngine, kw))
print(json.dumps(out))
"""


@pytest.mark.mesh
def test_async_mesh_parity_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)   # the script sets the forced device count
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for label, (sync, async_) in out.items():
        assert sync == async_, f"{label}: mesh async diverged"
        assert len(sync) == 4
