"""Radix prefix cache + chunked batched paged prefill (PR 2).

Covers, bottom-up:
  * BlockAllocator refcounts: fork / release / free semantics;
  * PrefixCache: longest-prefix match with the plen-1 copy-on-write cap,
    insert-after-prefill, LRU eviction of refcount-zero blocks, revival;
  * property test (seeded core + hypothesis wrapper) driving random
    submit/decode/fork/release/evict sequences, asserting no double
    free, refcounts == live block-table references, shared blocks never
    freed while referenced;
  * the full-block-table silent-overwrite fix (scheduler raises before
    update_latent_paged could clamp);
  * chunked paged prefill == contiguous "MHA-mode" prefill numerics, and
    decode-after-shared-chunked-prefill == contiguous decode for ALL
    FOUR execution schemes at ragged lengths (the acceptance criterion);
  * copy-on-write: a shared write-target block is swapped for a device
    copy and decode numerics are unaffected;
  * engine end-to-end: shared-prefix streams hit the cache, prefill
    strictly fewer tokens / allocate strictly fewer blocks than the
    PR-1 runtime, compile one prefill shape, and emit IDENTICAL tokens;
  * temperature / top-k sampling determinism, incl. preemption replay;
  * the hwmodel prefix-hit cost term.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.core import cache as cachelib
from repro.core import mla as mlalib
from repro.core.schemes import prefill_time
from repro.hwmodel import attention_costs as ac
from repro.nn import module as nnm
from repro.runtime import (BlockAllocator, ContinuousScheduler,
                           PagedMLAEngine, PrefixCache, Request,
                           SamplingParams, blocks_for)

MCFG = mlalib.MLAConfig(d_model=64, n_heads=4, q_lora_rank=48,
                        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ----------------------------------------------------- allocator refcounts --


def test_allocator_refcount_semantics():
    a = BlockAllocator(6)
    g = a.alloc(3)
    b0, b1, b2 = g
    a.fork([b0])                                 # rc 2
    with pytest.raises(ValueError):
        a.free([b0])                             # shared: must release
    assert a.release([b0]) == []                 # rc 1
    a.free([b0])                                 # now legal
    with pytest.raises(ValueError):
        a.free([b0])                             # double free
    with pytest.raises(ValueError):
        a.fork([b0])                             # fork of freed block
    with pytest.raises(ValueError):
        a.release([b0])                          # release of freed block
    assert a.release([b1]) == [b1]               # zeroed, NOT freed yet
    with pytest.raises(ValueError):
        a.release([b1])                          # rc already 0
    a.free([b1])
    assert a.num_free == 4 and a.refcount == {b2: 1}
    assert a.total_allocs == 3


# ---------------------------------------------------------- radix matching --


def _cache(num_blocks=10, bs=4, enabled=True, partial=True):
    alloc = BlockAllocator(num_blocks)
    return PrefixCache(alloc, bs, enabled=enabled, partial=partial), alloc


def _release_match(pc, m):
    """Hand back a match the way the scheduler does: full blocks plus the
    forked partial source (whose copy-on-write copy we don't make here)."""
    pc.release(m)
    if m.partial_src is not None:
        pc.release([m.partial_src])


def test_match_longest_prefix_with_cow_cap():
    pc, alloc = _cache(partial=False)            # block-granular (PR-9)
    toks = np.arange(12)                         # 3 full blocks of 4
    blocks = alloc.alloc(3)
    pc.insert(toks, blocks)
    pc.release(blocks)                           # trie keeps them resident
    # identical 12-token prompt: cap at (12-1)//4 = 2 blocks, NOT 3 —
    # the last block is recomputed privately so prefill emits the
    # last-position logits (the copy-on-write boundary)
    assert pc.match(toks) == blocks[:2]
    pc.release(blocks[:2])
    # longer prompt with the same start matches all 3 full blocks
    assert pc.match(np.arange(14)) == blocks
    pc.release(blocks)
    # divergence inside block 2 stops the walk after block 1
    div = np.concatenate([np.arange(6), [99], np.arange(7, 14)])
    assert pc.match(div) == blocks[:1]
    pc.release(blocks[:1])
    # prompts shorter than one full block never match
    assert pc.match(np.arange(4)) == []
    assert pc.stats.hit_tokens == (2 + 3 + 1) * 4
    assert pc.stats.partial_hits == 0


def test_match_token_granular_partial():
    """partial=True extends each hit mid-block: the cached block whose
    content continues the prefix is forked as ``partial_src`` and the
    caller materializes it copy-on-write."""
    pc, alloc = _cache()
    toks = np.arange(12)
    blocks = alloc.alloc(3)
    pc.insert(toks, blocks)
    pc.release(blocks)
    # identical prompt: 2 full blocks + 3 tokens into block 3 (the cap
    # still reserves the LAST prompt token for prefill)
    m = pc.match(toks)
    assert m == blocks[:2]
    assert m.partial_src == blocks[2] and m.partial_len == 3
    assert m.n_tokens(4) == 11
    assert alloc.refcount[blocks[2]] == 1        # forked for the caller
    _release_match(pc, m)
    # lookup_len sees the same count without forking anything
    assert pc.lookup_len(toks) == 11
    assert all(alloc.refcount[b] == 0 for b in blocks)
    # divergence inside block 2: full hit on block 1 + 2-token partial
    div = np.concatenate([np.arange(6), [99], np.arange(7, 14)])
    m = pc.match(div)
    assert m == blocks[:1]
    assert m.partial_src == blocks[1] and m.partial_len == 2
    _release_match(pc, m)
    # prompts shorter than one full block can now hit mid-block
    m = pc.match(np.arange(4))
    assert m == [] and m.partial_src == blocks[0] and m.partial_len == 3
    _release_match(pc, m)
    # a cancelled partial match backs its stats and fork out
    before = dataclasses.replace(pc.stats)
    m = pc.match(np.arange(7))
    pc.cancel_match(np.arange(7), m)
    assert pc.stats == before
    assert all(alloc.refcount[b] == 0 for b in blocks)
    assert pc.stats.partial_hits == 3
    assert pc.stats.partial_hit_tokens == 3 + 2 + 3
    assert pc.stats.hit_tokens == (8 + 3) + (4 + 2) + 3


def test_disabled_cache_is_passthrough():
    pc, alloc = _cache(enabled=False)
    blocks = pc.alloc(2)
    assert pc.insert(np.arange(8), blocks) == 0
    assert pc.match(np.arange(8)) == []
    pc.release(blocks)                           # straight back to the pool
    assert alloc.num_free == 9 and pc.num_cached == 0


def test_lru_eviction_and_revival():
    pc, alloc = _cache(num_blocks=8, bs=2)       # 7 usable
    a = pc.alloc(2)
    pc.insert([1, 2, 3, 4], a)
    b = pc.alloc(2)
    pc.insert([5, 6, 7, 8], b)
    pc.release(a)
    pc.release(b)                                # both cached, rc 0
    assert pc.num_evictable == 4 and alloc.num_free == 3
    # touch chain a to make it most-recently-used
    got = pc.match([1, 2, 3, 4, 9])              # forks both a-blocks
    assert got == a
    pc.release(a)
    # allocating 5 blocks: 3 free + 2 evicted; chain b (LRU) must go first
    fresh = pc.alloc(5)
    assert fresh is not None and len(fresh) == 5
    assert pc.stats.evictions == 2
    assert pc.match([5, 6, 7, 8, 9]) == []       # b evicted...
    assert pc.match([1, 2, 3, 4, 9]) == a        # ...a survived
    pc.release(a)
    # leaf-first: a's deeper block must evict before its parent
    pc.evict(1)
    assert pc.match([1, 2, 3, 4, 9]) == a[:1]
    pc.release(a[:1])


def test_refused_admission_does_not_inflate_hit_rate():
    """A pool-pressured queue head is matched then refused every tick;
    cancel_match must back the stats out so hit rate counts only tokens
    actually served (review finding on PR 2)."""
    s = ContinuousScheduler(num_blocks=7, block_size=2, max_batch=2)
    s.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=4))
    (slot, _), = s.try_admit()
    s.commit_prefill(slot)
    s.record_prefill_sample(slot, 1)
    hit0 = s.prefix.stats.hit_tokens
    # same prompt, but only 1 free block left: matched, then refused
    s.submit(Request(rid=1, prompt=np.arange(8, dtype=np.int32), max_new=4))
    for _ in range(3):
        assert s.try_admit() == []
    assert s.prefix.stats.hit_tokens == hit0
    assert s.prefix.stats.lookup_tokens == 8      # request 0's offer only
    # every forked block was handed back on refusal
    assert all(s.allocator.refcount[b] == 1 for b in s.blocks_of[slot])


def test_insert_keeps_existing_mapping():
    pc, alloc = _cache(bs=2)
    a = pc.alloc(1)
    pc.insert([7, 8], a)
    dup = pc.alloc(1)                            # same content, other block
    assert pc.insert([7, 8], dup) == 0           # path exists: not replaced
    assert pc.match([7, 8, 9]) == a
    pc.release(a + dup)
    assert alloc.refcount[a[0]] == 1             # still held by the match
    assert dup[0] not in alloc.refcount          # duplicate went free


# ------------------------------------------------------------ property test -


def _drive_scheduler(seed: int, n_ops: int = 120) -> None:
    """Random submit/decode/fork/release/evict/cancel traffic against
    the real scheduler (allocator + prefix cache), with invariants
    checked after every op:  refcount(b) == #live block-table references
    to b, the free list never intersects live tables or the trie, and
    shared blocks are never freed while referenced (free() raising on
    rc > 1 is exercised explicitly).  Submits include n-way
    parallel-sampling groups (admit -> commit -> fork_group in one op,
    the engine's tick order), so CoW forks, group cancellation and
    decode-block trie registration all run under the same invariants."""
    rng = np.random.default_rng(seed)
    s = ContinuousScheduler(num_blocks=int(rng.integers(6, 16)),
                            block_size=int(rng.integers(2, 5)),
                            max_batch=int(rng.integers(1, 4)))
    pool_tokens = (s.allocator.num_blocks - 1) * s.block_size
    rid = 0

    def live_refs():
        refs = {}
        for blocks in s.blocks_of.values():
            for b in blocks:
                refs[b] = refs.get(b, 0) + 1
        return refs

    def check():
        refs = live_refs()
        rc = s.allocator.refcount
        for b, n in refs.items():
            assert rc.get(b, 0) == n, (b, rc.get(b, 0), n)
        for b, c in rc.items():
            assert c == refs.get(b, 0), (b, c, refs.get(b, 0))
            if c == 0:
                assert b in s.prefix._evictable
        free = set(s.allocator._free)
        assert not free & set(refs)
        assert not free & set(s.prefix._node_of)
        assert not free & set(rc)
        # a shared block can never be hard-freed
        for b, c in rc.items():
            if c > 1:
                with pytest.raises(ValueError):
                    s.allocator.free([b])

    for _ in range(n_ops):
        op = rng.integers(0, 6)
        if op == 0 and len(s.waiting) < 4:           # submit
            # small alphabet + common preamble -> real prefix collisions
            plen = int(rng.integers(1, max(pool_tokens // 2, 2)))
            prompt = np.concatenate([
                np.zeros(min(plen, 4), np.int32),
                rng.integers(0, 3, max(plen - 4, 0)).astype(np.int32)])
            gen = int(rng.integers(1, 6))
            # sometimes an n-way parallel-sampling group — only when the
            # worst-case group demand fits the pool (try_admit fails
            # fast, by design, on can-never-fit groups)
            n = int(rng.integers(2, 4)) if rng.integers(0, 3) == 0 else 1
            if (n > s.max_batch or n * blocks_for(plen + gen + 1,
                                                  s.block_size)
                    > s.allocator.num_blocks - 1):
                n = 1
            s.submit(Request(rid=rid, prompt=prompt,
                             sampling=SamplingParams(max_tokens=gen, n=n)))
            rid += n
        elif op == 1:                                # admit + commit + fork
            for slot, _ in s.try_admit():
                s.commit_prefill(slot)
                s.fork_group(slot)
        elif op == 2 and s.active_slots:             # one decode tick
            s.ensure_step_capacity()
            s.drain_cow()
            s.advance({sl: int(rng.integers(0, 3))
                       for sl in s.active_slots})
        elif op == 3:                                # LRU eviction pressure
            s.prefix.evict(int(rng.integers(1, 3)))
        elif op == 4 and s.active_slots:             # external fork/release
            # (refcount transiently exceeds table refs between the two
            # calls — invariants are only claimed at op boundaries)
            slot = int(rng.choice(s.active_slots))
            blk = s.blocks_of[slot][0]
            s.allocator.fork([blk])
            s.prefix.release([blk])
        elif op == 5:                                # cancel anywhere
            rids = [r.rid for r in s.waiting]
            rids += [c.rid for r in s.waiting if not r.forked
                     for c in r.fork_children]
            rids += [s.slots[sl].rid for sl in s.active_slots]
            if rids:
                s.cancel(int(rng.choice(rids)))
        check()


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7, 11, 23, 42])
def test_scheduler_refcount_invariants_seeded(seed):
    _drive_scheduler(seed)


def test_scheduler_refcount_invariants_hypothesis():
    pytest.importorskip(
        "hypothesis", reason="optional dev dep: property-based sweeps")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def run(seed):
        _drive_scheduler(seed, n_ops=60)

    run()


# ------------------------------------------------ full-table overwrite fix --


def test_full_block_table_raises_not_clamps():
    # 2 blocks x 2 tokens per request: a 3-token prompt + 2 generated
    # tokens would need a 3rd block -> the PR-1 runtime would let
    # update_latent_paged clamp the page index onto block 1 and silently
    # overwrite it; the scheduler must refuse on the host instead.
    s = ContinuousScheduler(num_blocks=12, block_size=2, max_batch=1,
                            max_blocks_per_req=2)
    s.submit(Request(rid=0, prompt=np.arange(3, dtype=np.int32), max_new=8))
    (slot, _), = s.try_admit()
    s.record_prefill_sample(slot, 1)
    s.ensure_step_capacity()                     # lengths 3 (+1) fits: ok
    s.advance({slot: 1})                         # lengths -> 4 == capacity
    with pytest.raises(RuntimeError, match="block table full"):
        s.ensure_step_capacity()


# ----------------------------------------- chunked prefill / CoW numerics --


def _paged_setup(lengths, shared_tok, bs, nb, N):
    """Block tables where every request's leading ``shared_tok`` tokens
    map to the SAME pool blocks (the radix-cache layout)."""
    B = len(lengths)
    n_sh = shared_tok // bs
    rng = np.random.default_rng(0)
    ids = list(rng.permutation(np.arange(1, N)))
    shared = [ids.pop() for _ in range(n_sh)]
    bt = np.zeros((B, nb), np.int32)
    for b in range(B):
        nfull = blocks_for(int(lengths[b]) + 1, bs)
        bt[b, :n_sh] = shared
        for j in range(n_sh, nfull):
            bt[b, j] = ids.pop()
    return jnp.asarray(bt)


def _chunked_shared_prefill(chunk):
    """Fill a paged pool via chunked prefill with the leading blocks of
    every request SHARED (the radix-cache layout); returns everything
    needed to compare against the contiguous oracle."""
    bs, nb, N = 4, 8, 40
    lengths = np.asarray([13, 21, 9, 24], np.int32)   # ragged
    shared_tok = 8                                    # 2 shared blocks
    B, S = len(lengths), nb * bs
    params = nnm.init_params(jax.random.PRNGKey(0), mlalib.mla_defs(MCFG),
                             jnp.float32)
    rng = np.random.default_rng(1)
    common = rng.standard_normal((shared_tok, MCFG.d_model)) * 0.1
    xs = [np.concatenate([common,
                          rng.standard_normal((int(L) - shared_tok,
                                               MCFG.d_model)) * 0.1])
          for L in lengths]
    bt = _paged_setup(lengths, shared_tok, bs, nb, N)
    pool = cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                       MCFG.qk_rope_dim, jnp.float32)
    # contiguous oracle per request
    want_out, want_entries = [], []
    for b in range(B):
        x = jnp.asarray(xs[b], jnp.float32)[None]
        pos = jnp.arange(int(lengths[b]))[None]
        o, e = mlalib.mla_prefill(params, MCFG, x, pos)
        want_out.append(np.asarray(o[0]))
        want_entries.append(e)
    # paged: request 0 prefills its WHOLE prompt (it "populates" the
    # shared blocks); the others start after the 8 shared tokens.
    got_out = [np.zeros((int(L), MCFG.d_model), np.float32)
               for L in lengths]
    for b in range(B):
        start = 0 if b == 0 else shared_tok
        while start < int(lengths[b]):
            take = min(chunk, int(lengths[b]) - start)
            xc = np.zeros((B, chunk, MCFG.d_model), np.float32)
            xc[b, :take] = xs[b][start:start + take]
            lens = np.zeros((B,), np.int32)
            lens[b] = start
            nv = np.zeros((B,), np.int32)
            nv[b] = take
            o, pool = mlalib.mla_prefill_chunk_paged(
                params, MCFG, jnp.asarray(xc), pool, bt,
                jnp.asarray(lens), jnp.asarray(nv))
            got_out[b][start:start + take] = np.asarray(o[b, :take])
            start += take
    return (params, pool, bt, lengths, xs, got_out, want_out,
            want_entries, shared_tok)


@pytest.mark.parametrize("chunk", [3, 8])
def test_chunked_prefill_matches_contiguous(chunk):
    """Chunk-by-chunk paged prefill reproduces the contiguous MHA-mode
    prefill: same per-position outputs, same cached latents — including
    requests whose leading blocks are SHARED and therefore skipped."""
    (params, pool, bt, lengths, xs, got_out, want_out, want_entries,
     shared_tok) = _chunked_shared_prefill(chunk)
    for b in range(len(lengths)):
        lo = 0 if b == 0 else shared_tok
        np.testing.assert_allclose(got_out[b][lo:], want_out[b][lo:],
                                   atol=5e-5, rtol=5e-5)
        ckv_c, krope_c = cachelib.gather_latent_paged(pool, bt[b:b + 1])
        L = int(lengths[b])
        np.testing.assert_allclose(np.asarray(ckv_c[0, :L]),
                                   np.asarray(want_entries[b]["ckv"][0]),
                                   atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(np.asarray(krope_c[0, :L]),
                                   np.asarray(want_entries[b]["krope"][0]),
                                   atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("scheme", mlalib.SCHEMES)
def test_decode_after_shared_chunked_prefill(scheme):
    """THE acceptance criterion: decode over a pool filled by chunked
    prefill WITH block sharing is allclose to the contiguous non-shared
    path, for all four execution schemes, at ragged lengths."""
    params, pool, bt, lengths, xs = _chunked_shared_prefill(5)[:5]
    params = mlalib.prepare_serving(params, MCFG, "ru")
    B = len(lengths)
    S = bt.shape[1] * 4
    x_t = rand(jax.random.PRNGKey(9), (B, MCFG.d_model)) * 0.1
    want = []
    for b in range(B):
        c = cachelib.latent_cache(1, S, MCFG.kv_lora_rank, MCFG.qk_rope_dim,
                                  jnp.float32)
        pos = jnp.arange(int(lengths[b]))[None]
        _, e = mlalib.mla_prefill(params, MCFG,
                                  jnp.asarray(xs[b], jnp.float32)[None], pos)
        c = cachelib.update_latent(c, e["ckv"], e["krope"], 0)
        o, _ = mlalib.mla_decode(params, MCFG, x_t[b:b + 1], c,
                                 int(lengths[b]), scheme=scheme)
        want.append(np.asarray(o[0]))
    got, _ = mlalib.mla_decode_paged(params, MCFG, x_t, pool, bt,
                                     jnp.asarray(lengths), scheme=scheme)
    np.testing.assert_allclose(np.asarray(got), np.stack(want),
                               atol=5e-5, rtol=5e-5)


def test_copy_block_paged_and_cow():
    # device copy correctness
    pool = cachelib.paged_latent_cache(6, 4, 8, 4, jnp.float32)
    pool = {k: v.at[2].set(7.0) for k, v in pool.items()}
    pool = cachelib.copy_block_paged(pool, 2, 5)
    np.testing.assert_allclose(np.asarray(pool["ckv"][5]), 7.0)
    # scheduler swaps a SHARED write-target for a private copy
    s = ContinuousScheduler(num_blocks=12, block_size=4, max_batch=1)
    s.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_new=9))
    (slot, _), = s.try_admit()
    s.commit_prefill(slot)
    s.record_prefill_sample(slot, 1)
    wtarget = s.blocks_of[slot][1]          # partial block: write target
    s.allocator.fork([wtarget])             # simulate an external holder
    s.ensure_step_capacity()
    copies = s.drain_cow()
    assert len(copies) == 1 and copies[0][0] == wtarget
    assert s.blocks_of[slot][1] == copies[0][1] != wtarget
    assert s.block_table[slot, 1] == copies[0][1]
    assert s.allocator.refcount[wtarget] == 1       # our ref released
    assert s.prefix.stats.cow_copies == 1
    s.prefix.release([wtarget])             # external holder lets go
    # next tick: nothing left to break
    s.ensure_step_capacity()
    assert s.drain_cow() == []


# --------------------------------------------------------- engine e2e -------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _shared_prefix_requests(cfg, rng, n=4, pre=12):
    preamble = rng.integers(0, cfg.vocab, (pre,)).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab,
                            (int(rng.choice([5, 9, 14])),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([preamble, tail]),
                            max_new=int(rng.integers(2, 6)), arrival=2 * i))
    return reqs


def _contiguous_greedy(cfg, params, prompt, max_new):
    from repro.launch.serve import _prepare_mla
    from repro.runtime import make_prefill_step, make_serve_step
    params = _prepare_mla(params, cfg, "seq")
    capacity = len(prompt) + max_new + 1
    prefill = make_prefill_step(cfg, None, batch=1, capacity=capacity,
                                compute_dtype=jnp.float32, scheme="seq")
    step = make_serve_step(cfg, None, compute_dtype=jnp.float32,
                           scheme="seq")
    logits, cache = prefill(params, jnp.asarray(prompt, jnp.int32)[None])
    out = [int(jnp.argmax(logits[0]))]
    for i in range(max_new - 1):
        logits, cache = step(params, jnp.asarray(out[-1:], jnp.int32),
                             cache, len(prompt) + i)
        out.append(int(jnp.argmax(logits[0])))
    return out


def _run_engine(cfg, params, reqs, **kw):
    eng = PagedMLAEngine(cfg, params, num_blocks=40, block_size=4,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="seq", **kw)
    eng.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                     arrival=r.arrival) for r in reqs])
    return eng


def test_engine_shared_prefix_beats_pr1(smoke_model):
    """Acceptance: on a shared-prefix stream the prefix runtime reports a
    hit rate > 0, strictly fewer prefilled tokens and allocated blocks
    than PR-1's runtime, a SINGLE prefill compilation, and the exact
    greedy tokens of the contiguous path."""
    cfg, params = smoke_model
    reqs = _shared_prefix_requests(cfg, np.random.default_rng(5))
    new = _run_engine(cfg, params, reqs, prefill_chunk=6)
    old = _run_engine(cfg, params, reqs, enable_prefix_cache=False,
                      prefill_mode="per_request")
    sn, so = new.summary(), old.summary()
    assert sn["prefix_hit_rate"] > 0
    assert sn["prefill_tokens"] < so["prefill_tokens"]
    assert sn["total_blocks_allocated"] < so["total_blocks_allocated"]
    assert sn["prefill_compiles"] == 1          # one chunk size, 4 plens
    assert so["prefill_compiles"] > 1           # PR-1: per-plen buckets
    outs_new = {r.rid: r.output for r in new.sched.finished}
    outs_old = {r.rid: r.output for r in old.sched.finished}
    assert outs_new == outs_old
    for r in reqs:                               # and both match contiguous
        want = _contiguous_greedy(cfg, params, r.prompt, r.max_new)
        assert outs_new[r.rid] == want, f"request {r.rid}"


def test_engine_prefix_reuse_after_release(smoke_model):
    """Blocks released at finish stay LRU-evictable and are re-hit by a
    later identical prompt (no re-prefill of the shared blocks).  With
    token-granular matching + decode-block registration the second
    request also partial-hits the block request 0's decode completed:
    11 prompt tokens -> 2 full blocks (8) + 2 tokens into block 3 (whose
    content is prompt[8:11] + request 0's first generated token), so
    only ONE prompt token re-prefills."""
    cfg, params = smoke_model
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, (11,)).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new=3, arrival=6 * i)
            for i in range(2)]                  # strictly sequential
    eng = _run_engine(cfg, params, reqs, prefill_chunk=4)
    s = eng.summary()
    assert s["prefix_hit_tokens"] == 8 + 2      # 2 full blocks + partial
    assert s["prefix_partial_hits"] == 1
    assert s["prefix_decode_inserted_blocks"] == 1
    assert s["prefill_tokens"] == 11 + 1
    outs = {r.rid: r.output for r in eng.sched.finished}
    assert outs[0] == outs[1]
    # the PR-9 configuration is still reachable for A/B runs
    old = _run_engine(cfg, params, reqs, prefill_chunk=4,
                      partial_match=False, decode_block_reuse=False,
                      admission="fcfs")
    so = old.summary()
    assert so["prefix_hit_tokens"] == 8
    assert so["prefill_tokens"] == 11 + 3
    assert {r.rid: r.output for r in old.sched.finished} == outs


# ---------------------------------------------------------------- sampling --


def test_sampling_determinism_and_topk1(smoke_model):
    cfg, params = smoke_model
    rng = np.random.default_rng(11)
    reqs = _shared_prefix_requests(cfg, rng, n=3, pre=8)
    a = _run_engine(cfg, params, reqs, temperature=0.8, top_k=5,
                    sample_seed=3)
    b = _run_engine(cfg, params, reqs, temperature=0.8, top_k=5,
                    sample_seed=3)
    outs_a = {r.rid: r.output for r in a.sched.finished}
    outs_b = {r.rid: r.output for r in b.sched.finished}
    assert outs_a == outs_b                     # same seed -> same stream
    c = _run_engine(cfg, params, reqs, temperature=0.8, top_k=5,
                    sample_seed=4)
    outs_c = {r.rid: r.output for r in c.sched.finished}
    assert outs_c != outs_a                     # seed actually matters
    # top_k=1 collapses to greedy argmax regardless of temperature
    g = _run_engine(cfg, params, reqs)
    k1 = _run_engine(cfg, params, reqs, temperature=2.5, top_k=1)
    assert {r.rid: r.output for r in g.sched.finished} == \
        {r.rid: r.output for r in k1.sched.finished}


def test_sampling_survives_preemption_replay(smoke_model):
    """Recompute preemption must not change sampled outputs: the PRNG key
    folds the ABSOLUTE token position, and replayed tokens ride in the
    folded prompt."""
    cfg, params = smoke_model
    rng = np.random.default_rng(13)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new=10, arrival=0) for i in range(2)]
    kw = dict(block_size=4, max_batch=2, compute_dtype=jnp.float32,
              scheme="seq", temperature=0.7, top_k=8, sample_seed=1,
              prefill_chunk=4)
    big = PagedMLAEngine(cfg, params, num_blocks=40, **kw)
    big.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new)
             for r in reqs])
    # 6 usable blocks of 4 tokens cannot hold 2 x (6 prompt + 10 gen):
    # the youngest request must be preempted and replayed
    small = PagedMLAEngine(cfg, params, num_blocks=7, **kw)
    small.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new)
               for r in reqs])
    assert small.stats.preemptions > 0
    assert {r.rid: r.output for r in small.sched.finished} == \
        {r.rid: r.output for r in big.sched.finished}


# ----------------------------------------------------------------- hwmodel --


def test_prefill_cost_prefix_term():
    kw = dict(seq_len=512, batch=2)
    base = ac.mla_prefill_cost(ac.DSV3_MLA, **kw)
    hit = ac.mla_prefill_cost(ac.DSV3_MLA, cached_prefix=256, **kw)
    assert hit.flops < base.flops and hit.bytes < base.bytes
    assert "B:prefix_read" in hit.breakdown
    # suffix projections scale linearly, score pairs quadratically
    assert hit.breakdown["q_down"] == base.breakdown["q_down"] / 2
    assert hit.breakdown["attn_scores"] == pytest.approx(
        base.breakdown["attn_scores"] * (512**2 - 256**2) / 512**2)
    # savings monotone in the cached prefix
    s1 = ac.prefix_hit_savings(ac.DSV3_MLA, seq_len=512, cached_prefix=128)
    s2 = ac.prefix_hit_savings(ac.DSV3_MLA, seq_len=512, cached_prefix=384)
    assert 0 < s1["flops_saved"] < s2["flops_saved"]
    assert 0 < s1["bytes_saved"] < s2["bytes_saved"]
    with pytest.raises(ValueError):
        ac.mla_prefill_cost(ac.DSV3_MLA, seq_len=512, cached_prefix=512)


def test_prefill_time_reflects_hits():
    from repro.hwmodel.platforms import PLATFORMS
    plat = PLATFORMS["tpu_v5e"]
    t0 = prefill_time(ac.DSV3_MLA, plat, 2048)
    t1 = prefill_time(ac.DSV3_MLA, plat, 2048, cached_prefix=1024)
    assert t1 < t0                               # TTFT drops with hits
