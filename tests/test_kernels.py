"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes, dtypes, GQA group counts and window sizes per the kernel
contract; asserts allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mla_decode import mla_decode_kernel

pytestmark = pytest.mark.kernel

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,H,Hkv,Lq,Lk,D,Dv", [
    (1, 4, 4, 64, 64, 32, 32),      # MHA square
    (2, 4, 2, 48, 48, 16, 16),      # GQA 2:1
    (1, 8, 1, 33, 70, 16, 24),      # MQA, ragged, Dv != Dqk
    (2, 6, 3, 128, 128, 64, 64),    # larger, MXU-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_shapes(B, H, Hkv, Lq, Lk, D, Dv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, H, Lq, D), dtype)
    k = rand(ks[1], (B, Hkv, Lk, D), dtype)
    v = rand(ks[2], (B, Hkv, Lk, Dv), dtype)
    out = flash_attention(q, k, v, True, None, 0, None, 32, 32, True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [None, 8, 32])
def test_flash_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 4, 96, 32), jnp.float32)
    k = rand(ks[1], (1, 2, 96, 32), jnp.float32)
    v = rand(ks[2], (1, 2, 96, 32), jnp.float32)
    out = flash_attention(q, k, v, True, window, 0, None, 32, 32, True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_q_offset_chunked_prefill():
    """Chunked prefill: q block at absolute offset must equal full run."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (1, 2, 64, 16), jnp.float32)
    k = rand(ks[1], (1, 2, 64, 16), jnp.float32)
    v = rand(ks[2], (1, 2, 64, 16), jnp.float32)
    full = flash_attention(q, k, v, True, None, 0, None, 16, 16, True)
    part = flash_attention(q[:, :, 32:], k, v, True, None, 32, None, 16, 16, True)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, :, 32:]),
                               atol=2e-5)


def test_flash_backward():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (2, 4, 48, 32), jnp.float32)
    k = rand(ks[1], (2, 2, 48, 32), jnp.float32)
    v = rand(ks[2], (2, 2, 48, 32), jnp.float32)

    def loss_kernel(q, k, v):
        return (flash_attention(q, k, v, True, None, 0, None, 16, 16, True)
                ** 2).sum()

    def loss_ref(q, k, v):
        return (ref.flash_attention_ref(q, k, v, causal=True) ** 2).sum()

    g = jax.grad(loss_kernel, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("B,H,S,Dl,Dr,index,block", [
    (1, 4, 64, 32, 8, 0, 32),       # first token
    (2, 8, 100, 32, 8, 57, 32),     # mid-cache, ragged S
    (1, 16, 256, 64, 16, 255, 64),  # full cache
    (2, 128, 128, 512, 64, 100, 64),  # deepseek-v2 head/latent dims
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_decode_kernel(B, H, S, Dl, Dr, index, block, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (B, H, Dl + Dr), dtype)
    ckv = rand(ks[1], (B, S, Dl), dtype)
    krope = rand(ks[2], (B, S, Dr), dtype)
    out = mla_decode_kernel(q, ckv, krope, index, block_k=block,
                            interpret=True)
    want = ref.mla_decode_ref(q, ckv, krope, index)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_mla_decode_kernel_masks_beyond_index():
    """Entries past ``index`` must not influence the result."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = rand(ks[0], (1, 4, 40), jnp.float32)
    ckv = rand(ks[1], (1, 64, 32), jnp.float32)
    krope = rand(ks[2], (1, 64, 8), jnp.float32)
    out = mla_decode_kernel(q, ckv, krope, 19, block_k=16, interpret=True)
    out_p = mla_decode_kernel(q, ckv.at[:, 20:].set(1e4),
                              krope.at[:, 20:].set(1e4), 19, block_k=16,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p), atol=1e-6)
