"""Serving telemetry subsystem (PR 7): tracer, metrics, drift, logger.

Covers, bottom-up:
  * percentile / Histogram math against numpy's linear interpolation;
  * Tracer span nesting + `validate_trace` on good and broken traces,
    and the NullTracer contract (no events, export refuses);
  * disabled-mode overhead: a null span must cost well under the
    per-step budget that makes armed-off telemetry free;
  * RooflineDrift: unbound recorder is a no-op, predictions match
    `core.schemes.step_time` exactly (the drift channel may never
    disagree with the dispatcher), coverage checking;
  * engine end-to-end with telemetry armed: trace validates, every
    request-lifecycle phase and step phase has a span, metrics mirror
    `engine.summary()` exactly, TTFT/TPOT histograms cover the finished
    requests, drift covers the dispatched schemes — and outputs are
    TOKEN-IDENTICAL to an untraced run;
  * the step wall-clock fix (satellite): the engine must block on device
    work inside the step timer — jax dispatch is async, so without the
    sync `wall` measures dispatch, not compute;
  * StructLogger text/JSON/level modes + the `as_logger` adapter;
  * prefix-cache eviction / copy-on-write instants and counters.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.core.schemes import step_time
from repro.hwmodel.platforms import PLATFORMS
from repro.nn import module as nnm
from repro.obs import (NULL_TRACER, OFF_TELEMETRY, PID_ENGINE, PID_REQUESTS,
                       Histogram, RooflineDrift, StructLogger, Telemetry,
                       Tracer, as_logger, percentile, validate_trace)
from repro.runtime import (BlockAllocator, PagedMLAEngine, PrefixCache,
                           Request)


# -------------------------------------------------------- percentile math --


def test_percentile_linear_interpolation():
    assert percentile([], 50) == 0.0
    assert percentile([7.0], 99) == 7.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 0) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0
    rng = np.random.default_rng(0)
    vals = sorted(rng.normal(size=137).tolist())
    for p in (5, 25, 50, 75, 95, 99):
        assert percentile(vals, p) == pytest.approx(np.percentile(vals, p))


def test_histogram_summary_matches_numpy():
    h = Histogram()
    assert h.summary() == {"count": 0}
    rng = np.random.default_rng(1)
    vals = rng.exponential(size=200).tolist()
    for v in vals:
        h.record(v)
    s = h.summary()
    assert s["count"] == 200
    assert s["mean"] == pytest.approx(np.mean(vals))
    assert s["min"] == min(vals) and s["max"] == max(vals)
    for key, p in (("p50", 50), ("p95", 95), ("p99", 99)):
        assert s[key] == pytest.approx(np.percentile(vals, p))


# ------------------------------------------------------------------ tracer --


def test_tracer_spans_nest_and_validate():
    tr = Tracer()
    tr.set_process_name(PID_ENGINE, "engine")
    with tr.span("step"):
        with tr.span("schedule"):
            pass
        with tr.span("device_step"):
            pass
    tr.instant("evict", args={"n": 2})
    trace = tr.to_dict()
    assert validate_trace(trace) == []
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    # children close before the parent -> parent is appended LAST
    assert names == ["schedule", "device_step", "step"]
    step = [e for e in trace["traceEvents"] if e["name"] == "step"][0]
    kids = [e for e in trace["traceEvents"]
            if e["name"] in ("schedule", "device_step")]
    assert all(e["ts"] >= step["ts"] and
               e["ts"] + e["dur"] <= step["ts"] + step["dur"] + 1e-3
               for e in kids)


def test_validate_trace_rejects_malformed():
    assert validate_trace({}) != []
    assert validate_trace({"traceEvents": [{"ph": "X"}]}) != []
    overlapping = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"name": "b", "ph": "X", "pid": 1, "tid": 0, "ts": 5.0, "dur": 10.0},
    ]}
    assert any("overlaps" in p for p in validate_trace(overlapping))
    neg = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": -1.0}]}
    assert validate_trace(neg) != []


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("step") as sp:
        pass
    assert sp.dur_s == 0.0
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("y", 1, 0, 0.0, 1.0)
    assert NULL_TRACER.to_dict() == {"traceEvents": []}
    with pytest.raises(RuntimeError):
        NULL_TRACER.export("/tmp/never.json")


def test_null_span_overhead_is_negligible():
    """Armed-off telemetry must be free: a generous 20 us/hook bound
    (measured ~0.2 us) keeps the <2%-of-a-step acceptance criterion safe
    by orders of magnitude even on a loaded CI box."""
    import time
    n = 50_000
    span = OFF_TELEMETRY.tracer.span
    t0 = time.perf_counter()
    for _ in range(n):
        with span("step"):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 20e-6, f"{per_call * 1e6:.2f} us per null span"


def test_telemetry_off_singleton():
    assert Telemetry.off() is OFF_TELEMETRY
    assert not OFF_TELEMETRY.enabled
    assert OFF_TELEMETRY.metrics is None and OFF_TELEMETRY.drift is None


# ------------------------------------------------------------------- drift --


def test_drift_unbound_is_noop_and_bound_matches_dispatcher():
    d = RooflineDrift()
    assert not d.active
    d.record_decode("seq", 2, 64, 0.01)
    assert d.rows == []

    mla = configs.smoke("deepseek-v2-236b").mla_config()
    plat = PLATFORMS["tpu_v5e"]
    d.bind(mla=mla, platform=plat, paged_block=8)
    d.record_decode("seq", 2, 64, 0.01)
    row = d.rows[0]
    # the drift channel consults the EXACT function the dispatcher does
    assert row.pred_time_s == step_time("seq", mla, plat, cache_len=64,
                                        batch=2, paged_block=8)
    assert row.pred_bytes > 0
    assert row.ratio == pytest.approx(0.01 / row.pred_time_s)
    rep = d.report()
    assert rep["rows"] == 1
    assert rep["kinds"]["decode"]["schemes"] == ["seq"]
    assert "decode/seq/b2" in rep["buckets"]
    assert d.check_coverage({"seq": 3}) == []
    assert d.check_coverage({"rc": 1}) == \
        ["scheme 'rc' dispatched but has no drift row"]


# ---------------------------------------------------- engine end-to-end ----


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _reqs(cfg, seed=3, n=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new=4, arrival=i % 2) for i in range(n)]


def _run(cfg, params, reqs, telemetry=None):
    eng = PagedMLAEngine(cfg, params, num_blocks=24, block_size=4,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="auto", platform=PLATFORMS["tpu_v5e"],
                         prefill_chunk=4, telemetry=telemetry)
    eng.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                     arrival=r.arrival) for r in reqs])
    return eng


def test_engine_telemetry_end_to_end(smoke_model):
    cfg, params = smoke_model
    reqs = _reqs(cfg)
    tel = Telemetry.on()
    eng = _run(cfg, params, reqs, telemetry=tel)
    tel.finalize(eng)

    trace = tel.tracer.to_dict()
    assert validate_trace(trace) == []

    def names(pid):
        return {e["name"] for e in trace["traceEvents"]
                if e.get("pid") == pid and e["ph"] in ("X", "i")}

    # every lifecycle phase and every (non-spec) step phase has a span
    assert {"arrival", "queued", "prefill", "decode",
            "finish"} <= names(PID_REQUESTS)
    assert {"step", "schedule", "prefill", "prefill_chunk", "device_step",
            "host_sample"} <= names(PID_ENGINE)

    # metrics mirror EngineStats EXACTLY (the registry subsumes it)
    summ = eng.summary()
    m = tel.metrics
    assert m.engine_summary == summ
    assert m.counter("engine.steps").value == summ["steps"]
    assert m.counter("engine.decode_tokens").value == summ["decode_tokens"]
    assert m.gauge("engine.tokens_per_s").value == \
        pytest.approx(summ["tokens_per_s"])
    n_fin = len(eng.sched.finished)
    assert m.histogram("ttft_ms").count == n_fin
    assert m.histogram("queue_delay_ms").count == n_fin
    assert m.histogram("tpot_ms").count == n_fin        # all max_new > 1
    assert m.histogram("step_ms").count == summ["steps"]
    for r in eng.sched.finished:
        assert 0 <= r.submit_t <= r.admit_t <= r.first_tok_t <= r.finish_t

    # drift rows exist for every dispatched scheme and the report holds
    assert tel.drift.check_coverage(summ["schemes_used"],
                                    kinds=("decode",)) == []
    rep = tel.drift.report()
    assert rep["rows"] == len(tel.drift.rows) > 0
    assert {"decode", "prefill"} <= set(rep["kinds"])

    # finalize is idempotent: a second call must not duplicate spans
    n_events = len(trace["traceEvents"])
    tel.finalize(eng)
    assert len(tel.tracer.to_dict()["traceEvents"]) == n_events

    # the registry round-trips through JSON (the --metrics artifact)
    d = json.loads(json.dumps(m.to_dict()))
    assert d["counters"]["engine.steps"] == summ["steps"]
    assert "ttft_ms" in d["histograms"]
    assert "engine.steps" in m.render_table()


def test_engine_outputs_token_identical_with_tracing(smoke_model):
    cfg, params = smoke_model
    reqs = _reqs(cfg, seed=7)
    plain = _run(cfg, params, reqs)
    traced = _run(cfg, params, reqs, telemetry=Telemetry.on())
    assert {r.rid: r.output for r in traced.sched.finished} == \
        {r.rid: r.output for r in plain.sched.finished}


def test_step_wall_clock_blocks_on_device(smoke_model, monkeypatch):
    """Satellite fix pin: `engine.step` used to stop the wall timer while
    async-dispatched device work was still in flight.  The engine must
    call `jax.block_until_ready` on the pool within every step."""
    cfg, params = smoke_model
    calls = []
    real = jax.block_until_ready
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: calls.append(1) or real(x))
    eng = _run(cfg, params, _reqs(cfg, seed=9, n=2))
    assert eng.stats.steps > 0
    assert len(calls) >= eng.stats.steps   # >= one sync per step
    assert eng.stats.wall > 0


# ------------------------------------------------------------------ logger --


def test_struct_logger_text_json_levels():
    lines = []
    lg = StructLogger("eng", sink=lines.append, level="info")
    lg.debug("hidden", a=1)
    lg.info("admitted", step=12, rid=3, frac=0.123456)
    lg.warning("preempt", rid=3)
    assert lines == ["[eng] admitted step=12 rid=3 frac=0.1235",
                     "[eng] preempt rid=3"]

    jlines = []
    jl = StructLogger("eng", sink=jlines.append, json_mode=True)
    jl.bind(step=5).info("tick", ms=1.5)
    rec = json.loads(jlines[0])
    assert rec == {"logger": "eng", "level": "info", "msg": "tick",
                   "step": 5, "ms": 1.5}

    with pytest.raises(ValueError):
        StructLogger("x", level="verbose")
    assert StructLogger("x", level="off").silenced


def test_as_logger_adapts_legacy_callables():
    lg = StructLogger("a")
    assert as_logger(lg) is lg
    assert as_logger(None).silenced
    seen = []
    adapted = as_logger(seen.append, "loop")
    adapted.info("resumed", step=4)
    assert seen == ["[loop] resumed step=4"]
    assert not adapted.silenced


# ------------------------------------------------------------ prefix hooks --


def test_prefix_cache_evict_and_cow_instants():
    pc = PrefixCache(BlockAllocator(4), 4)
    tel = Telemetry.on(trace=True, metrics=True, drift=False)
    pc.tel = tel
    blocks = pc.alloc(2)
    pc.insert(list(range(8)), blocks)
    pc.release(blocks)                    # refcount 0 -> LRU-evictable
    assert pc.evict(2) == 2
    pc.count_cow()
    names = [e["name"] for e in tel.tracer.to_dict()["traceEvents"]]
    assert "prefix_evict" in names and "cow_copy" in names
    assert tel.metrics.counter("prefix_cache.evictions").value == 2
    assert tel.metrics.counter("prefix_cache.cow_copies").value == 1
