"""The while-loop-aware HLO cost parser: exactness on controlled programs."""
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.analysis import hlo as hloa


def compile_fn(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_exact_vs_xla_undercount():
    W = jnp.zeros((10, 128, 128), jnp.float32)
    x0 = jnp.zeros((128, 128), jnp.float32)

    def f(x0, W):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        return jax.lax.scan(body, x0, W)[0]

    comp = compile_fn(f, x0, W)
    expected = 10 * 2 * 128 ** 3
    got = hloa.analyze(comp.as_text()).flops
    assert got == pytest.approx(expected, rel=0.01)
    # and XLA's own cost_analysis undercounts the loop (the reason this
    # module exists) — if XLA ever fixes this, we can drop the parser.
    xla = compat.cost_analysis(comp).get("flops", 0)
    assert xla < expected


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), ()
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    comp = compile_fn(f, jnp.zeros((64, 64), jnp.float32))
    expected = 3 * 4 * 2 * 64 ** 3
    got = hloa.analyze(comp.as_text()).flops
    assert got == pytest.approx(expected, rel=0.02)


def test_plain_matmul_flops():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    comp = compile_fn(lambda a, b: a @ b, a, b)
    got = hloa.analyze(comp.as_text()).flops
    assert got == pytest.approx(2 * 256 * 512 * 128, rel=0.01)


def test_dus_charged_at_slice_size():
    """A scan writing small slices into a big buffer must not be billed
    full-buffer traffic per step."""
    buf = jnp.zeros((512, 1024), jnp.float32)   # 2 MB

    def f(buf):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(
                b, jnp.ones((1, 1024)), i, axis=0), ()
        return jax.lax.scan(body, buf, jnp.arange(512))[0]

    comp = compile_fn(f, buf)
    got = hloa.analyze(comp.as_text()).bytes
    # slice traffic = 512 iters * 2 * 4KB = 4 MB; full-buffer billing
    # would be 512 * 2 * 2 MB = 2 GB.  Allow generous slack for loop
    # bookkeeping, assert we are orders below full-buffer.
    assert got < 100e6


def test_collective_factors():
    txt = """
HloModule m

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    cost = hloa.analyze(txt, num_partitions=8)
    # all-reduce ring traffic = 2*(G-1)/G * bytes = 2*7/8*4096
    assert cost.collective_bytes == pytest.approx(2 * 7 / 8 * 4096)


def test_trip_count_from_backend_config():
    txt = """
HloModule m

%body (t: (s32[], f32[8])) -> (s32[], f32[8]) {
  %t = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[8]{0} get-tuple-element(%t), index=1
  %d = f32[8]{0} dot(%x, %x), lhs_contracting_dims={}, rhs_contracting_dims={}
  %c1 = s32[] constant(1)
  %ip = s32[] add(%i, %c1)
  ROOT %r = (s32[], f32[8]) tuple(%ip, %d)
}

%cond (t: (s32[], f32[8])) -> pred[] {
  %t = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  ROOT %w = (s32[], f32[8]) while(%p), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    cost = hloa.analyze(txt)
    assert cost.while_trip_counts.get("w") == 7


def test_unknown_trip_count_warns_and_defaults_to_one():
    """A while whose condition has no static s32 limit (data-dependent
    loop) must degrade to trip=1 WITH a warning — silent undercounting is
    the exact failure mode this parser exists to prevent."""
    txt = """
HloModule m

%body (t: (f32[], f32[8])) -> (f32[], f32[8]) {
  %t = (f32[], f32[8]) parameter(0)
  %l = f32[] get-tuple-element(%t), index=0
  %x = f32[8]{0} get-tuple-element(%t), index=1
  ROOT %r = (f32[], f32[8]) tuple(%l, %x)
}

%cond (t: (f32[], f32[8])) -> pred[] {
  %t = (f32[], f32[8]) parameter(0)
  %l = f32[] get-tuple-element(%t), index=0
  %z = f32[] get-tuple-element(%t), index=0
  ROOT %lt = pred[] compare(%l, %z), direction=LT
}

ENTRY %main (p: (f32[], f32[8])) -> (f32[], f32[8]) {
  %p = (f32[], f32[8]) parameter(0)
  ROOT %w = (f32[], f32[8]) while(%p), condition=%cond, body=%body
}
"""
    cost = hloa.analyze(txt)
    assert cost.while_trip_counts.get("w") == 1
    assert any("unknown trip count" in w for w in cost.warnings)


def test_tuple_typed_op_bytes_sum_components():
    """Tuple-typed results (with the /*index=N*/ comments real HLO puts
    inside them) must parse and bill the SUM of the component shapes."""
    assert hloa._shape_bytes("(s32[], /*index=1*/f32[8]{0}, bf16[4,4]{1,0})") \
        == 4 + 32 + 32
    txt = """
HloModule m

ENTRY %main (p: f32[8]) -> (s32[], f32[8]) {
  %p = f32[8]{0} parameter(0)
  ROOT %cc = (s32[], /*index=1*/f32[8]{0}) custom-call(%p), custom_call_target="topk"
}
"""
    comps = hloa.parse_computations(txt)
    entry = comps["main"]
    cc = entry.ops[-1]
    assert cc.opcode == "custom-call" and cc.operands == ["p"]
    # custom-call bytes = tuple output (4 + 32) + f32[8] operand (32)
    assert hloa.analyze(txt).bytes == 68


def test_scatter_charged_at_update_size():
    """scatter moves 2x the UPDATE operand (read+write in place), never
    the full indexed buffer."""
    txt = """
HloModule m

ENTRY %main (p: f32[128,64]) -> f32[128,64] {
  %p = f32[128,64]{1,0} parameter(0)
  %i = s32[4,1]{1,0} parameter(1)
  %u = f32[4,64]{1,0} parameter(2)
  ROOT %sc = f32[128,64]{1,0} scatter(%p, %i, %u), update_window_dims={1}, to_apply=%missing
}
"""
    # 2 * |update| = 2 * 4*64*4 B, NOT 2 * 128*64*4 B
    assert hloa.analyze(txt).bytes == 2 * 4 * 64 * 4


def test_pad_charged_at_output_not_operand_free():
    txt = """
HloModule m

ENTRY %main (p: f32[128,64]) -> f32[132,64] {
  %p = f32[128,64]{1,0} parameter(0)
  %z = f32[] constant(0)
  ROOT %pd = f32[132,64]{1,0} pad(%p, %z), padding=2_2x0_0
}
"""
    assert hloa.analyze(txt).bytes == 2 * 132 * 64 * 4


def test_effective_shapes_resolve_convert_chains():
    """Converts are CPU float-normalization artifacts: an op consuming a
    convert (even a chain of them) is billed at the pre-convert size."""
    txt = """
HloModule m

ENTRY %main (p: bf16[64,64]) -> f64[64,64] {
  %p = bf16[64,64]{1,0} parameter(0)
  %c1 = f32[64,64]{1,0} convert(%p)
  ROOT %c2 = f64[64,64]{1,0} convert(%c1)
}
"""
    comps = hloa.parse_computations(txt)
    entry = comps["main"]
    eff = hloa._EffectiveShapes(entry, comps, hloa._transparent_comps(comps))
    # both hops resolve back to the bf16 source: 64*64*2 bytes, not *4/*8
    assert eff.bytes_of("c1") == 64 * 64 * 2
    assert eff.bytes_of("c2") == 64 * 64 * 2
    # and converts themselves are free, so the module bills zero traffic
    assert hloa.analyze(txt).bytes == 0


def test_transparent_fusion_shim_is_free():
    """A fusion whose body only converts/reshapes is a dtype shim — its
    scheduled-op traffic must be zero."""
    txt = """
HloModule m

%shim (a: bf16[32,32]) -> f32[32,32] {
  %a = bf16[32,32]{1,0} parameter(0)
  ROOT %cv = f32[32,32]{1,0} convert(%a)
}

ENTRY %main (p: bf16[32,32]) -> f32[32,32] {
  %p = bf16[32,32]{1,0} parameter(0)
  ROOT %f = f32[32,32]{1,0} fusion(%p), kind=kLoop, calls=%shim
}
"""
    assert hloa.analyze(txt).bytes == 0
