"""Speculative decoding on the paged MLA runtime.

The load-bearing claim (ISSUE 5 acceptance): spec-decode emits tokens
IDENTICAL to plain paged decode under greedy AND seeded sampling — the
target samples its own token at every verify position with the same
fold(rid, absolute position) keys plain decode uses, and drafts are
accepted only on exact match (runtime.spec.accept_length), so draft
quality moves throughput, never tokens.  Sharded parity lives in
tests/test_mesh_paged.py-style subprocess drivers here under the ``mesh``
marker.

Coverage:
  * accept_length unit semantics; shallow_draft layer slicing (params
    shared by reference, plan-consistent reassembly);
  * engine greedy + seeded parity vs plain decode for the identity draft
    (the oracle: acceptance MUST be 100%) and a shallow self-speculation
    draft (rejections exercised), across schemes, k, and the Pallas
    kernel path;
  * budget clipping (max_new < k + 1 requests), recompute-preemption
    replay mid-draft, and the radix prefix cache: rejected drafts must
    never leave stale blocks registered in the trie (every registered
    path is a prompt prefix; refcounts match live references);
  * scheduler decode_window reservations + advance_multi guards;
  * hwmodel mla_verify_cost: k = 0 degrades to the decode cost,
    amortization terms, break-even, and verify-aware auto_dispatch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.core.schemes import auto_dispatch, step_time, verify_time
from repro.hwmodel import attention_costs as ac
from repro.hwmodel.platforms import PLATFORMS
from repro.nn import module as nnm
from repro.runtime import (ContinuousScheduler, PagedMLAEngine, Request,
                           accept_length, identity_draft, shallow_draft)

MLA = ac.DSV3_MLA


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _mkreqs(seed=7, vocab=256, shared_prefix=0,
            specs=((6, 7, 0), (9, 5, 1), (5, 9, 3))):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab, (shared_prefix,)).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [pre, rng.integers(0, vocab, (p,)).astype(np.int32)]),
                    max_new=g, arrival=a)
            for i, (p, g, a) in enumerate(specs)]


def _run(cfg, params, reqs, *, spec_k=0, draft=None, num_blocks=40,
         block_size=4, max_batch=2, scheme="seq", **kw):
    dcfg = dparams = None
    if draft == "self":
        dcfg, dparams = identity_draft(cfg, params)
    elif draft is not None:
        dcfg, dparams = shallow_draft(cfg, params, draft)
    eng = PagedMLAEngine(cfg, params, num_blocks=num_blocks,
                         block_size=block_size, max_batch=max_batch,
                         compute_dtype=jnp.float32, scheme=scheme,
                         platform=PLATFORMS["tpu_v5e"], prefill_chunk=5,
                         spec_k=spec_k, draft_cfg=dcfg,
                         draft_params=dparams, **kw)
    eng.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                     arrival=r.arrival) for r in reqs])
    return eng, {r.rid: r.output for r in eng.sched.finished}


# ------------------------------------------------------------- unit level --


def test_accept_length_semantics():
    t = np.asarray([5, 6, 7, 8])
    assert accept_length(np.asarray([5, 6, 7]), t) == 3   # all accepted
    assert accept_length(np.asarray([5, 6, 9]), t) == 2   # first mismatch
    assert accept_length(np.asarray([9, 6, 7]), t) == 0
    assert accept_length(np.asarray([], np.int32), t[:1]) == 0  # k = 0
    # targets shorter than drafts (budget-clipped window): capped
    assert accept_length(np.asarray([5, 6, 7]), t[:2]) == 1


def test_shallow_draft_slices_target_layers(smoke_model):
    cfg, params = smoke_model
    dcfg, dparams = shallow_draft(cfg, params, 2)
    assert dcfg.n_layers == 2 and dcfg.vocab == cfg.vocab
    # embed / final norm shared by reference, not copied
    assert dparams["embed"] is params["embed"]
    assert dparams["ln_f"] is params["ln_f"]
    # layer 0 (the first_dense prefix layer) shared by reference; the
    # fixed layer_plan keeps it in the plan even below one full period
    assert dparams["prefix"]["l0"] is params["prefix"]["l0"]
    # layer 1 == period slice 0 of the target, leaf for leaf
    got = jax.tree.leaves(dparams["prefix"]["l1"])
    want = jax.tree.leaves(jax.tree.map(lambda a: a[0],
                                        params["period"]["s0"]))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # the draft tree matches the draft config's own param defs
    ref = jax.eval_shape(lambda: nnm.init_params(
        jax.random.PRNGKey(0), models.model_defs(dcfg), jnp.float32))
    assert jax.tree.structure(ref) == jax.tree.structure(dparams)
    with pytest.raises(ValueError):
        shallow_draft(cfg, params, cfg.n_layers)


def test_engine_validates_spec_arguments(smoke_model):
    cfg, params = smoke_model
    kw = dict(num_blocks=8, block_size=4, max_batch=1,
              compute_dtype=jnp.float32, scheme="seq")
    with pytest.raises(ValueError):
        PagedMLAEngine(cfg, params, spec_k=2, **kw)    # no draft
    with pytest.raises(NotImplementedError):
        PagedMLAEngine(cfg, params, spec_k=2, draft_cfg=cfg,
                       draft_params=params, prefill_mode="per_request",
                       prefill_chunk=4, **kw)


# -------------------------------------------------------- scheduler level --


def test_scheduler_window_reserves_verify_blocks():
    s = ContinuousScheduler(num_blocks=16, block_size=4, max_batch=1,
                            decode_window=4)
    s.submit(Request(rid=0, prompt=np.zeros(5, np.int32), max_new=10))
    [(slot, req)] = s.try_admit()
    # admission reserves plen + window = 9 tokens -> 3 blocks (plain
    # decode would reserve blocks_for(6) = 2)
    assert len(s.blocks_of[slot]) == 3
    req.tokens.append(1)                      # prefill sample
    s.lengths[slot] = 5
    s.ensure_step_capacity()                  # window 4 -> 9 tokens: holds
    assert len(s.blocks_of[slot]) == 3
    s.advance_multi({slot: [2, 3, 4, 5]})     # full window accepted
    assert int(s.lengths[slot]) == 9 and req.tokens == [1, 2, 3, 4, 5]
    s.ensure_step_capacity()                  # 9 + window(4) -> 13: grow
    assert len(s.blocks_of[slot]) == 4


def test_scheduler_window_clips_to_budget_and_guards_overflow():
    s = ContinuousScheduler(num_blocks=16, block_size=4, max_batch=1,
                            decode_window=4)
    s.submit(Request(rid=0, prompt=np.zeros(5, np.int32), max_new=2))
    [(slot, req)] = s.try_admit()
    # window clipped to the remaining budget: plen + 2 -> 2 blocks
    assert len(s.blocks_of[slot]) == 2
    req.tokens.append(1)
    s.lengths[slot] = 5
    with pytest.raises(ValueError):           # 2 emitted > window 1
        s.advance_multi({slot: [2, 3]})
    done = s.advance_multi({slot: [2]})
    assert done and done[0].output == [1, 2]


# ----------------------------------------------------------- engine level --


@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_identity_draft_is_token_identical_and_fully_accepted(
        smoke_model, k):
    """Draft == target: every draft must be accepted (the machinery
    oracle), and outputs must equal plain paged decode exactly."""
    cfg, params = smoke_model
    reqs = _mkreqs()
    _, plain = _run(cfg, params, reqs)
    eng, out = _run(cfg, params, reqs, spec_k=k, draft="self")
    assert out == plain
    assert eng.stats.spec_drafted > 0
    assert eng.stats.spec_accepted == eng.stats.spec_drafted
    assert eng.stats.spec_rounds < sum(r.max_new for r in reqs)
    s = eng.summary()
    assert s["spec_accept_rate"] == 1.0 and s["spec_mean_emitted"] > 1.0


@pytest.mark.parametrize("scheme", ["seq", "rc", "ru", "auto"])
def test_spec_shallow_draft_greedy_parity_across_schemes(smoke_model,
                                                         scheme):
    cfg, params = smoke_model
    reqs = _mkreqs()
    _, plain = _run(cfg, params, reqs, scheme=scheme)
    eng, out = _run(cfg, params, reqs, spec_k=2, draft=2, scheme=scheme)
    assert out == plain
    # shallow drafts on this config do get rejections — the rewind path
    # is actually exercised (if this ever goes flaky, lower the seed's
    # agreement, not the assert)
    assert eng.stats.spec_accepted < eng.stats.spec_drafted


def test_spec_seeded_sampling_parity(smoke_model):
    """Temperature/top-k: the verify positions consume the same
    fold(rid, position) key stream as plain decode."""
    cfg, params = smoke_model
    reqs = _mkreqs()
    kw = dict(temperature=0.8, top_k=5, sample_seed=3)
    _, plain = _run(cfg, params, reqs, **kw)
    eng_i, out_i = _run(cfg, params, reqs, spec_k=2, draft="self", **kw)
    eng_s, out_s = _run(cfg, params, reqs, spec_k=3, draft=2, **kw)
    assert out_i == plain and out_s == plain
    assert eng_i.stats.spec_accepted == eng_i.stats.spec_drafted


@pytest.mark.kernel
def test_spec_parity_on_pallas_kernel_path(smoke_model):
    """Verify + prefill through the fused paged kernels (decode kernel +
    multi-query prefill kernel in interpret mode on CPU)."""
    cfg, params = smoke_model
    reqs = _mkreqs()
    _, plain = _run(cfg, params, reqs)
    _, out = _run(cfg, params, reqs, spec_k=2, draft=2, impl="kernel",
                  prefill_impl="pallas")
    assert out == plain


def test_spec_budget_clipping_short_requests(smoke_model):
    """max_new < k + 1: the verify window clips to the remaining budget,
    outputs stay identical and never overshoot max_new."""
    cfg, params = smoke_model
    reqs = _mkreqs(specs=((6, 1, 0), (7, 2, 0), (5, 5, 1)))
    _, plain = _run(cfg, params, reqs)
    eng, out = _run(cfg, params, reqs, spec_k=3, draft="self")
    assert out == plain
    assert all(len(out[r.rid]) == r.max_new for r in reqs)


def test_spec_preemption_replay_identical(smoke_model):
    """A request preempted mid-generation under spec decoding replays to
    the same tokens (position-keyed sampling + window-aware growth)."""
    cfg, params = smoke_model
    rng = np.random.default_rng(19)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new=10) for i in range(2)]
    kw = dict(temperature=0.7, top_k=8, sample_seed=1)
    _, big = _run(cfg, params, reqs, num_blocks=40, spec_k=2, draft=2, **kw)
    _, plain = _run(cfg, params, reqs, num_blocks=40, **kw)
    assert big == plain
    small_eng, small = _run(cfg, params, reqs, num_blocks=7, spec_k=2,
                            draft=2, **kw)
    assert small_eng.stats.preemptions > 0
    assert small == plain


def _trie_paths(node, acc=()):
    out = []
    for key, child in node.children.items():
        path = acc + key
        out.append((child.block, path))
        out.extend(_trie_paths(child, path))
    return out


def test_spec_rejections_leave_no_stale_prefix_blocks(smoke_model):
    """Rejected drafts must never surface through the radix cache: every
    registered trie path is a prefix of some request's COMMITTED stream
    (prompt + emitted output — decode-filled blocks are trie-registered
    at block boundaries, but drafts are only ever written past
    ``lengths`` and never committed), refcounts match live block tables,
    and a second wave re-hitting the shared preamble still decodes
    token-identically."""
    cfg, params = smoke_model
    reqs = _mkreqs(shared_prefix=8,
                   specs=((6, 7, 0), (9, 5, 1), (5, 9, 3), (7, 6, 30),
                          (6, 8, 31)))
    _, plain = _run(cfg, params, reqs)
    eng, out = _run(cfg, params, reqs, spec_k=2, draft=2)
    assert out == plain
    assert eng.stats.spec_accepted < eng.stats.spec_drafted  # rejections
    assert eng.summary()["prefix_hit_rate"] > 0               # cache used
    streams = [list(r.prompt) + [int(t) for t in r.output]
               for r in eng.sched.finished]
    for block, path in _trie_paths(eng.sched.prefix.root):
        assert any(list(path) == s[:len(path)] for s in streams), \
            f"block {block} caches tokens that were never committed"
    live = {}
    for slot, blocks in eng.sched.blocks_of.items():
        for b in blocks:
            live[b] = live.get(b, 0) + 1
    eng.sched.prefix.check_invariants(live)


def test_spec_draft_pool_stays_consistent_under_cow(smoke_model):
    """CoW block copies are applied to BOTH pools; the draft pool mirrors
    the target stream, so acceptance of the identity draft stays 100%
    even with prefix sharing + second-wave re-admission."""
    cfg, params = smoke_model
    reqs = _mkreqs(shared_prefix=8,
                   specs=((6, 5, 0), (6, 5, 1), (6, 5, 20), (6, 5, 21)))
    _, plain = _run(cfg, params, reqs)
    eng, out = _run(cfg, params, reqs, spec_k=2, draft="self")
    assert out == plain
    assert eng.stats.spec_accepted == eng.stats.spec_drafted


# ---------------------------------------------------------------- hwmodel --


def test_verify_cost_k0_degrades_to_decode():
    kw = dict(scheme="seq", batch=4, paged_block=128, dp_shards=2)
    dec = ac.mla_decode_cost(MLA, cache_len=1024, **kw)
    ver = ac.mla_verify_cost(MLA, cache_len=1023, k=0, **kw)
    for term in ("B:w_common", "B:w_scheme", "B:cache_read",
                 "B:block_table", "q_down", "kv_down", "attn_scores",
                 "attn_out", "v_up", "o_proj", "q_up", "q_latent"):
        assert ver.breakdown[term] == pytest.approx(dec.breakdown[term]), term
    assert ver.breakdown["B:cache_write"] == dec.breakdown["B:cache_write"]


@pytest.mark.parametrize("scheme", ["seq", "rc", "ru", "naive"])
def test_verify_cost_amortizes_shared_streams(scheme):
    """Bytes per window token fall with k (weights + cache read are paid
    once per round); per-query FLOPs scale ~linearly with the window."""
    kw = dict(scheme=scheme, cache_len=4096, batch=8, paged_block=128)
    costs = [ac.mla_verify_cost(MLA, k=k, **kw) for k in (0, 2, 4, 8)]
    per_tok = [c.bytes / (k + 1) for c, k in zip(costs, (0, 2, 4, 8))]
    assert per_tok == sorted(per_tok, reverse=True)
    if scheme != "naive":     # naive spills the up-projected cache: bytes
        assert per_tok[-1] < 0.25 * per_tok[0]   # scale with the window
    assert all(a.flops < b.flops for a, b in zip(costs, costs[1:]))
    if scheme in ("seq", "ru"):
        # every FLOP term is per-query here, so work scales ~(k + 1);
        # rc amortizes its batch-shared absorb recompute and naive its
        # cache up-projection, so their ratios are deliberately smaller
        assert costs[-1].flops > 5 * costs[0].flops


def test_spec_break_even_and_verify_dispatch():
    be = ac.spec_break_even(MLA, scheme="seq", cache_len=4096, k=4,
                            batch=8, paged_block=128)
    # one verify round costs barely more than one decode step in bytes ->
    # break-even expected accepted length is close to (and >=) 1
    assert 1.0 <= be["break_even_emitted"] < 2.0
    assert be["amortization_at_full_accept"] > 2.0
    assert be["bytes_per_token_best"] < be["decode_bytes"]
    # draft overhead shifts the break-even up
    be_d = ac.spec_break_even(MLA, scheme="seq", cache_len=4096, k=4,
                              batch=8, paged_block=128,
                              draft_bytes_frac=0.25)
    assert be_d["break_even_emitted"] > be["break_even_emitted"]
    with pytest.raises(ValueError):
        ac.mla_verify_cost(MLA, scheme="seq", cache_len=16, k=-1)
    # verify-aware dispatch returns a sane scheme and differs from the
    # plain path only through the verify cost model
    plat = PLATFORMS["tpu_v5e"]
    s = auto_dispatch(MLA, plat, cache_len=4096, batch=8, paged_block=128,
                      verify_k=4)
    assert s in ("seq", "rc", "ru")
    assert verify_time(s, MLA, plat, 4096, 4, 8, paged_block=128) \
        <= verify_time("naive", MLA, plat, 4096, 4, 8, paged_block=128)
    # k-token amortization on the time axis too: a verify round is far
    # cheaper than k + 1 decode steps at the bandwidth-bound point
    t_dec = step_time(s, MLA, plat, 4096, 8, paged_block=128)
    t_ver = verify_time(s, MLA, plat, 4096, 4, 8, paged_block=128)
    assert t_ver < 2.5 * t_dec < 5 * t_dec


# ------------------------------------------------------------------- mesh --

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro import configs, models
from repro.launch.mesh import make_mesh
from repro.nn import module as nnm
from repro.runtime import PagedMLAEngine, Request, shallow_draft
from repro.hwmodel.platforms import PLATFORMS

cfg = configs.smoke("deepseek-v2-236b")
params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                         jnp.float32)
rng = np.random.default_rng(7)
reqs = [Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, (p,)).astype(np.int32),
                max_new=g, arrival=a)
        for i, (p, g, a) in enumerate([(6, 7, 0), (9, 5, 1), (5, 9, 3)])]


def run(mesh, spec_k, temperature):
    dcfg = dparams = (None, None) if not spec_k else \
        shallow_draft(cfg, params, 2)
    eng = PagedMLAEngine(cfg, params, num_blocks=40, block_size=4,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="seq", platform=PLATFORMS["tpu_v5e"],
                         prefill_chunk=5, spec_k=spec_k,
                         draft_cfg=dcfg[0] if spec_k else None,
                         draft_params=dcfg[1] if spec_k else None,
                         temperature=temperature, top_k=5, sample_seed=3,
                         mesh=mesh)
    eng.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                     arrival=r.arrival) for r in reqs])
    return eng, {str(r.rid): [int(t) for t in r.output]
                 for r in eng.sched.finished}

mesh = make_mesh((2, 2), ("data", "model"))
out = {}
for temp, name in ((0.0, "greedy"), (0.8, "seeded")):
    _, plain = run(None, 0, temp)
    eng_m, spec_m = run(mesh, 3, temp)
    _, spec_1 = run(None, 3, temp)
    out[name] = {"plain": plain, "spec_mesh": spec_m,
                 "spec_single": spec_1,
                 "accepted": eng_m.stats.spec_accepted,
                 "drafted": eng_m.stats.spec_drafted,
                 "spec_compiles": eng_m.spec_compiles,
                 # shared leaves must reuse the target's committed device
                 # buffers, not a second device_put copy
                 "embed_shared": all(
                     a is b for a, b in zip(
                         jax.tree.leaves(eng_m.draft_params["embed"]),
                         jax.tree.leaves(eng_m.params["embed"])))}
print("RESULT" + json.dumps(out))
"""


@pytest.mark.mesh
def test_spec_decode_mesh_parity():
    """spec-decode on a (dp=2, model=2) mesh emits the same tokens as
    BOTH plain decode and single-host spec decode, greedy and seeded
    (the ISSUE 5 acceptance gate).  Subprocess forces the device count
    before jax init, so this executes under plain `make test` too."""
    import os
    import subprocess
    import sys
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-4000:]
    import json
    payload = [ln for ln in res.stdout.splitlines()
               if ln.startswith("RESULT")][0]
    out = json.loads(payload[len("RESULT"):])
    for name in ("greedy", "seeded"):
        r = out[name]
        assert r["spec_mesh"] == r["plain"], name
        assert r["spec_mesh"] == r["spec_single"], name
        assert 0 < r["accepted"] <= r["drafted"], name
        assert r["spec_compiles"] <= 2, name     # 1 verify + 1 draft step
        assert r["embed_shared"], name  # no duplicate embed on device
