"""Docs-drift gates, in tier 1 (the same functions back `make docs-check`
and the CI `docs` job via scripts/check_docs.py):

  * every intra-repo markdown link in README.md and docs/*.md resolves;
  * the README flag table covers EVERY launch/serve.py argparse flag;
  * the serving CLIs' module docstrings document their own flags
    (launch/serve.py and examples/serve_mla.py carry their long-form
    docs in the docstring — stale-print drift fails here, not in review).

The checks are static (ast + re over source text) — examples/serve_mla.py
builds its parser at module level, so importing it would run the CLI;
ast parsing sidesteps that entirely.
"""
import importlib.util
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
_spec = importlib.util.spec_from_file_location(
    "check_docs", os.path.join(ROOT, "scripts", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_intra_repo_links_resolve():
    assert check_docs.check_links() == []


def test_readme_covers_every_serve_flag():
    assert check_docs.check_readme_flags() == []


def test_cli_docstrings_document_their_own_flags():
    assert check_docs.check_docstring_parity() == []


def test_flag_collection_sees_the_full_serve_cli():
    flags = check_docs.argparse_flags(check_docs.SERVE_CLI)
    # spot-check one flag per PR-era so a parser refactor that breaks
    # the ast walk cannot pass vacuously
    for f in ("--paged", "--prefill-impl", "--mesh", "--spec-k",
              "--cache-dtype", "--trace", "--engine", "--serve"):
        assert f in flags
    assert len(flags) >= 25


def test_missing_flag_matcher_is_word_exact():
    # '--top' must not be satisfied by '--top-k', nor '--serve' by
    # 'x--serve'; exact mentions do satisfy
    assert check_docs._missing_flags(["--top"], "only --top-k here") == ["--top"]
    assert check_docs._missing_flags(["--serve"], "weird x--serve") == ["--serve"]
    assert check_docs._missing_flags(["--serve"], "use `--serve` now") == []


def test_link_extractor_skips_external_and_anchors(tmp_path):
    md = tmp_path / "docs"
    md.mkdir()
    (md / "x.md").write_text(
        "[a](https://x.y) [b](#frag) [c](gone.md) ![img](gone.png)"
    )
    (tmp_path / "README.md").write_text("[ok](docs/x.md)")
    old = check_docs.ROOT
    check_docs.ROOT = str(tmp_path)
    try:
        problems = check_docs.check_links()
    finally:
        check_docs.ROOT = old
    # the one broken link is caught; external/anchor/image links are not
    assert problems == ["docs/x.md: broken link -> gone.md"]
