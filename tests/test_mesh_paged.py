"""Sharded paged serving == single host, token for token.

The PR-4 acceptance suite (`mesh` marker; `make test-mesh` / the CI
``mesh`` job run it under XLA_FLAGS=--xla_force_host_platform_device_count
=8).  The engine drivers run in SUBPROCESSES that force the device count
themselves — the forced count must precede jax init — so the suites
EXECUTE (not skip) even under a plain single-device `make test`.

Coverage:
  * engine-level greedy parity on the MoE smoke model (the paper's native
    deepseek-v2 shape): a (dp=2, model=2) mesh produces the same tokens
    as ``mesh=None`` for ALL FOUR schemes x impl in {'gather', 'pallas'};
  * seeded temperature/top-k sampling parity + a recompute-preemption
    replay under the mesh, on a DENSE MLA config — discrete MoE routing
    amplifies GSPMD float-reassociation noise (~1e-7) into ~1e-3 logit
    deltas via near-tie expert flips, which greedy argmax absorbs but
    top-k boundary sampling may not, so the sampling-parity claim is made
    where it is numerically meaningful (the PRNG stream itself is
    topology-invariant by construction — engine._sample_tokens);
  * step-level allclose parity + pool-write equality for
    make_paged_serve_step and make_chunked_prefill_step (which no longer
    raise NotImplementedError for mesh is not None);
  * cache_pspecs paged pool layout and the per-device dp_shards roofline
    term (in-process — no devices needed).
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.mesh

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro import configs, models
from repro.launch.mesh import make_mesh
from repro.models.common import ModelConfig
from repro.nn import module as nnm
from repro.runtime import PagedMLAEngine, Request
from repro.runtime.steps import (make_chunked_prefill_step,
                                 make_paged_serve_step)

mesh = make_mesh((2, 2), ("data", "model"))
out = {}

MOE = configs.smoke("deepseek-v2-236b")
DENSE = ModelConfig(name="mla-dense-smoke", family="dense", n_layers=2,
                    d_model=64, n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
                    attn_kind="mla", q_lora_rank=48, kv_lora_rank=32,
                    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                    max_seq=128, remat=False)
PARAMS = {cfg.name: nnm.init_params(jax.random.PRNGKey(0),
                                    models.model_defs(cfg), jnp.float32)
          for cfg in (MOE, DENSE)}


def mkreqs(specs, seed=3, vocab=256):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                    max_new=g, arrival=a)
            for i, (p, g, a) in enumerate(specs)]


def run(cfg, reqs, mesh, scheme="seq", impl="ref", num_blocks=24, **kw):
    eng = PagedMLAEngine(cfg, PARAMS[cfg.name], num_blocks=num_blocks,
                         block_size=4, max_batch=2,
                         compute_dtype=jnp.float32, scheme=scheme,
                         impl=impl, prefill_chunk=5, mesh=mesh, **kw)
    eng.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                     arrival=r.arrival) for r in reqs])
    return eng, {r.rid: r.output for r in eng.sched.finished}

# ---- greedy parity: all four schemes x both impls (MoE smoke model) ------
specs = [(8, 3, 0), (11, 3, 1)]
reqs = mkreqs(specs)
for scheme in ("naive", "seq", "rc", "ru"):
    _, base = run(MOE, reqs, None, scheme)
    for name, impl in (("gather", "ref"), ("pallas", "kernel")):
        eng, got = run(MOE, reqs, mesh, scheme, impl)
        out[f"greedy_{scheme}_{name}"] = got == base
        out[f"compiles_{scheme}_{name}"] = eng.prefill_compiles
out["n_requests"] = len(reqs)

# ---- seeded sampling parity (dense MLA: continuous-function numerics) ----
reqs_d = mkreqs([(8, 6, 0), (11, 5, 1)])
kw = dict(temperature=0.8, top_k=5, sample_seed=3)
_, base = run(DENSE, reqs_d, None, **kw)
for name, impl in (("gather", "ref"), ("pallas", "kernel")):
    _, got = run(DENSE, reqs_d, mesh, impl=impl, **kw)
    out[f"sample_{name}"] = got == base

# ---- recompute-preemption replay under the mesh --------------------------
reqs_p = mkreqs([(6, 10, 0), (6, 10, 0)], seed=19)
kw = dict(temperature=0.7, top_k=8, sample_seed=1)
_, big = run(DENSE, reqs_p, None, num_blocks=40, **kw)
eng_small, small = run(DENSE, reqs_p, mesh, num_blocks=7, **kw)
out["preempt_happened"] = eng_small.stats.preemptions > 0
out["preempt_match"] = small == big

# ---- step-level parity (the lifted NotImplementedError paths) ------------
cfg, params = DENSE, PARAMS[DENSE.name]
pool0 = models.init_paged_cache(cfg, 16, 4, jnp.float32)
bt = jnp.asarray([[1, 2, 3, 0], [4, 5, 6, 0]], jnp.int32)
lens = jnp.asarray([5, 9], jnp.int32)
tok = jnp.asarray([7, 8], jnp.int32)
s0 = make_paged_serve_step(cfg, None, compute_dtype=jnp.float32)
l0, p0 = s0(params, tok, jax.tree.map(jnp.copy, pool0), bt, lens)
s1 = make_paged_serve_step(cfg, mesh, compute_dtype=jnp.float32)
l1, p1 = s1(params, tok, jax.tree.map(jnp.copy, pool0), bt, lens)
out["decode_step_err"] = float(jnp.max(jnp.abs(l0 - l1)))
out["decode_pool_err"] = float(max(
    jnp.max(jnp.abs(a - b)) for a, b in zip(jax.tree.leaves(p0),
                                            jax.tree.leaves(p1))))

toks = jnp.asarray(np.random.default_rng(7).integers(0, cfg.vocab, (2, 4)),
                   jnp.int32)
nv = jnp.asarray([4, 3], jnp.int32)
c0 = make_chunked_prefill_step(cfg, None, compute_dtype=jnp.float32)
cl0, cp0 = c0(params, toks, jax.tree.map(jnp.copy, pool0), bt, lens, nv)
c1 = make_chunked_prefill_step(cfg, mesh, compute_dtype=jnp.float32,
                               impl="kernel")
cl1, cp1 = c1(params, toks, jax.tree.map(jnp.copy, pool0), bt, lens, nv)
out["prefill_step_err"] = float(jnp.max(jnp.abs(cl0 - cl1)))
# block 0 (NULL) absorbs the chunk-padding garbage of every row; with
# several invalid rows racing duplicate scatter writes into it, the
# winner is topology-dependent — by design it is never attended, so the
# parity claim covers every ALLOCATED block (the block axis is -3).
out["prefill_pool_err"] = float(max(
    jnp.max(jnp.abs(a[..., 1:, :, :] - b[..., 1:, :, :]))
    for a, b in zip(jax.tree.leaves(cp0), jax.tree.leaves(cp1))))

# engine pads max_batch up to a DP multiple (free: empty slots)
eng_pad = PagedMLAEngine(DENSE, PARAMS[DENSE.name], num_blocks=12,
                         block_size=4, max_batch=3,
                         compute_dtype=jnp.float32, scheme="seq", mesh=mesh)
out["padded_max_batch"] = eng_pad.sched.max_batch

print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=ROOT, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("scheme", ["naive", "seq", "rc", "ru"])
@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_engine_greedy_token_identical(results, scheme, impl):
    """(dp=2, model=2) engine == single host, greedy, per scheme x impl."""
    assert results[f"greedy_{scheme}_{impl}"] is True
    # compile count stays bounded by chunk sizes under the mesh too
    assert results[f"compiles_{scheme}_{impl}"] == 1


@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_engine_seeded_sampling_token_identical(results, impl):
    """The sampled PRNG stream is topology-invariant (the engine samples
    from host-gathered rows; under jax<0.5's non-partitionable threefry a
    sharded operand would draw DIFFERENT bits than unsharded)."""
    assert results[f"sample_{impl}"] is True


def test_engine_preemption_replay_matches(results):
    assert results["preempt_happened"] is True
    assert results["preempt_match"] is True


def test_paged_steps_accept_mesh(results):
    """make_paged_serve_step / make_chunked_prefill_step build AND run
    under a mesh (no NotImplementedError), allclose to single host with
    identical pool writes."""
    assert results["decode_step_err"] < 1e-4
    assert results["decode_pool_err"] < 1e-5
    assert results["prefill_step_err"] < 1e-4
    assert results["prefill_pool_err"] < 1e-5


def test_engine_pads_max_batch_to_dp_multiple(results):
    assert results["padded_max_batch"] == 4   # 3 rounded up to dp=2 multiple


# ------------------------------------------------ in-process (no devices) --


def test_cache_pspecs_paged_pool_replicated():
    """The pool layout: every paged leaf (stacked or not) is replicated
    over EVERY mesh axis — block tables are host-global, so any DP shard
    may address any block, and 'model' shards re-read the shared compact
    pool (the MQA structure of absorbed MLA)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS

    from repro import configs, models
    from repro.runtime.steps import cache_pspecs

    cfg = configs.smoke("deepseek-v2-236b")
    pool = jax.eval_shape(
        lambda: models.init_paged_cache(cfg, 4, 2, jnp.float32))
    rules = {"batch": "data", "cache_seq": None}
    specs = cache_pspecs(pool, rules, family=cfg.family, paged=True)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PS))
    assert leaves and all(s == PS() for s in leaves)
    # the contiguous path is untouched: batch dim still shards
    cache = jax.eval_shape(
        lambda: models.init_cache(cfg, 4, 8, jnp.float32))
    cspecs = cache_pspecs(cache, rules, family=cfg.family,
                          batch_spec="data")
    flat = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, PS))
    assert any(s != PS() for s in flat)


def test_decode_cost_dp_shards_scaling():
    """Per-device paged decode bytes shrink by the DP factor; weight bytes
    do not (each device still streams the full weight set)."""
    from repro.hwmodel import attention_costs as ac

    kw = dict(scheme="seq", cache_len=1024, batch=8, paged_block=128)
    c1 = ac.mla_decode_cost(ac.DSV3_MLA, **kw)
    c2 = ac.mla_decode_cost(ac.DSV3_MLA, dp_shards=2, **kw)
    for term in ("B:cache_read", "B:cache_write", "B:block_table"):
        assert c2.breakdown[term] == pytest.approx(c1.breakdown[term] / 2)
    assert c2.breakdown["B:w_common"] == c1.breakdown["B:w_common"]
    assert c2.breakdown["B:w_scheme"] == c1.breakdown["B:w_scheme"]
    assert c2.bytes < c1.bytes and c2.flops < c1.flops
    # ceil semantics: a DP factor above the batch floors at one local row
    c8 = ac.mla_decode_cost(ac.DSV3_MLA, dp_shards=64, **kw)
    c1b = ac.mla_decode_cost(ac.DSV3_MLA, scheme="seq", cache_len=1024,
                             batch=1, paged_block=128)
    assert c8.bytes == c1b.bytes
    with pytest.raises(ValueError):
        ac.mla_decode_cost(ac.DSV3_MLA, dp_shards=0, **kw)


def test_auto_dispatch_accepts_dp_shards():
    from repro.core.schemes import auto_dispatch, step_time
    from repro.hwmodel import attention_costs as ac
    from repro.hwmodel.platforms import PLATFORMS

    plat = PLATFORMS["tpu_v5e"]
    s = auto_dispatch(ac.DSV3_MLA, plat, cache_len=4096, batch=8,
                      paged_block=64, dp_shards=4)
    assert s in ("seq", "rc", "ru")
    # sharding the batch can only shrink the per-device step time
    for sch in ("seq", "rc", "ru"):
        t1 = step_time(sch, ac.DSV3_MLA, plat, 4096, batch=8, paged_block=64)
        t4 = step_time(sch, ac.DSV3_MLA, plat, 4096, batch=8, paged_block=64,
                       dp_shards=4)
        assert t4 <= t1
