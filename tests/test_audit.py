"""Static hot-path auditor lane: the clean conformance matrix, plus
deliberately injected violations proving every audit actually fires.

Compile-only — no step in here is ever executed.  The mesh matrix runs
through scripts/audit_steps.py in a subprocess (it must force 8 host
devices before jax initializes)."""
import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import audit, jaxlint
from repro.analysis.audit_allowlist import AllowlistEntry

pytestmark = pytest.mark.audit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------- clean matrix --

SINGLE_SPECS = audit.single_device_matrix()


@pytest.fixture(scope="module")
def compiled_cells():
    """Compile-once cache shared by the matrix + injection tests."""
    return {}


def _get_cell(cache, spec, dtype=None):
    key = (spec, str(dtype))
    if key not in cache:
        cache[key] = (
            audit.compile_step(spec)
            if dtype is None
            else audit.compile_step(spec, dtype=dtype)
        )
    return cache[key]


@pytest.mark.parametrize("spec", SINGLE_SPECS, ids=lambda s: s.where)
def test_single_device_cell_clean(spec, compiled_cells):
    cs = _get_cell(compiled_cells, spec)
    rs = (
        _get_cell(compiled_cells, spec, audit.ROOFLINE_DTYPE)
        if audit.roofline_applicable(spec)
        else None
    )
    findings = audit.audit_step(spec, compiled_step=cs, roofline_step=rs)
    kept, _ = audit.split_allowlisted(findings)
    assert kept == [], "\n".join(str(f) for f in kept)


def test_mesh_matrix_clean_via_cli(tmp_path):
    """The forced-8-device matrix through the CLI (fresh process so
    XLA_FLAGS lands before jax init) — exit 0, no findings."""
    out = tmp_path / "audit.json"
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(REPO, "scripts", "audit_steps.py"),
            "--matrix",
            "mesh",
            "--no-lint",
            "--json",
            str(out),
        ],
        capture_output=True,
        text=True,
        timeout=1800,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(out.read_text())
    assert payload["findings"] == []
    assert len(payload["cells"]) == len(audit.mesh_matrix())


def test_cli_exit_nonzero_on_finding(tmp_path):
    """The CLI must fail loudly: lint a file with a known JL001 violation
    via --lint-root and assert the non-zero exit."""
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import jax\n"
        "key = jax.random.PRNGKey(0)\n"
        "a = jax.random.normal(key, (2,))\n"
        "b = jax.random.uniform(key, (2,))\n"
    )
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(REPO, "scripts", "audit_steps.py"),
            "--matrix",
            "none",
            "--lint",
            "--lint-root",
            str(tmp_path),
        ],
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "JL001" in proc.stdout


# ------------------------------------------------------ injected: donation --


def test_injected_donation_drop_detected():
    """A donated buffer whose output is a DIFFERENT dtype cannot alias —
    XLA silently copies.  The donation audit must catch exactly that."""
    pool = {
        "ckv": jnp.zeros((4, 8, 32), jnp.bfloat16),
        "krope": jnp.zeros((4, 8, 8), jnp.bfloat16),
    }

    @functools.partial(jax.jit, donate_argnums=(0,))
    def broken(p):
        return jax.tree.map(lambda x: (x + 1).astype(jnp.float32), p)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def fine(p):
        return jax.tree.map(lambda x: x + 1, p)

    ok = fine.lower(pool).compile()
    assert audit.audit_donation(ok, pool, "fine") == []

    bad = broken.lower(pool).compile()
    findings = audit.audit_donation(bad, pool, "broken")
    assert len(findings) == 2, [str(f) for f in findings]
    assert all(f.rule == "donation" for f in findings)


def test_real_step_donates_both_pool_leaves(compiled_cells):
    """Regression for the production decode step: both pool leaves (ckv +
    krope) must appear in input_output_alias — 2 entries, none dropped."""
    spec = audit.StepSpec("decode", "gather", "seq")
    cs = _get_cell(compiled_cells, spec)
    header = cs.compiled.as_text().split("\n", 1)[0]
    entries = audit._ALIAS_RE.findall(header)
    assert len(entries) == len(jax.tree.leaves(cs.pool_tree)) == 2
    assert cs.donation_warnings == []


# -------------------------------------------------------- injected: gather --


def test_injected_gather_detected(compiled_cells):
    """The reference (gather) decode step audited under the pallas rule
    must trip the budget — proof the gather audit sees the (B, S) view."""
    spec = audit.StepSpec("decode", "gather", "seq")
    cs = _get_cell(compiled_cells, spec)
    findings = audit.audit_gather(cs.compiled, cs.pool_tree, cs.batch, spec.where)
    assert findings, "reference gather path must exceed the pallas budget"
    assert all(f.rule == "gather" for f in findings)
    assert any("gather" in f.detail for f in findings)


def test_gather_budget_scales_with_slack(compiled_cells):
    """With an absurdly large slack the same cell passes — the threshold
    is the block-size-derived budget, not a hardcoded op ban."""
    spec = audit.StepSpec("decode", "gather", "seq")
    cs = _get_cell(compiled_cells, spec)
    assert (
        audit.audit_gather(
            cs.compiled, cs.pool_tree, cs.batch, spec.where, slack=10_000
        )
        == []
    )


# --------------------------------------------------------- injected: dtype --


def test_injected_f32_pool_promotion_detected():
    pool = audit_pool = {
        "ckv": jnp.zeros((2, 129, 8, 32), jnp.bfloat16),
    }

    def promoted(p):
        return jax.tree.map(lambda x: x.astype(jnp.float32) * 2.0, p)

    jaxpr = jax.make_jaxpr(promoted)(audit_pool)
    findings = audit.audit_dtypes(jaxpr, pool, "promoted")
    assert any("f32" in f.detail and f.rule == "dtype" for f in findings)

    def clean(p):
        return jax.tree.map(lambda x: x * 2.0, p)

    jaxpr = jax.make_jaxpr(clean)(audit_pool)
    assert audit.audit_dtypes(jaxpr, pool, "clean") == []


def test_injected_wide_dequant_of_quantized_pool_detected():
    """PR 8 rule: a wide-float buffer AT a quantized pool's shape means
    the whole pool was dequantized in HBM — the int8 cache-traffic win
    silently forfeited.  Dequantizing the GATHERED view stays clean."""
    pool = {
        "ckv": jnp.zeros((9, 8, 32), jnp.int8),
        "ckv_scale": jnp.ones((9, 8, 1), jnp.float32),
        "krope": jnp.zeros((9, 8, 8), jnp.int8),
        "krope_scale": jnp.ones((9, 8, 1), jnp.float32),
    }
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)

    def leaky(p):
        # the hazard: astype on the POOL leaf hoists a full f32 copy
        wide = p["ckv"].astype(jnp.float32) * p["ckv_scale"]
        return wide[bt].sum()

    jaxpr = jax.make_jaxpr(leaky)(pool)
    findings = audit.audit_dtypes(jaxpr, pool, "leaky")
    assert any(f.rule == "dtype" and "dequantized pool-sized" in f.detail
               for f in findings), [str(f) for f in findings]

    def clean(p):
        # gather first: the dequantized buffer is (B, nb*bs, D), never
        # pool-shaped
        g = p["ckv"][bt].reshape(2, 16, 32)
        s = p["ckv_scale"][bt].reshape(2, 16, 1)
        return (g.astype(jnp.float32) * s).sum()

    jaxpr = jax.make_jaxpr(clean)(pool)
    assert audit.audit_dtypes(jaxpr, pool, "clean") == []


def test_injected_scale_leaf_dropped_from_donation_detected():
    """PR 8 fixture: a step that re-emits the per-block scale leaves at a
    different dtype breaks their input_output_alias — the donation audit
    must flag exactly the two scale leaves."""
    pool = {
        "ckv": jnp.zeros((4, 8, 32), jnp.int8),
        "ckv_scale": jnp.ones((4, 8, 1), jnp.float32),
        "krope": jnp.zeros((4, 8, 8), jnp.int8),
        "krope_scale": jnp.ones((4, 8, 1), jnp.float32),
    }

    def step(p, narrow_scales):
        out = dict(p)
        out["ckv"] = (p["ckv"].astype(jnp.int32) + 1).astype(jnp.int8)
        out["krope"] = (p["krope"].astype(jnp.int32) + 1).astype(jnp.int8)
        if narrow_scales:
            # the bug: scales written back at f16 — cannot alias f32 in
            out["ckv_scale"] = p["ckv_scale"].astype(jnp.float16)
            out["krope_scale"] = p["krope_scale"].astype(jnp.float16)
        else:
            out["ckv_scale"] = p["ckv_scale"] * 2.0
            out["krope_scale"] = p["krope_scale"] * 2.0
        return out

    fine = jax.jit(functools.partial(step, narrow_scales=False),
                   donate_argnums=(0,)).lower(pool).compile()
    assert audit.audit_donation(fine, pool, "fine") == []

    broken = jax.jit(functools.partial(step, narrow_scales=True),
                     donate_argnums=(0,)).lower(pool).compile()
    findings = audit.audit_donation(broken, pool, "broken")
    assert len(findings) == 2, [str(f) for f in findings]
    assert all(f.rule == "donation" for f in findings)


def test_matrix_includes_quantized_cells_and_tolerances():
    """The single-device matrix must carry the int8 cells (both impls,
    all three kinds) and tolerances_for must resolve their calibrated
    bands, falling back to the wide-pool table otherwise."""
    quant = [s for s in audit.single_device_matrix()
             if s.cache_dtype == "int8"]
    assert {(s.kind, s.impl) for s in quant} == {
        (k, i) for k in ("decode", "prefill", "verify")
        for i in ("gather", "pallas")}
    assert all(s.where.endswith("/int8") for s in quant)
    for s in quant:
        tol = audit.tolerances_for(s)
        assert tol == audit.QUANT_TOLERANCES[(s.kind, s.impl, "1dev",
                                              "int8")]
    wide = audit.StepSpec("decode", "gather", "seq")
    assert audit.tolerances_for(wide) == audit.TOLERANCES[
        ("decode", "gather", "1dev")]


def test_fork_cow_cannot_grow_the_matrix():
    """PR 10 pin: n-way parallel sampling / CoW forking is host-side
    bookkeeping over the SAME compiled steps — the audit matrix is
    unchanged by construction.  StepSpec has no axis that could even
    express a fork/sampling-group variant, and the cell sets stay at
    their PR-8 size (27 single-device + 15 mesh cells)."""
    import dataclasses
    assert {f.name for f in dataclasses.fields(audit.StepSpec)} == {
        "kind", "impl", "scheme", "mesh_shape", "cache_dtype"}
    assert len(audit.single_device_matrix()) == 27
    assert len(audit.mesh_matrix()) == 15


def test_injected_f64_hlo_text_detected():
    pool = {"ckv": jnp.zeros((2, 4, 8, 32), jnp.bfloat16)}
    jaxpr = jax.make_jaxpr(lambda p: jax.tree.map(lambda x: x * 2, p))(pool)
    findings = audit.audit_dtypes(
        jaxpr, pool, "f64", hlo_text="ROOT %r = f64[128]{0} parameter(0)"
    )
    assert any("f64" in f.detail for f in findings)


# ------------------------------------------------------ injected: roofline --


def test_injected_cost_skew_breaches_tolerance(compiled_cells):
    """Skewing a byte term and a FLOP term of the model by >2x must push
    the calibrated ratio out of its committed band."""
    spec = audit.StepSpec("decode", "gather", "seq")
    rs = _get_cell(compiled_cells, spec, audit.ROOFLINE_DTYPE)
    clean = audit.audit_roofline(rs.compiled, spec, spec.where)
    assert clean == [], [str(f) for f in clean]
    skew_bytes = audit.audit_roofline(
        rs.compiled, spec, spec.where, term_scale={"w_mlp": 6.0}
    )
    assert any("bytes" in f.detail for f in skew_bytes)
    skew_flops = audit.audit_roofline(
        rs.compiled, spec, spec.where, term_scale={"mlp": 6.0}
    )
    assert any("flops" in f.detail for f in skew_flops)


def test_roofline_all_four_schemes_decode(compiled_cells):
    """Acceptance: conformance deltas for seq/rc/ru/naive all inside the
    committed table on the decode step."""
    for scheme in ("seq", "rc", "ru", "naive"):
        spec = audit.StepSpec("decode", "gather", scheme)
        rs = _get_cell(compiled_cells, spec, audit.ROOFLINE_DTYPE)
        fs = audit.audit_roofline(rs.compiled, spec, spec.where)
        assert fs == [], [str(f) for f in fs]


# ------------------------------------------------------------- allowlist --


def test_allowlist_suppresses_and_reports(monkeypatch):
    f = audit.Finding("gather", "decode/pallas/seq/1dev", "moves 9999 elements")
    kept, sup = audit.split_allowlisted([f])
    assert kept == [f] and sup == []
    monkeypatch.setattr(
        audit,
        "ALLOWLIST",
        (
            AllowlistEntry(
                rule="gather",
                where="decode/pallas",
                match="9999",
                reason="test entry",
            ),
        ),
    )
    kept, sup = audit.split_allowlisted([f])
    assert kept == [] and sup == [f]


# --------------------------------------------------------------- jaxlint --


def _lint(src):
    return jaxlint.lint_source(textwrap.dedent(src), "snippet.py")


def test_jl001_key_reuse_fires_and_split_is_clean():
    bad = _lint(
        """
        import jax
        def f(seed):
            key = jax.random.PRNGKey(seed)
            a = jax.random.normal(key, (2,))
            b = jax.random.uniform(key, (2,))
            return a, b
        """
    )
    assert any("JL001" in f.detail for f in bad)
    good = _lint(
        """
        import jax
        def f(seed):
            k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
            a = jax.random.normal(k1, (2,))
            b = jax.random.uniform(k2, (2,))
            return a, b
        """
    )
    assert good == []


def test_jl001_exclusive_branches_do_not_fire():
    """Draws in mutually exclusive if-branches share no path — the exact
    pattern of nn.module._init_one that must stay clean."""
    good = _lint(
        """
        import jax
        def init(key, kind):
            if kind == "normal":
                return jax.random.normal(key, (2,))
            if kind == "uniform":
                return jax.random.uniform(key, (2,))
            return jax.random.gumbel(key, (2,))
        """
    )
    assert good == []


def test_jl001_fold_in_rebind_is_clean():
    good = _lint(
        """
        import jax
        def f(key):
            for i in range(3):
                key = jax.random.fold_in(key, i)
                x = jax.random.normal(key, (2,))
            return x
        """
    )
    assert good == []


def test_jl002_tracer_branch_fires_only_under_jit():
    bad = _lint(
        """
        import jax
        import jax.numpy as jnp
        @jax.jit
        def f(x):
            if jnp.any(x > 0):
                return x
            return -x
        """
    )
    assert any("JL002" in f.detail for f in bad)
    good = _lint(
        """
        import jax.numpy as jnp
        def f(x):
            if jnp.any(x > 0):
                return x
            return -x
        """
    )
    assert good == []


def test_jl003_captured_mutation_fires():
    bad = _lint(
        """
        import jax
        stats = []
        @jax.jit
        def f(x):
            stats.append(1)
            return x
        """
    )
    assert any("JL003" in f.detail for f in bad)
    good = _lint(
        """
        import jax
        @jax.jit
        def f(x):
            acc = []
            acc.append(1)
            return x
        """
    )
    assert good == []


def test_jl004_use_after_donation_fires():
    bad = _lint(
        """
        import jax
        def f(step_fn, pool, tok):
            out = jax.jit(step_fn, donate_argnums=(0,))(pool, tok)
            return pool.sum() + out
        """
    )
    assert any("JL004" in f.detail for f in bad)
    good = _lint(
        """
        import jax
        def f(step_fn, pool, tok):
            pool = jax.jit(step_fn, donate_argnums=(0,))(pool, tok)
            return pool
        """
    )
    assert good == []


def test_repo_tree_is_lint_clean():
    """Regression for the serve.py PRNG-reuse fix: the whole src/repro
    tree stays jaxlint-clean modulo the committed allowlist."""
    findings = jaxlint.lint_tree(os.path.join(REPO, "src", "repro"))
    kept, _ = audit.split_allowlisted(findings)
    assert kept == [], "\n".join(str(f) for f in kept)


def test_serve_old_key_reuse_pattern_would_fire():
    """The exact shape of the bug fixed in launch/serve.py — guaranteed
    to stay detectable."""
    findings = _lint(
        """
        import jax
        def main(args, cfg, dtype):
            key = jax.random.PRNGKey(args.seed + 1)
            toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
            kw = {}
            if cfg.family in ("vlm", "encdec"):
                kw["embeds"] = jax.random.normal(key, (2, 4, 8), dtype)
            return toks, kw
        """
    )
    assert any("JL001" in f.detail for f in findings)
