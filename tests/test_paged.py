"""Paged latent-KV cache + continuous batching: kernel/oracle agreement,
paged-vs-contiguous allclose equivalence across all four execution schemes
at ragged per-request lengths, scheduler unit tests, and end-to-end engine
equivalence (greedy tokens match per-request contiguous decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.core import cache as cachelib
from repro.core import mla as mlalib
from repro.hwmodel import attention_costs as ac
from repro.hwmodel.platforms import PLATFORMS
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.mla_decode import mla_decode_paged_kernel
from repro.nn import module as nnm
from repro.runtime import (BlockAllocator, ContinuousScheduler,
                           PagedMLAEngine, Request,
                           make_prefill_step, make_serve_step)
from repro.runtime.scheduler import NULL_BLOCK

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}

MCFG = mlalib.MLAConfig(d_model=64, n_heads=4, q_lora_rank=48,
                        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ----------------------------------------------------------- kernel level --


@pytest.mark.parametrize("B,H,Dl,Dr,bs,nb,N,idx", [
    (1, 4, 32, 8, 16, 2, 4, [0]),             # single block, first token
    (3, 4, 32, 8, 16, 4, 16, [37, 0, -1]),    # ragged + inactive slot
    (2, 8, 64, 16, 32, 3, 8, [95, 17]),       # full + partial
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_vs_oracle(B, H, Dl, Dr, bs, nb, N, idx, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, H, Dl + Dr), dtype)
    ckv = rand(ks[1], (N, bs, Dl), dtype)
    krope = rand(ks[2], (N, bs, Dr), dtype)
    rng = np.random.default_rng(1)
    bt = jnp.asarray(rng.integers(0, N, (B, nb)), jnp.int32)
    idx = jnp.asarray(idx, jnp.int32)
    out = mla_decode_paged_kernel(q, ckv, krope, bt, idx, interpret=True)
    want = ref.mla_decode_paged_ref(q, ckv, krope, bt, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_kernel_matches_contiguous():
    """With an identity-style block table, paged == contiguous kernel."""
    B, H, Dl, Dr, bs, nb = 2, 4, 32, 8, 16, 4
    N = B * nb + 1
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (B, H, Dl + Dr))
    ckv_p = rand(ks[1], (N, bs, Dl))
    krope_p = rand(ks[2], (N, bs, Dr))
    bt = (1 + jnp.arange(B * nb, dtype=jnp.int32)).reshape(B, nb)
    ckv_c = ckv_p[bt].reshape(B, nb * bs, Dl)
    krope_c = krope_p[bt].reshape(B, nb * bs, Dr)
    for index in (0, 13, nb * bs - 1):
        got = mla_decode_paged_kernel(
            q, ckv_p, krope_p, bt, jnp.full((B,), index, jnp.int32),
            interpret=True)
        want = ref.mla_decode_ref(q, ckv_c, krope_c, index)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_paged_kernel_ignores_unreferenced_pages():
    """Poisoning pool blocks outside the table must not change results."""
    B, H, Dl, Dr, bs, nb, N = 1, 4, 32, 8, 8, 2, 6
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, H, Dl + Dr))
    ckv = rand(ks[1], (N, bs, Dl))
    krope = rand(ks[2], (N, bs, Dr))
    bt = jnp.asarray([[2, 4]], jnp.int32)
    idx = jnp.asarray([11], jnp.int32)
    out = mla_decode_paged_kernel(q, ckv, krope, bt, idx, interpret=True)
    poisoned = [i for i in range(N) if i not in (2, 4)]
    out_p = mla_decode_paged_kernel(
        q, ckv.at[jnp.asarray(poisoned)].set(1e4),
        krope.at[jnp.asarray(poisoned)].set(1e4), bt, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p), atol=1e-6)


# ------------------------------------------------------------- core level --


def _filled_caches(params, lengths, S, bs, nb, N, seed=0):
    """Build per-request contiguous caches AND an equivalent paged pool
    with a scrambled block table from the same token history."""
    rng = np.random.default_rng(seed)
    B = len(lengths)
    hist = jnp.asarray(rng.standard_normal((B, S, MCFG.d_model)) * 0.1,
                       jnp.float32)
    pool = cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                       MCFG.qk_rope_dim, jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb),
                     jnp.int32)
    caches = []
    for b in range(B):
        c = cachelib.latent_cache(1, S, MCFG.kv_lora_rank, MCFG.qk_rope_dim,
                                  jnp.float32)
        L = int(lengths[b])
        if L:
            pos = jnp.arange(L)[None]
            ckv, krope = mlalib._kv_latent(params, MCFG, hist[b:b + 1, :L],
                                           pos)
            c = cachelib.update_latent(c, ckv, krope, 0)
            for t in range(L):
                pool = cachelib.update_latent_paged(
                    pool, bt[b:b + 1], jnp.asarray([t], jnp.int32),
                    ckv[:, t], krope[:, t])
        caches.append(c)
    return caches, pool, bt


@pytest.mark.parametrize("scheme", mlalib.SCHEMES)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_mla_decode_paged_equals_contiguous(scheme, use_kernel):
    """The acceptance criterion: paged decode allclose-equal to the
    contiguous path for naive/seq/rc/ru at ragged per-request lengths."""
    if scheme == "naive" and use_kernel:
        pytest.skip("naive has no kernel path (paper's strawman)")
    bs, nb, N = 8, 4, 20
    S = bs * nb
    lengths = np.asarray([5, 17, 0, S - 1], np.int32)
    B = len(lengths)
    params = nnm.init_params(jax.random.PRNGKey(0), mlalib.mla_defs(MCFG),
                             jnp.float32)
    params = mlalib.prepare_serving(params, MCFG, "ru")
    caches, pool, bt = _filled_caches(params, lengths, S, bs, nb, N)
    x_t = rand(jax.random.PRNGKey(7), (B, MCFG.d_model)) * 0.1

    want, caches_after = [], []
    for b in range(B):
        o, c2 = mlalib.mla_decode(params, MCFG, x_t[b:b + 1],
                                  dict(caches[b]), int(lengths[b]),
                                  scheme=scheme)
        want.append(np.asarray(o[0]))
        caches_after.append(c2)

    decode_kernel = None
    if use_kernel:
        def decode_kernel(q_full, ckv, krope, tables, idx, softmax_scale):
            return kops.mla_decode_paged_attention(
                q_full, ckv, krope, tables, idx, impl="kernel",
                softmax_scale=softmax_scale)
    got, pool2 = mlalib.mla_decode_paged(params, MCFG, x_t, pool, bt,
                                         lengths, scheme=scheme,
                                         decode_kernel=decode_kernel)
    np.testing.assert_allclose(np.asarray(got), np.stack(want),
                               atol=2e-5, rtol=2e-5)
    # and the new token landed at the right (page, slot), matching the
    # contiguous cache write
    for b in range(B):
        L = int(lengths[b])
        page = int(bt[b, L // bs])
        np.testing.assert_allclose(
            np.asarray(pool2["ckv"][page, L % bs]),
            np.asarray(caches_after[b]["ckv"][0, L]), atol=2e-6)
        np.testing.assert_allclose(
            np.asarray(pool2["krope"][page, L % bs]),
            np.asarray(caches_after[b]["krope"][0, L]), atol=2e-6)


def test_gather_scatter_roundtrip():
    pool = cachelib.paged_latent_cache(8, 4, 16, 8, jnp.float32)
    bt = jnp.asarray([[3, 1], [5, 2]], jnp.int32)
    for t in range(6):
        pool = cachelib.update_latent_paged(
            pool, bt, jnp.asarray([t, t], jnp.int32),
            jnp.full((2, 16), float(t)), jnp.full((2, 8), float(-t)))
    ckv, krope = cachelib.gather_latent_paged(pool, bt)
    for t in range(6):
        np.testing.assert_allclose(np.asarray(ckv[:, t]), float(t))
        np.testing.assert_allclose(np.asarray(krope[:, t]), float(-t))


# -------------------------------------------------------------- scheduler --


def test_allocator_reserves_null_and_refuses_overdraw():
    a = BlockAllocator(5)
    got = a.alloc(4)
    assert sorted(got) == [1, 2, 3, 4]       # block 0 never handed out
    assert NULL_BLOCK not in got
    assert a.alloc(1) is None                # overdraw refused, no change
    a.free([2, 3])
    assert a.num_free == 2
    with pytest.raises(ValueError):
        a.free([2])                          # double free detected
    with pytest.raises(ValueError):
        a.free([0])                          # null block is unfreeable


def test_scheduler_admission_refusal_and_reuse():
    # pool: 4 usable blocks of 4 tokens; each request needs 2 blocks
    s = ContinuousScheduler(num_blocks=5, block_size=4, max_batch=3)
    reqs = [Request(rid=i, prompt=np.arange(5, dtype=np.int32), max_new=3)
            for i in range(3)]
    for r in reqs:
        s.submit(r)
    admitted = s.try_admit()
    # 5-token prompt + 1 => 2 blocks each => only 2 of 3 fit
    assert [r.rid for _, r in admitted] == [0, 1]
    assert s.allocator.num_free == 0
    assert len(s.waiting) == 1               # head refused, stays queued
    # finishing request 0 frees its blocks; request 2 reuses them
    slot0 = admitted[0][0]
    blocks0 = set(s.blocks_of[slot0])
    s.slots[slot0].tokens = [1, 2]
    s.advance({slot0: 9})                    # third token -> done
    assert s.slots[slot0] is None
    assert (s.block_table[slot0] == NULL_BLOCK).all()
    assert s.lengths[slot0] == 0
    admitted2 = s.try_admit()
    assert [r.rid for _, r in admitted2] == [2]
    assert set(s.blocks_of[slot0]) == blocks0      # block reuse
    assert not s.waiting


def test_scheduler_grows_blocks_and_preempts():
    s = ContinuousScheduler(num_blocks=4, block_size=2, max_batch=2)
    a = Request(rid=0, prompt=np.zeros(1, np.int32), max_new=8)
    b = Request(rid=1, prompt=np.zeros(1, np.int32), max_new=8)
    s.submit(a), s.submit(b)
    assert len(s.try_admit()) == 2           # 1 block each, 1 spare
    s.record_prefill_sample(0, 5)
    s.record_prefill_sample(1, 5)
    s.advance({0: 5})                        # only a crosses the boundary
    assert int(s.lengths[0]) == 2 and int(s.lengths[1]) == 1
    pre = s.ensure_step_capacity()
    assert pre == [] and len(s.blocks_of[0]) == 2   # grew from the spare
    # now b crosses too; the pool is dry -> youngest (b) is preempted
    s.advance({0: 5, 1: 5})
    pre = s.ensure_step_capacity()
    assert [r.rid for r in pre] == [1]
    assert s.slots[1] is None and len(s.waiting) == 1
    w = s.waiting[0]
    assert w.n_preempted == 1 and w.tokens == []
    assert w.plen == 3                       # 1 prompt + 2 generated folded
    assert w.max_new == 8 - 2
    # the oldest request kept its blocks and keeps making progress
    assert len(s.blocks_of[0]) == 2 and s.slots[0] is a


def test_scheduler_prefill_sample_finishes_max_new_1():
    s = ContinuousScheduler(num_blocks=4, block_size=4, max_batch=1)
    s.submit(Request(rid=0, prompt=np.zeros(2, np.int32), max_new=1))
    (slot, req), = s.try_admit()
    done = s.record_prefill_sample(slot, 7)
    assert done is req and req.output == [7]
    assert s.all_done and s.allocator.num_free == 3


# ----------------------------------------------------- model/engine level --


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _contiguous_greedy(cfg, params, prompt, max_new):
    """Per-request contiguous prefill+decode (the pre-PR serving path)."""
    from repro.launch.serve import _prepare_mla
    params = _prepare_mla(params, cfg, "seq")
    capacity = len(prompt) + max_new + 1
    prefill = make_prefill_step(cfg, None, batch=1, capacity=capacity,
                                compute_dtype=jnp.float32, scheme="seq")
    step = make_serve_step(cfg, None, compute_dtype=jnp.float32,
                           scheme="seq")
    logits, cache = prefill(params, jnp.asarray(prompt, jnp.int32)[None])
    out = [int(jnp.argmax(logits[0]))]
    for i in range(max_new - 1):
        logits, cache = step(params, jnp.asarray(out[-1:], jnp.int32),
                             cache, len(prompt) + i)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_engine_tokens_match_contiguous(smoke_model):
    """End-to-end: ragged requests admitted mid-generation through the
    paged engine produce exactly the greedy tokens of the per-request
    contiguous path."""
    cfg, params = smoke_model
    rng = np.random.default_rng(3)
    specs = [(8, 5, 0), (12, 3, 1), (4, 7, 4)]    # (plen, gen, arrival)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (p,)).astype(np.int32),
                    max_new=g, arrival=a)
            for i, (p, g, a) in enumerate(specs)]
    eng = PagedMLAEngine(cfg, params, num_blocks=20, block_size=8,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="seq")
    summary = eng.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                               max_new=r.max_new, arrival=r.arrival)
                       for r in reqs])
    assert len(eng.sched.finished) == len(reqs)
    assert summary["mid_gen_admissions"] >= 1     # continuous batching
    by_rid = {r.rid: r for r in eng.sched.finished}
    for r in reqs:
        want = _contiguous_greedy(cfg, params, r.prompt, r.max_new)
        assert by_rid[r.rid].output == want, f"request {r.rid}"


def test_engine_auto_dispatch_runs(smoke_model):
    cfg, params = smoke_model
    rng = np.random.default_rng(5)
    eng = PagedMLAEngine(cfg, params, num_blocks=16, block_size=8,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="auto", platform=PLATFORMS["tpu_v5e"])
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new=3, arrival=0) for i in range(2)]
    summary = eng.run(reqs)
    used = sum(summary["schemes_used"].values())
    assert 0 < used <= summary["steps"]
    assert set(summary["schemes_used"]) <= {"seq", "rc", "ru"}


def _run_outputs(cfg, params, reqs, *, num_blocks=40, **kw):
    eng = PagedMLAEngine(cfg, params, num_blocks=num_blocks, block_size=4,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="seq", prefill_chunk=5, **kw)
    eng.run([Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                     arrival=r.arrival) for r in reqs])
    return eng, {r.rid: r.output for r in eng.sched.finished}


def test_engine_pallas_prefill_token_identical(smoke_model):
    """End-to-end drive of the Pallas chunked-prefill path: the engine
    with impl='pallas' (kernel prefill AND kernel decode) produces
    token-identical outputs to the reference gather path, under greedy
    and under seeded temperature/top-k sampling."""
    cfg, params = smoke_model
    rng = np.random.default_rng(17)
    pre = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [pre, rng.integers(0, cfg.vocab, (p,)).astype(np.int32)]),
                    max_new=g, arrival=2 * i)
            for i, (p, g) in enumerate([(5, 4), (9, 3), (3, 5)])]
    _, outs_ref = _run_outputs(cfg, params, reqs, prefill_impl="gather")
    eng, outs_pal = _run_outputs(cfg, params, reqs, impl="pallas")
    assert outs_pal == outs_ref
    assert eng.stats.prefill_chunks > 0 and eng.prefill_compiles == 1
    # seeded sampling: same PRNG stream regardless of the prefill impl
    kw = dict(temperature=0.8, top_k=5, sample_seed=3)
    _, s_ref = _run_outputs(cfg, params, reqs, prefill_impl="gather", **kw)
    _, s_pal = _run_outputs(cfg, params, reqs, prefill_impl="pallas", **kw)
    assert s_pal == s_ref


def test_engine_pallas_prefill_survives_preemption_replay(smoke_model):
    """Recompute-preemption replay re-prefills through the Pallas kernel
    (the replayed prompt re-hits the prefix cache): outputs must match a
    preemption-free run exactly, under seeded sampling."""
    cfg, params = smoke_model
    rng = np.random.default_rng(19)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new=10) for i in range(2)]
    kw = dict(prefill_impl="pallas", temperature=0.7, top_k=8, sample_seed=1)
    _, big = _run_outputs(cfg, params, reqs, num_blocks=40, **kw)
    # 6 usable blocks of 4 tokens cannot hold 2 x (6 prompt + 10 gen):
    # the youngest request must be preempted and replayed
    small_eng, small = _run_outputs(cfg, params, reqs, num_blocks=7, **kw)
    assert small_eng.stats.preemptions > 0
    assert small == big


# ---------------------------------------------------------------- hwmodel --


def test_paged_cost_terms():
    base = ac.mla_decode_cost(ac.DSV3_MLA, scheme="seq", cache_len=1000,
                              batch=4)
    paged = ac.mla_decode_cost(ac.DSV3_MLA, scheme="seq", cache_len=1000,
                               batch=4, paged_block=128)
    assert "B:block_table" in paged.breakdown
    # whole-block reads: 1000 rounds up to 8 blocks x 128 = 1024 tokens
    ratio = paged.breakdown["B:cache_read"] / base.breakdown["B:cache_read"]
    assert ratio == pytest.approx(1024 / 1000)
    assert paged.bytes > base.bytes
    assert paged.flops == base.flops          # paging is a bytes-only term


def test_auto_dispatch_accepts_paged_block():
    from repro.core.schemes import auto_dispatch
    s = auto_dispatch(ac.DSV3_MLA, PLATFORMS["tpu_v5e"], cache_len=4096,
                      batch=8, paged_block=64)
    assert s in ("seq", "rc", "ru")
