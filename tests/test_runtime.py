"""Runtime: fault-tolerant loop (auto-resume bitwise equality, straggler
re-dispatch), loss-goes-down integration, serve path."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as models
from repro.data import DataConfig, SyntheticLM
from repro.models.common import ModelConfig
from repro.nn import module as nnm
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import (LoopConfig, SimulatedFailure, TrainLoop,
                           TrainStepConfig, make_prefill_step,
                           make_serve_step, make_train_step)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, remat=False)
OPT = AdamWConfig(lr=1e-3)


def fresh():
    p = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(CFG),
                        jnp.float32)
    return p, adamw_init(p, OPT)


def data():
    return SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=8))


@pytest.fixture
def step_fn():
    return make_train_step(CFG, None, OPT,
                           TrainStepConfig(compute_dtype=jnp.float32))[0]


def test_loss_decreases():
    hot = make_train_step(CFG, None, AdamWConfig(lr=3e-3),
                          TrainStepConfig(compute_dtype=jnp.float32))[0]
    p, o = fresh()
    d = data()
    losses = []
    for _ in range(25):
        toks, labels = d.next_batch()
        p, o, m = hot(p, o, {"tokens": jnp.asarray(toks),
                             "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
    assert sum(losses[-5:]) / 5 < sum(losses[:5]) / 5 - 0.05


def test_resume_after_failure_is_bitwise_identical(step_fn, tmp_path):
    a_dir, b_dir = str(tmp_path / "a"), str(tmp_path / "b")
    p, o = fresh()
    la = TrainLoop(LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=a_dir,
                              log_every=0, async_save=False),
                   step_fn, p, o, data(), log=lambda *_: None)
    la.run()
    p, o = fresh()
    lb = TrainLoop(LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=b_dir,
                              log_every=0, async_save=False, fail_at_step=4),
                   step_fn, p, o, data(), log=lambda *_: None)
    with pytest.raises(SimulatedFailure):
        lb.run()
    p, o = fresh()   # relaunch from scratch: must auto-resume at step 4
    lb2 = TrainLoop(LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=b_dir,
                               log_every=0, async_save=False),
                    step_fn, p, o, data(), log=lambda *_: None)
    assert lb2.step == 4
    lb2.run()
    for x, y in zip(jax.tree.leaves(la.params), jax.tree.leaves(lb2.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_straggler_redispatch(tmp_path):
    """A step exceeding deadline_factor x median is re-dispatched once."""
    calls = {"n": 0}

    def slow_step(params, opt, batch):
        calls["n"] += 1
        if calls["n"] == 12:      # one straggler after warmup
            time.sleep(0.3)
        return params, opt, {"loss": jnp.float32(1.0)}

    p, o = fresh()
    loop = TrainLoop(LoopConfig(total_steps=14, ckpt_every=100,
                                ckpt_dir=str(tmp_path), log_every=0,
                                straggler_factor=5.0, straggler_warmup=8),
                     slow_step, p, o, data(), log=lambda *_: None)
    loop.run()
    assert len(loop.straggler_events) >= 1
    assert calls["n"] == 14 + len(loop.straggler_events)


def test_serve_prefill_decode_roundtrip():
    p, _ = fresh()
    prefill = make_prefill_step(CFG, None, batch=2, capacity=20,
                                compute_dtype=jnp.float32)
    step = make_serve_step(CFG, None, compute_dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, CFG.vocab)
    logits, cache = prefill(p, toks)
    assert logits.shape == (2, CFG.vocab)
    for i in range(4):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = step(p, tok, cache, 8 + i)
    assert not bool(jnp.isnan(logits).any())


def test_microbatched_train_matches_full_batch():
    """Grad accumulation over 2 microbatches == single big batch (mean)."""
    p, o = fresh()
    full, _ = make_train_step(CFG, None, OPT,
                              TrainStepConfig(compute_dtype=jnp.float32))
    micro, _ = make_train_step(CFG, None, OPT,
                               TrainStepConfig(compute_dtype=jnp.float32,
                                               microbatches=2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, CFG.vocab)
    lbls = jax.random.randint(jax.random.PRNGKey(4), (8, 16), 0, CFG.vocab)
    b = {"tokens": toks, "labels": lbls}
    p1, _, m1 = full(p, o, b)
    p2, _, m2 = micro(*fresh(), b)
    assert float(m1["ce"]) == pytest.approx(float(m2["ce"]), rel=1e-5)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)
