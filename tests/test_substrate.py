"""Substrate tests: data pipeline, optimizer, compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep: property-based sweeps")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticLM
from repro.optim import (AdamWConfig, TopKConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress, compression_ratio,
                         cosine, init_error)


# ------------------------------------------------------------------ data ---


def test_data_deterministic_replay():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=4, seed=3)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for _ in range(3):
        (ta, la), (tb, lb) = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ta, tb)
        np.testing.assert_array_equal(la, lb)


def test_data_state_restore_midstream():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    a = SyntheticLM(cfg)
    for _ in range(5):
        a.next_batch()
    state = a.state_dict()
    want = a.next_batch()
    b = SyntheticLM(cfg)
    b.load_state_dict(state)
    got = b.next_batch()
    np.testing.assert_array_equal(got[0], want[0])


@settings(max_examples=10, deadline=None)
@given(n_hosts=st.sampled_from([1, 2, 4]), step=st.integers(0, 20))
def test_data_shard_invariance(n_hosts, step):
    """Global batch content is a pure fn of (seed, step) — independent of
    how many hosts shard it (elastic restart property)."""
    ref_cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1)
    from repro.data.pipeline import _batch_at
    ref = _batch_at(ref_cfg, step, host_id=0)  # full batch, 1 host
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1,
                     n_hosts=n_hosts)
    got = np.concatenate(
        [_batch_at(cfg, step, host_id=h) for h in range(n_hosts)], axis=0)
    np.testing.assert_array_equal(got, ref)


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    toks, labels = SyntheticLM(cfg).next_batch()
    assert toks.shape == labels.shape == (2, 8)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


# ----------------------------------------------------------------- optim ---


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params, cfg)
    target = jnp.array([1.0, 2.0, 3.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_adamw_bf16_moments_track_fp32():
    cfg32 = AdamWConfig(lr=1e-2, weight_decay=0.0)
    cfg16 = AdamWConfig(lr=1e-2, weight_decay=0.0, moment_dtype=jnp.bfloat16)
    p32 = {"w": jnp.ones((8,))}
    p16 = {"w": jnp.ones((8,))}
    s32, s16 = adamw_init(p32, cfg32), adamw_init(p16, cfg16)
    assert s16["mu"]["w"].dtype == jnp.bfloat16
    for i in range(20):
        g = {"w": jnp.sin(jnp.arange(8.0) + i)}
        p32, s32, _ = adamw_update(cfg32, p32, g, s32)
        p16, s16, _ = adamw_update(cfg16, p16, g, s16)
    assert float(jnp.max(jnp.abs(p32["w"] - p16["w"]))) < 0.05


def test_cosine_schedule_shape():
    fn = cosine(1.0, warmup=10, total=100)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# ----------------------------------------------------------- compression ---


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), frac=st.sampled_from([0.01, 0.1, 0.5]))
def test_topk_error_feedback_invariant(seed, frac):
    """sent + new_error == grads + old_error (nothing is lost)."""
    cfg = TopKConfig(fraction=frac, min_elems=16)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64, 32))}
    err = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (64, 32)) * 0.1}
    sent, new_err = compress(cfg, g, err)
    np.testing.assert_allclose(np.asarray(sent["w"] + new_err["w"]),
                               np.asarray(g["w"] + err["w"]), atol=1e-6)
    # sparsity: at most ~frac of entries transmitted (ties may add a few)
    nnz = float(jnp.mean((sent["w"] != 0).astype(jnp.float32)))
    assert nnz <= frac * 1.5 + 1e-3


def test_topk_small_leaves_pass_through():
    cfg = TopKConfig(fraction=0.01, min_elems=1024)
    g = {"b": jnp.arange(8.0)}
    sent, err = compress(cfg, g, init_error(g))
    np.testing.assert_array_equal(np.asarray(sent["b"]), np.asarray(g["b"]))
    assert float(jnp.abs(err["b"]).max()) == 0.0


def test_compression_ratio():
    params = {"w": jnp.zeros((4096, 64)), "b": jnp.zeros((8,))}
    r = compression_ratio(TopKConfig(fraction=0.01, min_elems=1024), params)
    assert 0.01 < r < 0.03


# ------------------------------------------------------------ checkpoint ---


def test_checkpoint_roundtrip_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones((4,))}}
    for s in (1, 2, 3):
        store.save(s, tree, data_state={"step": s}, blocking=True)
    assert store.steps() == [2, 3]          # keep-2 GC
    got, meta = store.restore(3, jax.tree.map(np.asarray, tree))
    np.testing.assert_array_equal(got["a"], np.asarray(tree["a"]))
    assert meta["data_state"]["step"] == 3


def test_checkpoint_skips_partial_saves(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=5)
    store.save(1, {"x": jnp.ones(3)}, blocking=True)
    # simulate a crash mid-save: directory without the done marker
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "meta.json").write_text("{}")
    assert store.latest_step() == 1


def test_checkpoint_async(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3)
    store.save(7, {"x": jnp.full((128, 128), 3.0)}, blocking=False)
    store.wait()
    got, _ = store.restore(7, {"x": np.zeros((128, 128))})
    assert float(got["x"][0, 0]) == 3.0


def test_checkpoint_elastic_sharding_hook(tmp_path):
    """restore() re-device_puts with caller-provided shardings."""
    store = CheckpointStore(str(tmp_path), keep=1)
    store.save(1, {"x": jnp.arange(8.0)}, blocking=True)
    dev = jax.devices()[0]
    got, _ = store.restore(
        1, {"x": np.zeros(8)},
        sharding_for=lambda path, v: jax.sharding.SingleDeviceSharding(dev))
    assert got["x"].sharding.device_set == {dev}
