"""Quantized latent-KV block pool + AMLA exponent-add rescaling, gated
by the fp32 oracle (PR 8).

Every quantized kernel path is held against TWO references:

  1. the fp32 oracle on the exact (pre-quantization) latents — the
     committed per-dtype max-logit-error bounds in ``ORACLE_TOL`` bound
     the QUANTIZATION error end to end;
  2. the quantized oracle (``ref.mla_*_paged_ref`` with scales, which
     dequantizes the gathered f32 view) — ``KERNEL_TOL`` bounds the
     KERNEL error separately, so a kernel bug cannot hide inside the
     quantization budget.

Sweeps: schemes x decode/prefill x storage dtypes x ragged lengths x
adversarial block tables (null blocks, inactive slots, stale entries
outside the table).  The AMLA section pins the exp-add online-softmax
rescaling against the classic multiply path and the chunk-1 ==
decode-kernel triangle identity; hypothesis drives quantize/dequant
round-trip error and per-block scale invariants under CoW
fork/release.  Everything runs on CPU via interpret mode — the
``kernel`` marker wires the module into the CI kernel lane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.core import cache as cachelib
from repro.core import mla as mlalib
from repro.core import schemes as schemeslib
from repro.hwmodel import attention_costs as ac
from repro.hwmodel.platforms import PLATFORMS
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.mla_decode import (RESCALES, exp_add_rescale,
                                      mla_decode_paged_kernel)
from repro.kernels.mla_prefill import mla_prefill_paged_kernel
from repro.nn import module as nnm
from repro.obs import Telemetry
from repro.obs.drift import RooflineDrift
from repro.runtime import BlockAllocator, PagedMLAEngine, Request

pytestmark = pytest.mark.kernel

CACHE_DTYPES = ("int8",) + (("fp8",) if hasattr(jnp, "float8_e4m3fn") else ())

# Committed kernel-vs-fp32-oracle max-logit-error bounds per storage
# dtype (unit-normal latents; measured int8 ~7e-3, fp8 ~7e-2 — the
# bounds leave ~3-5x headroom without letting a broken dequant through).
ORACLE_TOL = {"int8": 5e-2, "fp8": 2e-1}
# kernel vs the QUANTIZED oracle on identical inputs (pure kernel error;
# measured ~5e-7)
KERNEL_TOL = 2e-5
# exp-add vs classic-mul online softmax (measured ~2e-7)
RESCALE_TOL = 1e-5

MCFG = mlalib.MLAConfig(d_model=64, n_heads=4, q_lora_rank=48,
                        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16)


def _qinfo(name):
    return cachelib.cache_dtype_info(name)


def _latents(N, bs, Dl, Dr, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    ckv = jax.random.normal(ks[0], (N, bs, Dl), jnp.float32)
    krope = jax.random.normal(ks[1], (N, bs, Dr), jnp.float32)
    return ckv, krope


def _quantize(ckv, krope, cache_dtype):
    qdtype, qmax = _qinfo(cache_dtype)
    cq, cs = cachelib.quantize_latent(ckv, qmax, qdtype)
    rq, rs = cachelib.quantize_latent(krope, qmax, qdtype)
    return cq, cs, rq, rs


# ------------------------------------------- kernel vs fp32 oracle: decode --


@pytest.mark.parametrize("B,H,Dl,Dr,bs,nb,N,idx,table", [
    # plain ragged batch, scrambled table
    (3, 4, 32, 8, 8, 4, 16, [5, 31, 12], "scrambled"),
    # adversarial: NULL blocks interleaved in the table + inactive slot
    (2, 4, 32, 8, 8, 4, 12, [17, -1], "null_holes"),
    # stale entries: table points at blocks holding garbage BEYOND each
    # request's valid extent (must be masked, not dequantized into play)
    (2, 8, 64, 16, 4, 3, 10, [0, 9], "stale"),
])
@pytest.mark.parametrize("cache_dtype", CACHE_DTYPES)
def test_decode_kernel_vs_fp32_oracle(B, H, Dl, Dr, bs, nb, N, idx, table,
                                      cache_dtype):
    ckv, krope = _latents(N, bs, Dl, Dr, seed=B + N)
    q = jax.random.normal(jax.random.PRNGKey(7), (B, H, Dl + Dr),
                          jnp.float32)
    rng = np.random.default_rng(3)
    if table == "scrambled":
        bt = rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb)
    elif table == "null_holes":
        bt = rng.integers(1, N, (B, nb))
        bt[:, 1] = 0                      # a NULL block mid-table
    else:  # stale: poison everything outside the table
        bt = rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb)
        outside = np.setdiff1d(np.arange(N), bt.ravel())
        ckv = ckv.at[jnp.asarray(outside)].set(1e4)
        krope = krope.at[jnp.asarray(outside)].set(1e4)
    bt = jnp.asarray(bt, jnp.int32)
    idx = jnp.asarray(idx, jnp.int32)
    oracle = ref.mla_decode_paged_ref(q, ckv, krope, bt, idx)
    cq, cs, rq, rs = _quantize(ckv, krope, cache_dtype)
    got = mla_decode_paged_kernel(q, cq, rq, bt, idx, ckv_scales=cs,
                                  krope_scales=rs, interpret=True)
    err = float(jnp.max(jnp.abs(got - oracle)))
    assert err <= ORACLE_TOL[cache_dtype], (cache_dtype, err)
    # the kernel must agree with the quantized oracle far tighter — the
    # bound above is quantization error, not kernel slack
    qref = ref.mla_decode_paged_ref(q, cq, rq, bt, idx, ckv_scales=cs,
                                    krope_scales=rs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(qref),
                               atol=KERNEL_TOL, rtol=KERNEL_TOL)


@pytest.mark.parametrize("B,C,H,Dl,Dr,bs,nb,N,lengths,n_valid", [
    (3, 6, 4, 32, 8, 4, 8, 16, [0, 5, 11], [6, 3, 0]),   # ragged + idle row
    (2, 4, 4, 32, 8, 8, 3, 8, [8, 15], [4, 1]),  # boundary start + 1-tail
])
@pytest.mark.parametrize("cache_dtype", CACHE_DTYPES)
def test_prefill_kernel_vs_fp32_oracle(B, C, H, Dl, Dr, bs, nb, N, lengths,
                                       n_valid, cache_dtype):
    ckv, krope = _latents(N, bs, Dl, Dr, seed=11)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, C, H, Dl + Dr),
                          jnp.float32)
    rng = np.random.default_rng(9)
    bt = rng.integers(1, N, (B, nb))
    bt[0, -1] = 0                         # null tail block
    bt = jnp.asarray(bt, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    oracle = ref.mla_prefill_paged_ref(q, ckv, krope, bt, lengths, n_valid)
    cq, cs, rq, rs = _quantize(ckv, krope, cache_dtype)
    got = mla_prefill_paged_kernel(q, cq, rq, bt, lengths, n_valid,
                                   ckv_scales=cs, krope_scales=rs,
                                   interpret=True)
    err = float(jnp.max(jnp.abs(got - oracle)))
    assert err <= ORACLE_TOL[cache_dtype], (cache_dtype, err)
    qref = ref.mla_prefill_paged_ref(q, cq, rq, bt, lengths, n_valid,
                                     ckv_scales=cs, krope_scales=rs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(qref),
                               atol=KERNEL_TOL, rtol=KERNEL_TOL)


# ------------------------------------------------ scheme sweep, core level --


def _scatter_history(pool, bt, ckv_hist, krope_hist):
    """Scatter a (B, S, D) latent history token-by-token through the
    production write path (exercises quantize-on-write for quantized
    pools).  Every row fills its whole table — content past a request's
    ragged length is exactly the stale garbage attention must mask."""
    B, S = ckv_hist.shape[:2]
    for t in range(S):
        pool = cachelib.update_latent_paged(
            pool, bt, jnp.full((B,), t, jnp.int32), ckv_hist[:, t],
            krope_hist[:, t])
    return pool


@pytest.fixture(scope="module")
def mla_params():
    params = nnm.init_params(jax.random.PRNGKey(0), mlalib.mla_defs(MCFG),
                             jnp.float32)
    return mlalib.prepare_serving(params, MCFG, "ru")


@pytest.mark.parametrize("scheme", ["seq", "rc", "ru", "naive"])
@pytest.mark.parametrize("cache_dtype", CACHE_DTYPES)
def test_decode_schemes_quantized_pool_vs_fp32(scheme, cache_dtype,
                                               mla_params):
    """Full decode layer over a quantize-on-write pool, every scheme, vs
    the same layer over the exact f32 pool.  The kernel path covers
    seq/rc/ru; naive exercises the gathered-view dequant path."""
    bs, nb, N = 4, 3, 12
    lengths = np.asarray([3, 11, 7], np.int32)
    B, S = len(lengths), bs * nb
    rng = np.random.default_rng(21)
    hist = jnp.asarray(rng.standard_normal((B, S, MCFG.d_model)) * 0.1,
                       jnp.float32)
    pos = jnp.arange(S)[None].repeat(B, 0)
    ckv_h, krope_h = mlalib._kv_latent(mla_params, MCFG, hist, pos)
    bt = jnp.asarray(rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb),
                     jnp.int32)
    pool_f = _scatter_history(
        cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                    MCFG.qk_rope_dim, jnp.float32),
        bt, ckv_h, krope_h)
    pool_q = _scatter_history(
        cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                    MCFG.qk_rope_dim, jnp.float32,
                                    cache_dtype=cache_dtype),
        bt, ckv_h, krope_h)
    qdtype, _ = _qinfo(cache_dtype)
    assert pool_q["ckv"].dtype == qdtype
    x_t = jax.random.normal(jax.random.PRNGKey(3), (B, MCFG.d_model),
                            jnp.float32) * 0.1

    decode_kernel = None
    if scheme != "naive":
        def decode_kernel(q_full, ckv, krope, tables, idx, softmax_scale,
                          **qkw):
            return kops.mla_decode_paged_attention(
                q_full, ckv, krope, tables, idx, impl="kernel",
                softmax_scale=softmax_scale, **qkw)
    want, _ = mlalib.mla_decode_paged(mla_params, MCFG, x_t, pool_f, bt,
                                      lengths, scheme=scheme)
    got, pool_q2 = mlalib.mla_decode_paged(mla_params, MCFG, x_t, pool_q, bt,
                                           lengths, scheme=scheme,
                                           decode_kernel=decode_kernel)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=ORACLE_TOL[cache_dtype],
                               rtol=ORACLE_TOL[cache_dtype])
    # the write-back stayed quantized and refreshed the written scales
    assert pool_q2["ckv"].dtype == qdtype
    for b in range(B):
        L = int(lengths[b])
        page, slot = int(bt[b, L // bs]), L % bs
        s = float(pool_q2["ckv_scale"][page, slot, 0])
        amax = float(jnp.max(jnp.abs(
            cachelib.dequantize_latent(pool_q2["ckv"], pool_q2["ckv_scale"])
            [page, slot])))
        assert s > 0 and (amax == 0 or s == pytest.approx(
            amax / _qinfo(cache_dtype)[1], rel=0.2))


@pytest.mark.parametrize("scheme", ["seq", "rc", "ru"])
@pytest.mark.parametrize("cache_dtype", CACHE_DTYPES)
def test_prefill_schemes_quantized_pool_vs_fp32(scheme, cache_dtype,
                                                mla_params):
    """Chunked prefill through the Pallas kernel over a quantized pool,
    every kernel scheme, vs the f32 gather reference."""
    bs, nb, N, C = 4, 3, 10, 5
    lengths = np.asarray([0, 4], np.int32)
    n_valid = np.asarray([5, 3], np.int32)
    B = len(lengths)
    rng = np.random.default_rng(33)
    x = jnp.asarray(rng.standard_normal((B, C, MCFG.d_model)) * 0.1,
                    jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, N))[:B * nb].reshape(B, nb),
                     jnp.int32)
    pool_f = cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                         MCFG.qk_rope_dim, jnp.float32)
    pool_q = cachelib.paged_latent_cache(N, bs, MCFG.kv_lora_rank,
                                         MCFG.qk_rope_dim, jnp.float32,
                                         cache_dtype=cache_dtype)
    want, _ = mlalib.mla_prefill_chunk_paged(
        mla_params, MCFG, x, pool_f, bt, lengths, n_valid, scheme=scheme,
        impl="gather")
    got, pool_q2 = mlalib.mla_prefill_chunk_paged(
        mla_params, MCFG, x, pool_q, bt, lengths, n_valid, scheme=scheme,
        impl="pallas")
    # idle tail rows are garbage by contract: compare valid rows only
    for b in range(B):
        nv = int(n_valid[b])
        np.testing.assert_allclose(np.asarray(got[b, :nv]),
                                   np.asarray(want[b, :nv]),
                                   atol=ORACLE_TOL[cache_dtype],
                                   rtol=ORACLE_TOL[cache_dtype])
    assert pool_q2["ckv"].dtype == _qinfo(cache_dtype)[0]


# --------------------------------------------------------- AMLA rescaling --


def test_exp_add_rescale_is_exact_power_of_two_scaling():
    x = jnp.asarray([1.5, -3.25, 0.0, 2.0 ** -126, 1e30], jnp.float32)
    d = jnp.asarray([-3, -1, -4, -5, -20], jnp.int32)
    got = exp_add_rescale(x, d)
    # zero stays zero; exponent underflow flushes to zero (2**-126 has
    # biased exponent 1: any d <= -1 underflows)
    want = np.asarray([1.5 * 2.0 ** -3, -3.25 * 0.5, 0.0, 0.0,
                       1e30 * 2.0 ** -20], np.float32)
    np.testing.assert_array_equal(np.asarray(got), want)
    # d = 0 is the identity
    np.testing.assert_array_equal(
        np.asarray(exp_add_rescale(x, jnp.zeros_like(d))), np.asarray(x))


@pytest.mark.parametrize("cache_dtype", (None,) + CACHE_DTYPES)
def test_decode_exp_add_matches_mul(cache_dtype):
    """The AMLA exponent-add correction agrees with the classic
    FlashAttention multiply path on the decode kernel, quantized or not."""
    B, H, Dl, Dr, bs, nb, N = 3, 4, 32, 8, 8, 4, 16
    ckv, krope = _latents(N, bs, Dl, Dr, seed=2)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, Dl + Dr),
                          jnp.float32)
    rng = np.random.default_rng(5)
    bt = jnp.asarray(rng.integers(0, N, (B, nb)), jnp.int32)
    idx = jnp.asarray([31, 0, 12], jnp.int32)
    kw = {}
    if cache_dtype is not None:
        cq, cs, rq, rs = _quantize(ckv, krope, cache_dtype)
        ckv, krope = cq, rq
        kw = dict(ckv_scales=cs, krope_scales=rs)
    outs = {r: mla_decode_paged_kernel(q, ckv, krope, bt, idx, rescale=r,
                                       interpret=True, **kw)
            for r in RESCALES}
    np.testing.assert_allclose(np.asarray(outs["exp_add"]),
                               np.asarray(outs["mul"]),
                               atol=RESCALE_TOL, rtol=RESCALE_TOL)


@pytest.mark.parametrize("cache_dtype", (None,) + CACHE_DTYPES)
def test_prefill_exp_add_matches_mul(cache_dtype):
    B, C, H, Dl, Dr, bs, nb, N = 2, 6, 4, 32, 8, 4, 6, 12
    ckv, krope = _latents(N, bs, Dl, Dr, seed=4)
    q = jax.random.normal(jax.random.PRNGKey(6), (B, C, H, Dl + Dr),
                          jnp.float32)
    rng = np.random.default_rng(8)
    bt = jnp.asarray(rng.integers(0, N, (B, nb)), jnp.int32)
    lengths = jnp.asarray([0, 9], jnp.int32)
    n_valid = jnp.asarray([6, 4], jnp.int32)
    kw = {}
    if cache_dtype is not None:
        cq, cs, rq, rs = _quantize(ckv, krope, cache_dtype)
        ckv, krope = cq, rq
        kw = dict(ckv_scales=cs, krope_scales=rs)
    outs = {r: mla_prefill_paged_kernel(q, ckv, krope, bt, lengths, n_valid,
                                        rescale=r, interpret=True, **kw)
            for r in RESCALES}
    np.testing.assert_allclose(np.asarray(outs["exp_add"]),
                               np.asarray(outs["mul"]),
                               atol=RESCALE_TOL, rtol=RESCALE_TOL)


@pytest.mark.parametrize("cache_dtype", (None,) + CACHE_DTYPES)
def test_chunk1_prefill_equals_decode_kernel(cache_dtype):
    """Triangle identity: a 1-token prefill chunk at position L sees
    exactly the decode kernel's window (pos <= L) — the two kernels must
    agree on the same pool."""
    B, H, Dl, Dr, bs, nb, N = 3, 4, 32, 8, 8, 3, 10
    ckv, krope = _latents(N, bs, Dl, Dr, seed=9)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, Dl + Dr),
                          jnp.float32)
    rng = np.random.default_rng(13)
    bt = jnp.asarray(rng.integers(0, N, (B, nb)), jnp.int32)
    L = jnp.asarray([0, 7, 23], jnp.int32)
    kw = {}
    if cache_dtype is not None:
        cq, cs, rq, rs = _quantize(ckv, krope, cache_dtype)
        ckv, krope = cq, rq
        kw = dict(ckv_scales=cs, krope_scales=rs)
    dec = mla_decode_paged_kernel(q, ckv, krope, bt, L, interpret=True, **kw)
    pre = mla_prefill_paged_kernel(q[:, None], ckv, krope, bt, L,
                                   jnp.ones((B,), jnp.int32),
                                   interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(pre[:, 0]), np.asarray(dec),
                               atol=RESCALE_TOL, rtol=RESCALE_TOL)


def test_kernel_rejects_unknown_rescale():
    B, H, Dl, Dr, bs, nb, N = 1, 2, 16, 8, 4, 2, 4
    ckv, krope = _latents(N, bs, Dl, Dr)
    q = jnp.zeros((B, H, Dl + Dr), jnp.float32)
    bt = jnp.zeros((B, nb), jnp.int32)
    with pytest.raises(ValueError, match="rescale"):
        mla_decode_paged_kernel(q, ckv, krope, bt,
                                jnp.zeros((B,), jnp.int32),
                                rescale="fma", interpret=True)


def test_rescale_multiplies_model_drops_to_zero():
    """Cost-model term the AMLA rewrite removes: the per-tile rescale
    multiplies on the (acc, l) state vanish under exp_add."""
    kw = dict(cache_len=4096, batch=16, paged_block=128)
    mul = ac.rescale_multiplies(ac.DSV3_MLA, rescale="mul", **kw)
    add = ac.rescale_multiplies(ac.DSV3_MLA, rescale="exp_add", **kw)
    n_tiles = -(-4096 // 128)
    assert add == 0.0
    assert mul == 16 * n_tiles * ac.DSV3_MLA.n_heads * (
        ac.DSV3_MLA.kv_lora_rank + 1)
    with pytest.raises(ValueError):
        ac.rescale_multiplies(ac.DSV3_MLA, rescale="fma", **kw)


# ----------------------------------------------------- cost-model dtype axis


def test_cache_dtype_bytes_axis_shrinks_cache_terms_only():
    kw = dict(scheme="seq", cache_len=4096, batch=16, paged_block=128)
    w8 = cachelib.cache_element_bytes(ac.DSV3_MLA.kv_lora_rank,
                                      ac.DSV3_MLA.qk_rope_dim, 2, "int8")
    base = ac.mla_decode_cost(ac.DSV3_MLA, **kw)
    quant = ac.mla_decode_cost(ac.DSV3_MLA, cache_dtype_bytes=w8, **kw)
    assert quant.flops == base.flops
    rd = quant.breakdown["B:cache_read"] / base.breakdown["B:cache_read"]
    assert rd == pytest.approx(w8 / 2) and rd <= 0.55   # ISSUE acceptance
    assert (quant.breakdown["B:cache_write"]
            < base.breakdown["B:cache_write"])
    assert quant.breakdown["B:w_common"] == base.breakdown["B:w_common"]
    vkw = dict(scheme="seq", cache_len=4096, k=2, batch=16, paged_block=128)
    bv = ac.mla_verify_cost(ac.DSV3_MLA, **vkw)
    qv = ac.mla_verify_cost(ac.DSV3_MLA, cache_dtype_bytes=w8, **vkw)
    assert qv.bytes < bv.bytes and qv.flops == bv.flops
    pkw = dict(seq_len=1024, chunk=128, paged_block=128, batch=16)
    bp = ac.mla_prefill_chunk_cost(ac.DSV3_MLA, **pkw)
    qp = ac.mla_prefill_chunk_cost(ac.DSV3_MLA, cache_dtype_bytes=w8, **pkw)
    assert qp.bytes < bp.bytes and qp.flops == bp.flops


def test_bytes_per_token_and_schemes_cache_width():
    K, dr = 512, 64
    assert cachelib.bytes_per_token_latent(K, dr, 2) == (K + dr) * 2
    assert cachelib.bytes_per_token_latent(K, dr, 2, "int8") == (K + dr) + 8
    w = cachelib.cache_element_bytes(K, dr, 2, "int8")
    assert 0 < w < 2
    plat = PLATFORMS["tpu_v5e"]
    assert schemeslib.cache_width(ac.DSV3_MLA, plat, "int8") < \
        schemeslib.cache_width(ac.DSV3_MLA, plat, None)
    t16 = schemeslib.step_time("seq", ac.DSV3_MLA, plat, cache_len=4096,
                               batch=16, paged_block=128)
    t8 = schemeslib.step_time("seq", ac.DSV3_MLA, plat, cache_len=4096,
                              batch=16, paged_block=128, cache_dtype="int8")
    assert t8 < t16
    s = schemeslib.auto_dispatch(ac.DSV3_MLA, plat, cache_len=4096, batch=8,
                                 paged_block=64, cache_dtype="int8")
    assert s in ("seq", "rc", "ru")


# ------------------------------------------------- drift/telemetry dtype pin


def test_drift_predictions_are_dispatcher_exact_for_quantized_pool():
    """Satellite fix pin: a drift channel bound with cache_dtype must
    price the quantized cache stream (modeled bytes AND time shrink) and
    stamp the dtype into its report."""
    plat = PLATFORMS["tpu_v5e"]
    rows = {}
    for cd in (None, "int8"):
        d = RooflineDrift(mla=ac.DSV3_MLA, platform=plat, paged_block=128,
                          cache_dtype=cd)
        d.record_decode("seq", 16, 4096, 1e-3)
        rows[cd] = d.rows[0]
        assert d.report()["cache_dtype"] == (cd or "bf16")
    assert rows["int8"].pred_bytes < rows[None].pred_bytes
    assert rows["int8"].pred_time_s < rows[None].pred_time_s
    assert rows["int8"].pred_time_s == pytest.approx(
        schemeslib.step_time("seq", ac.DSV3_MLA, plat, cache_len=4096,
                             batch=16, paged_block=128, cache_dtype="int8"))


# ----------------------------------------------------------- engine, e2e ---


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _engine_run(cfg, params, reqs, cache_dtype, telemetry=None):
    eng = PagedMLAEngine(cfg, params, num_blocks=24, block_size=8,
                         max_batch=2, compute_dtype=jnp.float32,
                         scheme="seq", impl="kernel",
                         prefill_mode="chunked", prefill_chunk=8,
                         cache_dtype=cache_dtype, telemetry=telemetry)
    summary = eng.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                               max_new=r.max_new, arrival=r.arrival)
                       for r in reqs])
    if telemetry is not None:
        telemetry.finalize(eng)
    return eng, summary, {r.rid: r.output for r in eng.sched.finished}


def test_engine_int8_greedy_token_parity(smoke_model):
    """End-to-end acceptance: the engine serving from an int8 pool emits
    exactly the greedy tokens of the wide-pool engine, and the metrics
    pool-occupancy gauge prices the quantized bytes."""
    cfg, params = smoke_model
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (p,)).astype(np.int32),
                    max_new=g, arrival=a)
            for i, (p, g, a) in enumerate([(9, 4, 0), (13, 3, 1), (5, 5, 3)])]
    tel16 = Telemetry.on(metrics=True)
    tel8 = Telemetry.on(metrics=True)
    _, s16, out16 = _engine_run(cfg, params, reqs, "bf16", telemetry=tel16)
    _, s8, out8 = _engine_run(cfg, params, reqs, "int8", telemetry=tel8)
    assert out8 == out16 and len(out8) == len(reqs)
    assert s8["cache_dtype"] == "int8" and s16["cache_dtype"] == "bf16"
    # compute runs f32 here, so the wide pool is 4 B/elem: int8+scales
    # must land at <= 0.55x of it (the ISSUE bound is vs bf16 = 2 B/elem,
    # strictly looser)
    ratio = s8["cache_token_bytes"] / s16["cache_token_bytes"]
    assert ratio <= 0.55, ratio
    g16 = tel16.metrics.histogram("pool_allocated_bytes").summary()
    g8 = tel8.metrics.histogram("pool_allocated_bytes").summary()
    assert g8["count"] == s8["steps"] and g8["count"] > 0
    # identical tokens -> identical allocation trajectory -> the gauges
    # differ by exactly the bytes/token ratio
    assert g8["max"] == pytest.approx(ratio * g16["max"])


def test_engine_rejects_bad_cache_dtype_configs(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="cache_dtype"):
        PagedMLAEngine(cfg, params, num_blocks=8, block_size=8, max_batch=1,
                       compute_dtype=jnp.float32, scheme="seq",
                       cache_dtype="int4")
    with pytest.raises(NotImplementedError, match="chunked"):
        PagedMLAEngine(cfg, params, num_blocks=8, block_size=8, max_batch=1,
                       compute_dtype=jnp.float32, scheme="seq",
                       prefill_mode="per_request", cache_dtype="int8")


# ------------------------------------------------------- hypothesis drives --


def test_quantize_roundtrip_error_property():
    """Round-trip |dequant(quantize(x)) - x| stays inside the per-row
    half-step bound across magnitudes from subnormal-feeding tiny to 1e8,
    and zero rows quantize exactly (scale 1, payload 0)."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dep: property-based sweeps")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.data())
    def drive(data):
        name = data.draw(st.sampled_from(CACHE_DTYPES), label="dtype")
        rows = data.draw(st.integers(1, 4), label="rows")
        D = data.draw(st.sampled_from([1, 8, 32]), label="D")
        mag = data.draw(st.integers(-6, 8), label="mag")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        zero_row = data.draw(st.booleans(), label="zero_row")
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((rows, D)).astype(np.float32) * 10.0 ** mag
        if zero_row:
            x[0] = 0.0
        qdtype, qmax = _qinfo(name)
        q, s = cachelib.quantize_latent(jnp.asarray(x), qmax, qdtype)
        dq = np.asarray(cachelib.dequantize_latent(q, s))
        s = np.asarray(s)
        amax = np.max(np.abs(x), axis=-1, keepdims=True)
        # scale invariant: amax/qmax, or exactly 1 for an all-zero row
        np.testing.assert_allclose(
            s, np.where(amax > 0, amax / qmax, 1.0), rtol=1e-6)
        if name == "int8":
            # symmetric round-to-nearest: half a step per element
            bound = s * (0.5 + 1e-3)
        else:
            # e4m3: 3 mantissa bits -> rel err <= 2^-4, plus one
            # subnormal step (2^-9 of the scaled unit) near zero
            bound = np.abs(x) * 2.0 ** -4 + s * 2.0 ** -9 + s * 1e-3
        assert np.all(np.abs(dq - x) <= bound), name
        if zero_row:
            assert np.all(dq[0] == 0.0) and s[0, 0] == 1.0

    drive()


def test_cow_fork_release_scale_invariants_property():
    """Hypothesis drive of the CoW machinery over a QUANTIZED pool:
    fork/release refcounts follow the model, copy_block_paged clones
    data AND scale leaves, and writes never leak scales into untouched
    blocks."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dep: property-based sweeps")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def drive(data):
        name = data.draw(st.sampled_from(CACHE_DTYPES), label="dtype")
        bs = data.draw(st.sampled_from([2, 4]), label="bs")
        N = data.draw(st.integers(4, 8), label="N")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        rng = np.random.default_rng(seed)
        pool = cachelib.paged_latent_cache(N, bs, 16, 8, jnp.float32,
                                           cache_dtype=name)
        alloc = BlockAllocator(N)
        blocks = alloc.alloc(3)
        assert blocks is not None and 0 not in blocks
        src, dst, other = blocks
        # write a few tokens into src through the production scatter
        bt = jnp.asarray([[src]], jnp.int32)
        n_tok = data.draw(st.integers(1, bs), label="n_tok")
        for t in range(n_tok):
            pool = cachelib.update_latent_paged(
                pool, bt, jnp.asarray([t], jnp.int32),
                jnp.asarray(rng.standard_normal((1, 16)), jnp.float32),
                jnp.asarray(rng.standard_normal((1, 8)), jnp.float32))
        # written slots carry real scales; untouched blocks keep the
        # init scale of exactly 1 (no write leakage)
        assert float(pool["ckv_scale"][src, 0, 0]) != 1.0 or n_tok == 0
        np.testing.assert_array_equal(
            np.asarray(pool["ckv_scale"][other]), 1.0)
        np.testing.assert_array_equal(
            np.asarray(pool["krope_scale"][other]), 1.0)
        # CoW break: the copy must clone every leaf, scales included
        before = jax.tree.map(jnp.copy, pool)
        pool = cachelib.copy_block_paged(pool, src, dst)
        for leaf in ("ckv", "ckv_scale", "krope", "krope_scale"):
            np.testing.assert_array_equal(np.asarray(pool[leaf][dst]),
                                          np.asarray(pool[leaf][src]))
            np.testing.assert_array_equal(np.asarray(pool[leaf][other]),
                                          np.asarray(before[leaf][other]))
        # refcount model: fork adds a holder, release peels them off,
        # the block only zeroes at the last release
        alloc.fork([src])
        assert alloc.release([src]) == []
        assert alloc.release([src]) == [src]
        alloc.free([src])
        with pytest.raises(ValueError):
            alloc.release([src])

    drive()
