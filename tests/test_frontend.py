"""HTTP/SSE frontend (launch.server) over a live engine on an ephemeral
port: blocking + streaming generation, per-request stop sequences and
max_tokens, mid-decode cancellation, and the pool invariants cancellation
must preserve — every block refcount returns to the trie-held baseline
and the radix prefix cache stays unpoisoned (an identical-prefix request
after a cancel still produces the reference tokens).

The server threads drive the real engine (async by default here — the
PR-9 path); nothing is mocked.  Requests go through urllib against
127.0.0.1 only.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.launch.server import Frontend
from repro.nn import module as nnm
from repro.obs import Telemetry
from repro.runtime import AsyncPagedMLAEngine, PagedMLAEngine, Request


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke("deepseek-v2-236b")
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _engine(cfg, params, engine_cls=AsyncPagedMLAEngine, **kw):
    kw.setdefault("enable_prefix_cache", True)
    return engine_cls(cfg, params, num_blocks=32, block_size=8, max_batch=2,
                      max_blocks_per_req=10, compute_dtype=jnp.float32,
                      scheme="seq", prefill_chunk=8, **kw)


@pytest.fixture()
def frontend(smoke_model):
    cfg, params = smoke_model
    fe = Frontend(_engine(cfg, params), port=0).start()
    yield fe
    fe.stop()


def _post(fe, path, payload, timeout=120):
    req = urllib.request.Request(
        f"http://{fe.host}:{fe.port}{path}",
        json.dumps(payload).encode(), {"Content-Type": "application/json"})
    return urllib.request.urlopen(req, timeout=timeout)


def _get(fe, path):
    return json.load(urllib.request.urlopen(
        f"http://{fe.host}:{fe.port}{path}", timeout=30))


def _events(resp):
    """Parse an SSE body into [(event, payload), ...]."""
    out, ev = [], None
    for line in resp:
        line = line.decode().strip()
        if line.startswith("event: "):
            ev = line[len("event: "):]
        elif line.startswith("data: "):
            out.append((ev, json.loads(line[len("data: "):])))
    return out


def _reference(cfg, params, prompt, max_new):
    """Ground truth from a fresh synchronous engine, no HTTP anywhere."""
    eng = _engine(cfg, params, engine_cls=PagedMLAEngine)
    eng.run([Request(rid=0, prompt=np.asarray(prompt, np.int32),
                     max_new=max_new)])
    return [int(t) for t in eng.sched.finished[0].output]


PROMPT = [5, 9, 3, 7, 11, 2]


# ------------------------------------------------------------ generate ----


def test_generate_blocking_matches_reference(smoke_model, frontend):
    cfg, params = smoke_model
    r = json.load(_post(frontend, "/v1/generate",
                        {"prompt": PROMPT, "max_tokens": 6}))
    assert r["finish_reason"] == "length"
    assert r["output"] == _reference(cfg, params, PROMPT, 6)


def test_generate_stream_tokens_match_done(frontend):
    resp = _post(frontend, "/v1/generate",
                 {"prompt": PROMPT, "max_tokens": 6, "stream": True})
    evs = _events(resp)
    assert [e for e, _ in evs][:1] == ["start"]
    toks = [d["token"] for e, d in evs if e == "token"]
    (done,) = [d for e, d in evs if e == "done"]
    assert done["finish_reason"] == "length"
    assert toks == done["output"] and len(toks) == 6


def test_generate_concurrent_requests_isolated(smoke_model, frontend):
    cfg, params = smoke_model
    prompts = [PROMPT, [8, 1, 4, 4, 2, 9, 13], [3, 3, 3, 5]]
    results = [None] * len(prompts)

    def go(i):
        results[i] = json.load(_post(frontend, "/v1/generate",
                                     {"prompt": prompts[i], "max_tokens": 5}))

    ts = [threading.Thread(target=go, args=(i,)) for i in range(len(prompts))]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=300)
    for i, p in enumerate(prompts):
        assert results[i]["output"] == _reference(cfg, params, p, 5)


def test_stop_sequence_over_http(smoke_model, frontend):
    cfg, params = smoke_model
    free = _reference(cfg, params, PROMPT, 8)
    stop = [free[2:4]]
    resp = _post(frontend, "/v1/generate",
                 {"prompt": PROMPT, "max_tokens": 8, "stop": stop,
                  "stream": True})
    evs = _events(resp)
    toks = [d["token"] for e, d in evs if e == "token"]
    (done,) = [d for e, d in evs if e == "done"]
    assert done["finish_reason"] == "stop"
    # the matched stop gram is hidden, and the streamed prefix never
    # leaked a token the truncation later removed (hold-back works)
    assert done["output"] == free[:2]
    assert toks == done["output"][:len(toks)]


def test_generate_rejects_empty_prompt(frontend):
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(frontend, "/v1/generate", {"prompt": [], "max_tokens": 4})
    assert e.value.code == 400


# -------------------------------------------- PR 10: request API wire ----


def test_generate_single_response_golden_bytes(smoke_model, frontend):
    """Without "n" in the body, the PR-9 single-completion wire format
    is preserved byte for byte (key set, key order, serialization)."""
    cfg, params = smoke_model
    raw = _post(frontend, "/v1/generate",
                {"prompt": PROMPT, "max_tokens": 4}).read()
    r = json.loads(raw)
    golden = json.dumps({"rid": r["rid"], "finish_reason": "length",
                         "output": _reference(cfg, params, PROMPT, 4)})
    assert raw == golden.encode()


def test_generate_n_blocking_choices(smoke_model, frontend):
    cfg, params = smoke_model
    r = json.load(_post(frontend, "/v1/generate",
                        {"prompt": PROMPT, "max_tokens": 5, "n": 2}))
    assert set(r) == {"rid", "choices"}
    assert [c["index"] for c in r["choices"]] == [0, 1]
    ref = _reference(cfg, params, PROMPT, 5)   # greedy engine: forks agree
    for c in r["choices"]:
        assert c["tokens"] == ref and c["finish_reason"] == "length"


def test_generate_explicit_n1_uses_choices_format(frontend):
    # "n" PRESENT — even n=1 — selects the choices[] format; only its
    # ABSENCE keeps the legacy body (the byte-compat contract above)
    r = json.load(_post(frontend, "/v1/generate",
                        {"prompt": PROMPT, "max_tokens": 3, "n": 1}))
    assert set(r) == {"rid", "choices"}
    assert len(r["choices"]) == 1 and r["choices"][0]["index"] == 0


def test_generate_stream_n_carries_choice_indices(frontend):
    resp = _post(frontend, "/v1/generate",
                 {"prompt": PROMPT, "max_tokens": 4, "n": 2,
                  "stream": True})
    evs = _events(resp)
    (start,) = [d for e, d in evs if e == "start"]
    assert start["n"] == 2
    toks = {}
    for e, d in evs:
        if e == "token":
            toks.setdefault(d["choice"], []).append(d["token"])
    (done,) = [d for e, d in evs if e == "done"]
    by_idx = {c["index"]: c for c in done["choices"]}
    assert set(toks) == {0, 1} == set(by_idx)
    for c in (0, 1):
        assert toks[c] == by_idx[c]["tokens"]
        assert by_idx[c]["finish_reason"] == "length"


@pytest.mark.parametrize("body", [
    {"prompt": [5, 9], "max_tokens": 4, "n": 0},            # n < 1
    {"prompt": [5, 9], "max_tokens": 4, "temperature": -1},  # negative
    {"prompt": [5, 9], "max_tokens": 0},                     # empty budget
    {"prompt": [5, 9], "max_tokens": 4, "temperature": 0.7},  # != engine
    {"max_tokens": 4},                                       # no prompt
], ids=["n0", "neg-temp", "max0", "temp-mismatch", "no-prompt"])
def test_generate_structured_error_bodies(frontend, body):
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(frontend, "/v1/generate", body)
    assert e.value.code == 400
    err = json.load(e.value)
    assert err["error"]["type"] == "invalid_request"
    assert err["error"]["message"]


# ------------------------------------------------------- cancellation ----


def _drain(fe, timeout=120):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        h = _get(fe, "/v1/health")
        if h["active"] == 0 and h["waiting"] == 0:
            return h
        time.sleep(0.05)
    raise TimeoutError("engine did not drain")


def _stream_until_rid_and_tokens(fe, payload, n_tokens=2):
    """Open a stream, return (rid, iterator) after n_tokens arrived."""
    resp = _post(fe, "/v1/generate", dict(payload, stream=True))
    rid, seen, ev = None, 0, None
    for line in resp:
        line = line.decode().strip()
        if line.startswith("event: "):
            ev = line[len("event: "):]
        elif line.startswith("data: "):
            d = json.loads(line[len("data: "):])
            if ev == "start":
                rid = d["rid"]
            elif ev == "token":
                seen += 1
                if seen >= n_tokens:
                    return rid, resp
    raise AssertionError("stream ended before tokens arrived")


def test_cancel_mid_decode_frees_blocks(smoke_model):
    cfg, params = smoke_model
    eng = _engine(cfg, params, enable_prefix_cache=False)
    fe = Frontend(eng, port=0).start()
    try:
        rid, resp = _stream_until_rid_and_tokens(
            fe, {"prompt": PROMPT, "max_tokens": 400})
        assert eng.sched.allocator.num_allocated > 0
        _post(fe, "/v1/cancel", {"rid": rid})
        evs = _events(resp)   # read to the done event
        (done,) = [d for e, d in evs if e == "done"]
        assert done["finish_reason"] == "cancelled"
        _drain(fe)
        # no prefix cache: cancellation must return EVERY block
        assert eng.sched.allocator.num_allocated == 0
        assert eng.sched.allocator.refcount == {}
        # the pool is reusable: a fresh request still serves correctly
        r = json.load(_post(fe, "/v1/generate",
                            {"prompt": PROMPT, "max_tokens": 5}))
        assert r["output"] == _reference(cfg, params, PROMPT, 5)
    finally:
        fe.stop()


def test_cancel_waiting_request(smoke_model):
    cfg, params = smoke_model
    eng = _engine(cfg, params, enable_prefix_cache=False)
    fe = Frontend(eng, port=0).start()
    try:
        # saturate both slots with long requests, then queue a third
        streams = [_stream_until_rid_and_tokens(
            fe, {"prompt": [i + 1, 7, 2, 9], "max_tokens": 300}, 1)
            for i in range(2)]
        st = fe.worker.submit(PROMPT, 300)
        _post(fe, "/v1/cancel", {"rid": st.rid})
        item = st.q.get(timeout=60)
        assert item[0] == "done" and item[1] == "cancelled"
        for rid, resp in streams:
            _post(fe, "/v1/cancel", {"rid": rid})
            resp.close()
        _drain(fe)
        assert eng.sched.allocator.num_allocated == 0
    finally:
        fe.stop()


def test_cancel_leaves_radix_cache_unpoisoned(smoke_model):
    cfg, params = smoke_model
    eng = _engine(cfg, params)   # prefix cache ON
    fe = Frontend(eng, port=0).start()
    try:
        rid, resp = _stream_until_rid_and_tokens(
            fe, {"prompt": PROMPT, "max_tokens": 400})
        _post(fe, "/v1/cancel", {"rid": rid})
        _events(resp)
        _drain(fe)
        # trie-held blocks may stay cached (refcount 0, LRU-evictable)
        # but nothing may hold a live reference
        assert all(rc == 0 for rc in eng.sched.allocator.refcount.values())
        # unpoisoned: an identical-prefix request hits the cache and
        # still produces the reference tokens
        r = json.load(_post(fe, "/v1/generate",
                            {"prompt": PROMPT, "max_tokens": 6}))
        assert r["output"] == _reference(cfg, params, PROMPT, 6)
    finally:
        fe.stop()


def test_cancel_one_fork_leaves_rest_of_group_running(smoke_model):
    """POST /v1/cancel with rid + c kills ONLY choice c: the sibling
    decodes to its natural finish, the done event reports per-choice
    finish reasons, and every block reference unwinds."""
    cfg, params = smoke_model
    eng = _engine(cfg, params)
    fe = Frontend(eng, port=0).start()
    try:
        rid, resp = _stream_until_rid_and_tokens(
            fe, {"prompt": PROMPT, "max_tokens": 40, "n": 2}, 2)
        _post(fe, "/v1/cancel", {"rid": rid + 1})   # choice 1 only
        evs = _events(resp)                          # drain to done
        (done,) = [d for e, d in evs if e == "done"]
        by = {c["index"]: c for c in done["choices"]}
        assert by[1]["finish_reason"] == "cancelled"
        assert by[0]["finish_reason"] == "length"
        assert len(by[0]["tokens"]) == 40           # sibling unharmed
        _drain(fe)
        assert all(rc == 0 for rc in eng.sched.allocator.refcount.values())
    finally:
        fe.stop()


# ------------------------------------------------------------ plumbing ----


def test_health_and_metrics_endpoints(smoke_model):
    cfg, params = smoke_model
    tel = Telemetry.on(trace=False, metrics=True, drift=False)
    eng = _engine(cfg, params, telemetry=tel)
    fe = Frontend(eng, port=0).start()
    try:
        json.load(_post(fe, "/v1/generate",
                        {"prompt": PROMPT, "max_tokens": 4}))
        h = _get(fe, "/v1/health")
        assert h["ok"] and h["finished"] == 1 and h["steps"] > 0
        m = _get(fe, "/v1/metrics")
        # 4 output tokens = 1 prefill-sampled + 3 decoded
        assert m["summary"]["decode_tokens"] >= 3
        # live registry: the engine records step_ms / pool gauges per tick
        assert m["metrics"]["histograms"]["step_ms"]["count"] > 0
        assert _get(fe, "/v1/health")["ok"]
    finally:
        fe.stop()
