"""Multi-turn serving + n-way parallel sampling (PR 10 acceptance).

  * SamplingParams: validation errors, and the legacy
    ``Request(prompt, max_new, stop=...)`` constructor kept working
    through the DeprecationWarning shim (pinned here);
  * per-request temperature/top_k/seed must MATCH the engine config
    (they are baked into the compiled step — a per-request value would
    mint new step variants) — ``validate_sampling`` raises;
  * multi-turn decode-block reuse: a follow-up turn whose prompt embeds
    the previous turn's generation re-hits the trie blocks that DECODE
    filled (registered as lengths crossed each block boundary), prefills
    only the genuinely new suffix, and still emits the cold-engine
    tokens;
  * n-way parallel sampling: one prefill + fork is token-identical to n
    independent seeded requests on consecutive rids, on BOTH engines,
    while allocating strictly fewer pool blocks (the acceptance
    criterion) and compiling no extra step shapes;
  * cancelling one fork mid-decode frees exactly that fork's unshared
    blocks: the shared prompt blocks drop one refcount and the rest of
    the group decodes on, token-unchanged.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
import repro.models as models
from repro.nn import module as nnm
from repro.runtime import (AsyncPagedMLAEngine, PagedMLAEngine, Request,
                           SamplingParams, blocks_for)


@pytest.fixture(scope="module")
def smoke_model():
    # The parity tests below compare runs whose PREFILL batches differ by
    # construction (one forked prefill vs n independent ones).  MoE
    # capacity overflow is the only op whose per-token result depends on
    # the rest of the batch (which tokens DROP is a function of every
    # co-batched token's routing), so token-identity across batch shapes
    # needs drop-free capacity: C >= T at capacity_factor = E / top_k.
    cfg = configs.smoke("deepseek-v2-236b")
    cfg = dataclasses.replace(
        cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                             jnp.float32)
    return cfg, params


def _engine(cfg, params, engine_cls=PagedMLAEngine, **kw):
    kw.setdefault("num_blocks", 40)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_blocks_per_req", 12)
    return engine_cls(cfg, params, block_size=8,
                      compute_dtype=jnp.float32, scheme="seq",
                      prefill_chunk=8, **kw)


def _prompt(n, seed=11):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 64, (n,)).astype(np.int32)


def _outs(eng):
    return {r.rid: (tuple(r.output), r.finish_reason)
            for r in eng.sched.finished}


# ------------------------------------------------------ SamplingParams ----


def test_sampling_params_validation():
    sp = SamplingParams(max_tokens=4, n=2, stop=[[1, 2]])
    assert sp.validate() is sp
    assert sp.stop == ((1, 2),)          # JSON lists normalize to tuples
    for bad in (dict(n=0), dict(max_tokens=0), dict(temperature=-0.5),
                dict(top_k=-1), dict(stop=((),))):
        with pytest.raises(ValueError):
            SamplingParams(**bad).validate()


def test_legacy_request_constructor_shim():
    with pytest.warns(DeprecationWarning, match="SamplingParams"):
        r = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new=5,
                    stop=[[1, 2]])
    assert r.sampling == SamplingParams(max_tokens=5, stop=((1, 2),))
    assert r.max_new == 5 and r.stop == [[1, 2]]
    # the new-style constructor must NOT warn
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        r = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                    sampling=SamplingParams(max_tokens=3))
    assert r.max_new == 3


def test_engine_rejects_mismatched_sampling_overrides(smoke_model):
    cfg, params = smoke_model
    eng = _engine(cfg, params, temperature=0.8, top_k=5, sample_seed=7)
    ids = np.arange(6, dtype=np.int32)
    # matching overrides (and None = inherit) are fine
    eng.validate_sampling(SamplingParams(max_tokens=2))
    eng.validate_sampling(SamplingParams(max_tokens=2, temperature=0.8,
                                         top_k=5, seed=7))
    for bad in (dict(temperature=0.3), dict(top_k=9), dict(seed=1)):
        with pytest.raises(ValueError, match="engine"):
            eng.submit(Request(rid=0, prompt=ids,
                               sampling=SamplingParams(max_tokens=2, **bad)))


# ---------------------------------------------------------- multi-turn ----


def test_second_turn_rehits_decode_blocks(smoke_model):
    """Turn 2's prompt = turn 1's prompt + generation + new user tokens:
    the generation blocks were trie-registered as decode crossed each
    block boundary, so only the new suffix prefills — and the tokens
    still match a cold engine serving the same turn-2 prompt."""
    cfg, params = smoke_model
    p1 = _prompt(16)
    eng = _engine(cfg, params)
    eng.run([Request(rid=0, prompt=p1,
                     sampling=SamplingParams(max_tokens=16))])
    out1 = list(eng.sched.finished[0].output)
    assert len(out1) == 16
    st = eng.sched.prefix.stats
    # lengths crossed 24 (16 prompt + 8 generated): >= 1 decode block
    assert st.decode_blocks_inserted >= 1
    hit0, prefill0 = st.hit_tokens, eng.stats.prefill_tokens

    p2 = np.concatenate([p1, np.asarray(out1, np.int32),
                         _prompt(6, seed=12)])
    eng.run([Request(rid=1, prompt=p2,
                     sampling=SamplingParams(max_tokens=4))])
    st = eng.sched.prefix.stats
    # the warm turn re-hit prompt AND generated blocks: 16 + 8 full
    # blocks at least (the trailing partial tail forks copy-on-write)
    assert st.hit_tokens - hit0 >= 24
    warm_prefill = eng.stats.prefill_tokens - prefill0
    assert warm_prefill < len(p2) // 2

    cold = _engine(cfg, params)
    cold.run([Request(rid=1, prompt=p2,
                      sampling=SamplingParams(max_tokens=4))])
    assert _outs(cold)[1] == _outs(eng)[1]


# ---------------------------------------------------- parallel sampling ----


@pytest.mark.parametrize("engine_cls", [PagedMLAEngine, AsyncPagedMLAEngine],
                         ids=["sync", "async"])
def test_fork_group_token_identical_and_fewer_blocks(smoke_model,
                                                     engine_cls):
    """The n=4 acceptance: one prefill + CoW fork emits exactly the
    tokens of 4 independent seeded requests on consecutive rids, while
    allocating strictly fewer pool blocks (the prompt is block-aligned,
    so the group shares every prompt block) and compiling no extra
    prefill shapes."""
    cfg, params = smoke_model
    kw = dict(temperature=0.9, top_k=5, sample_seed=7)
    p = _prompt(16)                       # 16 % block_size == 0

    grp = _engine(cfg, params, engine_cls=engine_cls, **kw)
    grp.run([Request(rid=0, prompt=p,
                     sampling=SamplingParams(max_tokens=6, n=4))])

    ind = _engine(cfg, params, engine_cls=engine_cls, **kw)
    ind.run([Request(rid=i, prompt=p,
                     sampling=SamplingParams(max_tokens=6))
             for i in range(4)])

    assert _outs(grp) == _outs(ind)
    assert len(_outs(grp)) == 4
    # with temperature on, the forks must actually diverge
    assert len({toks for toks, _ in _outs(grp).values()}) > 1
    assert (grp.sched.allocator.total_allocs
            < ind.sched.allocator.total_allocs)
    assert grp.summary()["fork_groups"] == 1.0
    assert grp.summary()["fork_children"] == 3.0
    # host-side fork/CoW: no new compiled step shapes vs the independents
    assert grp.prefill_compiles <= ind.prefill_compiles


def test_fork_group_midblock_prompt_cow(smoke_model):
    """A NON-block-aligned prompt forks too: the partial tail block is
    materialized per child by a queued device copy, and tokens still
    match the independent runs."""
    cfg, params = smoke_model
    kw = dict(temperature=0.9, top_k=5, sample_seed=7)
    p = _prompt(13)                       # 13 % 8 != 0: CoW tail per child

    grp = _engine(cfg, params, **kw)
    grp.run([Request(rid=0, prompt=p,
                     sampling=SamplingParams(max_tokens=5, n=3))])
    ind = _engine(cfg, params, **kw)
    ind.run([Request(rid=i, prompt=p, sampling=SamplingParams(max_tokens=5))
             for i in range(3)])
    assert _outs(grp) == _outs(ind)
    assert grp.sched.prefix.stats.cow_copies >= 2    # one per child


def test_cancel_one_fork_frees_only_unshared_blocks(smoke_model):
    """Mid-decode cancellation of a single fork: exactly that fork's
    private blocks return to the pool, every shared prompt block drops
    ONE refcount, and the survivors' tokens are unchanged."""
    cfg, params = smoke_model
    kw = dict(temperature=0.9, top_k=5, sample_seed=7)
    p = _prompt(16)

    ref = _engine(cfg, params, **kw)
    ref.run([Request(rid=0, prompt=p,
                     sampling=SamplingParams(max_tokens=10, n=3))])

    eng = _engine(cfg, params, **kw)
    eng.submit(Request(rid=0, prompt=p,
                       sampling=SamplingParams(max_tokens=10, n=3)))
    # step until the group is forked and a couple of tokens are out,
    # but BEFORE any decode block completes (16 + 8 boundary) so the
    # victim's private blocks are trie-free
    while eng.sched.fork_groups == 0 or any(
            len(eng.sched.slots[s].tokens) < 3
            for s in eng.sched.active_slots):
        eng.step()
    alloc = eng.sched.allocator
    victim_slot = next(s for s in eng.sched.active_slots
                       if eng.sched.slots[s].rid == 1)
    n_shared = len(p) // 8
    shared = eng.sched.blocks_of[victim_slot][:n_shared]
    private = eng.sched.blocks_of[victim_slot][n_shared:]
    rc_before = {b: alloc.refcount[b] for b in shared}
    n_before = alloc.num_allocated

    eng.request_cancel(1)
    eng.step()
    assert alloc.num_allocated == n_before - len(private)
    for b in private:
        assert b not in alloc.refcount            # hard-freed, not cached
    for b in shared:
        assert alloc.refcount[b] == rc_before[b] - 1

    while not eng.sched.all_done:
        eng.step()
    outs, refs = _outs(eng), _outs(ref)
    assert outs[1][1] == "cancelled"
    for rid in (0, 2):                    # survivors: token-unchanged
        assert outs[rid] == refs[rid]
