"""MoE dispatch: sort-based capacity routing vs a dense (gather-all)
reference; aux losses; drop accounting; shared experts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moelib
from repro.models.common import ModelConfig
from repro.nn import module as nnm


def mk_cfg(E=8, k=2, cap=8.0):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=16, vocab=64,
                       n_experts=E, top_k=k, moe_d_ff=16,
                       capacity_factor=cap)


def dense_reference(params, cfg, x):
    """No-capacity-limit reference: every token reaches its top-k experts."""
    logits = (x @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for j in range(cfg.top_k):
        for e in range(cfg.n_experts):
            m = (sel[:, j] == e).astype(x.dtype)[:, None]
            h = jnp.einsum("td,dgf->tgf", x, params["gate_up"][e])
            h = jax.nn.silu(h[:, 0]) * h[:, 1]
            y = h @ params["down"][e]
            out = out + m * gate[:, j:j + 1].astype(x.dtype) * y
    return out


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = mk_cfg(cap=64.0)   # capacity never binds
    params = nnm.init_params(jax.random.PRNGKey(0), moelib.moe_defs(cfg),
                             jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 32)) * 0.5
    got, aux = moelib.moe_apply(params, cfg, x)
    want = dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert float(aux["dropped_frac"]) == 0.0


def test_moe_capacity_drops_tokens():
    cfg = mk_cfg(E=2, k=1, cap=0.25)   # tiny capacity: drops guaranteed
    params = nnm.init_params(jax.random.PRNGKey(0), moelib.moe_defs(cfg),
                             jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    out, aux = moelib.moe_apply(params, cfg, x)
    assert float(aux["dropped_frac"]) > 0.0
    assert not bool(jnp.isnan(out).any())


def test_moe_aux_losses_sane():
    cfg = mk_cfg()
    params = nnm.init_params(jax.random.PRNGKey(3), moelib.moe_defs(cfg),
                             jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (128, 32))
    _, aux = moelib.moe_apply(params, cfg, x)
    # perfectly balanced router -> balance ~= k; random init is near-uniform
    assert 0.5 * cfg.top_k < float(aux["balance"]) < 3.0 * cfg.top_k
    assert float(aux["z_loss"]) >= 0.0


def test_moe_shared_experts_added():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=16, vocab=64,
                      n_experts=4, top_k=1, moe_d_ff=16, n_shared_experts=2,
                      capacity_factor=64.0)
    params = nnm.init_params(jax.random.PRNGKey(5), moelib.moe_defs(cfg),
                             jnp.float32)
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(6), (16, 32)) * 0.5
    with_shared, _ = moelib.moe_apply(params, cfg, x)
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    without, _ = moelib.moe_apply(p2, cfg, x)
    assert float(jnp.max(jnp.abs(with_shared - without))) > 1e-4


def test_moe_3d_input():
    cfg = mk_cfg()
    params = nnm.init_params(jax.random.PRNGKey(7), moelib.moe_defs(cfg),
                             jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 12, 32))
    out, _ = moelib.moe_apply(params, cfg, x)
    assert out.shape == x.shape
