"""End-to-end training driver: a ~100M-param MLA+MoE model (DeepSeek-V2
family, narrow) trained for a few hundred steps on the deterministic
synthetic pipeline, with checkpoints + auto-resume.  Loss drops from
~ln(vocab) to well below — proving the full substrate (data -> model ->
optimizer -> loop -> checkpoint) end-to-end.

Default is a CPU-friendly 5-minute run; pass --steps 300 --d-model 512
for the full-size version.

    PYTHONPATH=src python examples/train_mla.py
    PYTHONPATH=src python examples/train_mla.py --steps 300 --d-model 512
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

import jax
import jax.numpy as jnp

import repro.models as models
from repro.configs import deepseek_v2_236b
from repro.data import DataConfig, SyntheticLM
from repro.nn import module as nnm
from repro.optim import AdamWConfig, adamw_init, cosine
from repro.runtime import LoopConfig, TrainLoop, TrainStepConfig, \
    make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--vocab", type=int, default=4096)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_mla")
args = ap.parse_args()

cfg = dataclasses.replace(
    deepseek_v2_236b.SMOKE, name="mla-100m",
    n_layers=args.layers, d_model=args.d_model,
    n_heads=8, q_lora_rank=args.d_model // 2, kv_lora_rank=args.d_model // 4,
    qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
    d_ff=args.d_model * 2, vocab=args.vocab,
    n_experts=8, top_k=2, moe_d_ff=args.d_model * 2, n_shared_experts=1,
    first_dense_layers=1, first_dense_d_ff=args.d_model * 4,
    max_seq=args.seq * 2)
print(f"{cfg.name}: {models.param_count(cfg)/1e6:.1f}M params "
      f"(--d-model 512 --layers 6 gives ~100M+)")

params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                         jnp.float32)
opt_cfg = AdamWConfig(lr=cosine(3e-3, warmup=20, total=args.steps))
opt = adamw_init(params, opt_cfg)
step, _ = make_train_step(cfg, None, opt_cfg,
                          TrainStepConfig(compute_dtype=jnp.float32))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch))
loop = TrainLoop(LoopConfig(total_steps=args.steps, ckpt_every=25,
                            ckpt_dir=args.ckpt_dir, log_every=10),
                 step, params, opt, data)
metrics = loop.run()
import math
print(f"final loss {float(metrics['loss']):.3f} "
      f"(uniform = ln({cfg.vocab}) = {math.log(cfg.vocab):.3f})")
assert float(metrics["loss"]) < math.log(cfg.vocab) * 0.9, \
    "loss should drop well below uniform"
print("OK")
