"""Continuous-batching MLA serving driver (the paper is an inference paper
— this is the headline example): a Poisson stream of requests with mixed
prompt/generation lengths served from the PAGED latent-KV pool, with
mid-generation admission and the execution scheme re-dispatched every step
on the live (batch, max cache_len) point.

    PYTHONPATH=src python examples/serve_mla.py --requests 10 --max-batch 4
    PYTHONPATH=src python examples/serve_mla.py --platform edge_tpu

The compact latent cache ((D_kvl + D_rope) bytes/token vs 2*H*Dh dense) is
what makes a shared block pool pay off: ~16x more requests fit the same
HBM, and the paged layout stops ragged requests from stranding capacity.

PR 2 adds the serving-side dual of that result — cutting redundant
TOKENS, not just bytes: every prompt here opens with the same
``--shared-prefix-len`` system preamble, and the radix prefix cache
(runtime.prefix_cache) maps those leading blocks to the SAME ref-counted
pool blocks (copy-on-write at the first divergent/partial block), so
only each prompt's un-cached suffix is prefilled — in fixed-size batched
chunks straight into the pool (``--prefill-chunk``: one compiled prefill
shape per chunk size instead of one retrace per prompt length).  Flags:

  --shared-prefix-len N  common preamble tokens (0: fully random prompts)
  --no-prefix-cache      disable block sharing (PR-1 behaviour)
  --prefill-chunk N      batched prefill chunk size (0: per-request prefill)
  --temperature T        sample with temperature T (0: greedy argmax);
  --top-k K              PRNG keys fold (request id, absolute position),
                         so recompute-preemption replay is deterministic

PR 3 closes the loop on the prefill phase itself: the chunked prefill's
chunk-attention can run through the fused paged Pallas kernel
(kernels.mla_prefill — the multi-query sibling of the flash-decode
kernel) instead of materializing the contiguous block-table view in HBM
every chunk:

  --prefill-impl {auto,gather,pallas}
                         'gather' = reference view (what PR 2 shipped);
                         'pallas' = in-place block-table walk, no gather
                         ever written (token-identical, tier-1-gated);
                         'auto' follows --impl ('kernel' -> pallas)
  --impl {ref,kernel}    attention impl for decode AND (via 'auto' above)
                         prefill; on CPU kernels run interpreted

PR 4 lifts the single-host restriction — the same engine serves sharded:

  --mesh DPxMP           e.g. '2x2': batch rows (token / block-table /
                         length) shard over 'data', heads over 'model',
                         the latent pool replicates on every device (its
                         compactness is what makes that affordable — the
                         paper's bandwidth argument scaled out; the
                         per-device cache traffic still shrinks by DP).
                         Tokens are identical to single-host serving
                         (tests/test_mesh_paged.py).  On CPU this script
                         forces the virtual device count for you.

PR 5 adds speculative decoding on top of all of it — the paper's
compute-bound-decode finding turned into throughput: a cheap draft
proposes k tokens, the target scores all k+1 positions in ONE forward
(the chunked-prefill machinery at chunk k+1), and rejections are a pure
host-side length rewind.  Emitted tokens are identical to plain decode
under greedy and seeded sampling; only the tokens-per-step ratio moves:

  --spec-k K             draft window (0 = off; composes with --mesh,
                         --prefill-impl, the prefix cache, preemption)
  --draft SPEC           'shallow:N' = self-speculation on the target's
                         own first N layers (weights shared by
                         reference) | 'self' = identity-draft oracle
                         (acceptance is exactly 100%)

PR 8 shrinks the pool itself — the bytes axis of the paper's argument,
quantized: the {ckv|krope} block pool can store int8 (or fp8 where the
jax build has float8_e4m3fn) with per-token-row f32 scales riding the
pool pytree.  Writes quantize in the scatter paths, the Pallas kernels
dequantize in-register while walking the block table (no pool-sized f32
copy ever lands in HBM), and the online softmax rescales by AMLA-style
exponent addition (integer add into the f32 exponent field instead of a
per-element multiply):

  --cache-dtype {bf16,int8,fp8}
                         pool storage dtype.  int8 cuts modeled cache
                         bytes/token to ~0.3x bf16 at DeepSeek shapes
                         (the auto-dispatch crossovers shift
                         accordingly); greedy tokens stay parity with
                         bf16 on the smoke model, and per-dtype
                         logit-error bounds vs the fp32 oracle are gated
                         in tests/test_quant_cache.py.  Requires
                         --prefill-chunk > 0.

PR 7 makes the whole run observable (repro.obs) — spans, metrics, and
the roofline drift channel that checks the dispatch's own cost model
against measured step times:

  --trace PATH           Chrome/Perfetto trace-event JSON: per-request
                         lifecycle spans (arrival -> queued -> prefill ->
                         decode -> finish/preempt) on one track per
                         request + per-step phase spans (schedule /
                         prefill chunks / draft / verify / device_step /
                         host_sample).  Open at https://ui.perfetto.dev.
  --metrics PATH         metrics-registry JSON (counters, gauges,
                         TTFT/TPOT/queue-delay/step-time histograms with
                         p50/p95/p99 + the engine summary) and a printed
                         table.  Either flag also records predicted-vs-
                         measured drift per dispatched scheme.

PR 9 overlaps host and device — ``--engine async`` runs the double-
buffered AsyncPagedMLAEngine: the fused decode+sample step for tick N is
dispatched and the host immediately schedules tick N+1 (admission, block
growth, CoW drain) while the device executes; only the sampled token ids
sync back, one tick later.  Tokens are identical to the synchronous
engine under greedy AND seeded sampling, preemption and speculation
included (tests/test_async_engine.py).

PR 10 makes the stream conversational: DECODE-filled blocks register
into the radix trie as each request crosses a block boundary (a second
turn re-hits its own generation, not just the shared preamble), prefix
matching is token-granular (a hit may end mid-block — the partial block
forks copy-on-write), and requests carry ``SamplingParams`` — including
``n``-way parallel sampling, which prefills once and forks the sequence
n ways through the ref-counted block pool:

  --n N                  parallel samples per request: one prefill, then
                         an n-way copy-on-write fork; children sample
                         with their own rid-folded PRNG keys, so tokens
                         match n independent requests while the prompt
                         blocks are allocated once per group
  --admission {cache_aware,fcfs}
                         'cache_aware' admits the waiting request with
                         the longest cached prefix first (fewest new
                         prefill tokens); 'fcfs' is strict arrival order
  --admission-age-bound N
                         starvation bound: a request bypassed N times is
                         admitted unconditionally next

Serving-flags summary (all compose):

  flag              default   effect
  --requests        10        number of requests in the Poisson stream
  --arrival-rate    0.4       mean requests per decode step (Poisson)
  --seed            0         weight init + sampling PRNG + workload seed
  --platform        tpu_v5e   hwmodel deployment point for auto-dispatch
  --engine          sync      paged engine: 'sync' | 'async' (overlapped)
  --max-batch       4         decode slots (continuous batching)
  --block-size      8         tokens per pool block
  --num-blocks      48        pool capacity
  --no-prefix-cache off       disable radix block sharing
  --prefill-chunk   16        batched prefill chunk (0 = per-request)
  --prefill-impl    auto      'gather' view vs 'pallas' in-place kernel
  --impl            ref       decode attention: 'ref' | 'kernel'
  --cache-dtype     bf16      pool storage: 'bf16' | 'int8' | 'fp8'
  --temperature     0.0       0 = greedy; else seeded sampling
  --top-k           0         top-k filter when sampling
  --mesh            ''        'DPxMP' sharded serving
  --spec-k          0         speculative decoding draft window
  --draft           shallow:2 draft spec ('shallow:N' | 'self')
  --trace           ''        Perfetto trace-event JSON output path
  --metrics         ''        metrics-registry JSON output path
  --n               1         parallel samples per request (CoW fork)
  --admission       cache_aware  'cache_aware' | 'fcfs' waiting order
  --admission-age-bound 64    cache-aware admission starvation bound
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --mesh on CPU needs the forced device count set BEFORE jax initializes;
# peek at argv so `python examples/serve_mla.py --mesh 2x2` (or --mesh=2x2)
# just works.
_spec = ""
for _i, _a in enumerate(sys.argv):
    if _a == "--mesh" and _i + 1 < len(sys.argv):
        _spec = sys.argv[_i + 1]
    elif _a.startswith("--mesh="):
        _spec = _a.split("=", 1)[1]
if _spec:
    try:
        _need = 1
        for _d in _spec.lower().replace(",", "x").split("x"):
            _need *= int(_d)
    except ValueError:
        _need = 0
    from repro.envflags import force_host_device_count
    force_host_device_count(_need)

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.models as models
from repro.core.schemes import auto_dispatch, step_time
from repro.hwmodel.platforms import PLATFORMS
from repro.nn import module as nnm
from repro.runtime import (AsyncPagedMLAEngine, PagedMLAEngine, Request,
                           SamplingParams, blocks_for)

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=10)
ap.add_argument("--max-batch", type=int, default=4)
ap.add_argument("--block-size", type=int, default=8)
ap.add_argument("--num-blocks", type=int, default=48)
ap.add_argument("--arrival-rate", type=float, default=0.4,
                help="mean requests per decode step (Poisson)")
ap.add_argument("--platform", default="tpu_v5e", choices=sorted(PLATFORMS))
ap.add_argument("--shared-prefix-len", type=int, default=16)
ap.add_argument("--no-prefix-cache", action="store_true")
ap.add_argument("--prefill-chunk", type=int, default=16)
ap.add_argument("--prefill-impl", default="auto",
                choices=("auto", "gather", "pallas"))
ap.add_argument("--impl", default="ref", choices=("ref", "kernel"))
ap.add_argument("--cache-dtype", default="bf16",
                choices=("bf16", "int8", "fp8"),
                help="pool storage dtype: int8/fp8 quantize on write with "
                     "per-row f32 scales, dequantized in-register by the "
                     "kernels (~0.3x cache bytes/token vs bf16)")
ap.add_argument("--temperature", type=float, default=0.0)
ap.add_argument("--top-k", type=int, default=0)
ap.add_argument("--mesh", default="",
                help="device mesh 'DPxMP' (e.g. '2x2' = data x model); "
                     "'' = single host")
ap.add_argument("--spec-k", type=int, default=0,
                help="speculative decoding draft window (0 = off)")
ap.add_argument("--draft", default="shallow:2",
                help="draft under --spec-k: 'shallow:N' | 'self'")
ap.add_argument("--trace", default="",
                help="write Perfetto trace-event JSON (request lifecycle "
                     "+ step phase spans) to this path")
ap.add_argument("--metrics", default="",
                help="write metrics-registry JSON to this path and print "
                     "the metrics table")
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--engine", default="sync", choices=("sync", "async"),
                help="paged engine: 'sync' waits on the device each tick; "
                     "'async' double-buffers host scheduling against device "
                     "execution (token-identical)")
ap.add_argument("--n", type=int, default=1,
                help="parallel samples per request: one prefill, then an "
                     "n-way copy-on-write fork of the sequence")
ap.add_argument("--admission", default="cache_aware",
                choices=("cache_aware", "fcfs"),
                help="waiting-queue order: longest-cached-prefix first "
                     "(aging-bounded) vs strict arrival order")
ap.add_argument("--admission-age-bound", type=int, default=64,
                help="admit a request unconditionally after cache-aware "
                     "admission bypassed it this many times")
args = ap.parse_args()

cfg = configs.smoke("deepseek-v2-236b")
mla = cfg.mla_config()
plat = PLATFORMS[args.platform]
bs = args.block_size
mesh = None
if args.mesh:
    from repro.launch.serve import _parse_mesh
    mesh = _parse_mesh(args.mesh)
    print(f"mesh {args.mesh}: batch over 'data', heads over 'model', "
          f"latent pool replicated ({jax.device_count()} devices)")

print(f"platform {plat.name}: ridge OI = {plat.ridge_oi:.0f} FLOP/B")
for L, B in ((64, 1), (64, args.max_batch), (2048, args.max_batch)):
    sch = auto_dispatch(mla, plat, cache_len=L, batch=B, paged_block=bs)
    ts = {s: step_time(s, mla, plat, cache_len=L, batch=B, paged_block=bs)
          for s in ("seq", "rc", "ru")}
    print(f"  live point (B={B}, L={L}): " + "  ".join(
        f"{s}={t*1e6:7.2f}us" for s, t in ts.items()) + f"  -> '{sch}'")

params = nnm.init_params(jax.random.PRNGKey(args.seed),
                         models.model_defs(cfg), jnp.float32)
# Poisson arrivals, mixed prompt/generation lengths (quantized to bound
# prefill recompiles).
rng = np.random.default_rng(args.seed + 1)
gaps = rng.exponential(1.0 / args.arrival_rate, args.requests)
arrivals = np.floor(np.cumsum(gaps)).astype(int)
preamble = rng.integers(0, cfg.vocab,
                        (args.shared_prefix_len,)).astype(np.int32)
reqs = []
for i in range(args.requests):
    plen = int(rng.choice([8, 16, 24, 32]))
    gen = int(rng.integers(4, 20))
    prompt = np.concatenate(
        [preamble, rng.integers(0, cfg.vocab, (plen,)).astype(np.int32)])
    # rids spaced by n: fork-group children claim rid+1..rid+n-1
    reqs.append(Request(rid=i * args.n, prompt=prompt,
                        arrival=int(arrivals[i]),
                        sampling=SamplingParams(max_tokens=gen, n=args.n)))

per_req = max(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs)
draft_cfg = draft_params = None
if args.spec_k:
    from repro.runtime.spec import parse_draft_spec
    draft_cfg, draft_params = parse_draft_spec(args.draft, cfg, params)
    print(f"speculative decoding: k={args.spec_k}, draft={args.draft} "
          f"({draft_cfg.n_layers} of {cfg.n_layers} layers)")
tel = None
if args.trace or args.metrics:
    from repro.obs import Telemetry
    tel = Telemetry.on(trace=bool(args.trace), metrics=bool(args.metrics),
                       drift=True)
engine_cls = AsyncPagedMLAEngine if args.engine == "async" else PagedMLAEngine
engine = engine_cls(cfg, params, num_blocks=args.num_blocks,
                    block_size=bs, max_batch=args.max_batch,
                    max_blocks_per_req=per_req,
                    compute_dtype=jnp.float32, impl=args.impl,
                    scheme="auto", platform=plat,
                    enable_prefix_cache=not args.no_prefix_cache,
                    prefill_mode="chunked" if args.prefill_chunk
                    else "per_request",
                    prefill_impl=args.prefill_impl,
                    prefill_chunk=args.prefill_chunk or 32,
                    temperature=args.temperature, top_k=args.top_k,
                    sample_seed=args.seed, mesh=mesh,
                    spec_k=args.spec_k, draft_cfg=draft_cfg,
                    draft_params=draft_params,
                    cache_dtype=args.cache_dtype, telemetry=tel,
                    admission=args.admission,
                    admission_age_bound=args.admission_age_bound)
total_need = sum(blocks_for(r.plen + r.max_new + 1, bs) for r in reqs)
print(f"\n{args.requests} requests (prompts 8-32, gen 4-19), pool "
      f"{args.num_blocks - 1} usable blocks x {bs} tokens "
      f"(peak demand {total_need} blocks if all resident)")

t0 = time.time()
summary = engine.run(reqs, log_every=10)
dt = time.time() - t0

lat = [r.finished_step - r.arrival for r in engine.sched.finished]
print(f"\nserved {args.requests} requests in {summary['steps']:.0f} steps / "
      f"{dt:.2f}s wall ({summary['tokens_per_s']:.1f} decode tok/s on CPU)")
print(f"  mid-generation admissions : {summary['mid_gen_admissions']:.0f}"
      f" / {summary['admissions']:.0f}")
print(f"  preemptions (recompute)   : {summary['preemptions']:.0f}")
print(f"  cache utilization         : {summary['cache_utilization']:.2f} "
      f"(valid tokens / allocated block slots)")
print(f"  pool occupancy            : {summary['pool_occupancy']:.2f}")
print(f"  scheme usage              : {summary['schemes_used']}")
print(f"  prefix hit rate           : {summary['prefix_hit_rate']:.2f} "
      f"({summary['prefix_hit_tokens']:.0f}/{summary['prompt_tokens']:.0f} "
      f"prompt tokens shared)")
print(f"  prefilled tokens / chunks : {summary['prefill_tokens']:.0f} / "
      f"{summary['prefill_chunks']:.0f} "
      f"({summary['prefill_compiles']:.0f} compiled prefill shapes)")
print(f"  cache evictions / CoW     : {summary['prefix_evictions']:.0f} / "
      f"{summary['prefix_cow_copies']:.0f}")
if args.n > 1:
    print(f"  fork groups / children    : {summary['fork_groups']:.0f} / "
          f"{summary['fork_children']:.0f} (one prefill per group)")
if args.spec_k:
    print(f"  spec accept / emit rate   : "
          f"{summary['spec_accept_rate']:.2f} "
          f"({summary['spec_accepted']:.0f}/"
          f"{summary['spec_drafted']:.0f} drafts), "
          f"{summary['spec_mean_emitted']:.2f} tokens/round over "
          f"{summary['spec_rounds']:.0f} rounds")
print(f"  latency steps p50/max     : {int(np.median(lat))}/{int(max(lat))}")
first = min(engine.sched.finished, key=lambda r: r.rid)
print("first request's tokens:", np.asarray(first.output)[:16])

if tel is not None:
    tel.finalize(engine)
    written = tel.export(trace_path=args.trace or None,
                         metrics_path=args.metrics or None)
    for channel, path in written.items():
        print(f"telemetry: {channel} -> {path}")
    if tel.metrics is not None:
        ttft = tel.metrics.histogram("ttft_ms").summary()
        print(f"  TTFT ms p50/p95           : {ttft.get('p50', 0):.1f}/"
              f"{ttft.get('p95', 0):.1f}")
        print(tel.metrics.render_table())
    if tel.drift is not None and tel.drift.rows:
        d = tel.drift.report()
        print(f"roofline drift: {d['rows']} rows, time-ratio p50 "
              f"{d['summary']['time_ratio_p50']:.3g} (CPU wall vs "
              f"{plat.name} prediction), spread "
              f"{d['summary']['spread']:.2f}")

# latent-cache footprint vs dense-KV equivalent (the paper's Fig 3 point),
# at the pool's STORAGE dtype (int8/fp8 pay 1 byte/elem + per-row scales)
from repro.core.cache import bytes_per_token_latent
lat_b = bytes_per_token_latent(
    mla.kv_lora_rank, mla.qk_rope_dim, 2,
    None if args.cache_dtype == "bf16" else args.cache_dtype)
dense_b = 2 * cfg.n_heads * mla.qk_dim * 2
print(f"KV bytes/token/layer: latent {lat_b:.0f} ({args.cache_dtype}) vs "
      f"dense {dense_b} ({dense_b / lat_b:.1f}x smaller -> "
      f"{dense_b / lat_b:.1f}x more requests per pool)")
