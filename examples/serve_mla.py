"""End-to-end serving driver (the paper is an inference paper — this is
the headline example): batched requests against an MLA model with the
execution scheme picked per deployment platform, latent-KV caching, and
per-phase timing.

    PYTHONPATH=src python examples/serve_mla.py --batch 8 --gen 32
    PYTHONPATH=src python examples/serve_mla.py --platform edge_tpu
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.models as models
from repro.core.schemes import auto_dispatch, step_time
from repro.hwmodel.platforms import PLATFORMS
from repro.launch.serve import _prepare_mla
from repro.nn import module as nnm
from repro.runtime import make_prefill_step, make_serve_step

ap = argparse.ArgumentParser()
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prompt-len", type=int, default=48)
ap.add_argument("--gen", type=int, default=32)
ap.add_argument("--platform", default="tpu_v5e", choices=sorted(PLATFORMS))
args = ap.parse_args()

cfg = configs.smoke("deepseek-v2-236b")
mla = cfg.mla_config()
plat = PLATFORMS[args.platform]
capacity = args.prompt_len + args.gen + 1

scheme = auto_dispatch(mla, plat, cache_len=capacity, batch=args.batch)
print(f"platform {plat.name}: ridge OI = {plat.ridge_oi:.0f} FLOP/B "
      f"-> scheme '{scheme}'")
for s in ("naive", "seq", "rc", "ru"):
    t = step_time(s, mla, plat, cache_len=capacity, batch=args.batch)
    print(f"  modeled decode step ({s:6s}): {t*1e6:9.2f} us/layer")

params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                         jnp.float32)
params = _prepare_mla(params, cfg, scheme)
prefill = make_prefill_step(cfg, None, batch=args.batch, capacity=capacity,
                            compute_dtype=jnp.float32, scheme=scheme)
decode = make_serve_step(cfg, None, compute_dtype=jnp.float32, scheme=scheme)

prompts = jax.random.randint(jax.random.PRNGKey(1),
                             (args.batch, args.prompt_len), 0, cfg.vocab)
t0 = time.time()
logits, cache = prefill(params, prompts)
jax.block_until_ready(logits)
print(f"prefill {args.batch} x {args.prompt_len}: {time.time()-t0:.2f}s")

generated = []
t0 = time.time()
for i in range(args.gen):
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    generated.append(np.asarray(nxt))
    logits, cache = decode(params, nxt, cache, args.prompt_len + i)
jax.block_until_ready(logits)
dt = time.time() - t0
print(f"decode {args.gen} steps x {args.batch} seqs: {dt:.2f}s "
      f"({args.gen*args.batch/dt:.1f} tok/s on CPU)")
print("first sequence:", np.stack(generated, 1)[0][:24])

# latent-cache footprint vs dense-KV equivalent (the paper's Fig 3 point)
lat = (mla.kv_lora_rank + mla.qk_rope_dim) * 2
dense = 2 * cfg.n_heads * mla.qk_dim * 2
print(f"KV-cache bytes/token/layer: latent {lat} vs dense {dense} "
      f"({dense/lat:.1f}x smaller)")
