"""Quickstart: the paper's technique in 60 lines.

Builds a small MLA model (DeepSeek-V2-style, reduced dims), trains a few
steps on the synthetic pipeline, then serves tokens under ALL FOUR MLA
execution schemes, verifying they emit identical tokens — the paper's
central observation ("both implement the same algorithm with identical
weights; the choice between them can be made dynamically").

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
import repro.models as models
from repro.core.schemes import auto_dispatch
from repro.data import DataConfig, SyntheticLM
from repro.hwmodel.platforms import PLATFORMS
from repro.nn import module as nnm
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import TrainStepConfig, make_prefill_step, \
    make_serve_step, make_train_step

cfg = configs.smoke("deepseek-v2-236b")          # MLA + MoE, reduced dims
print(f"model: {cfg.name}  ({models.param_count(cfg)/1e6:.2f}M params)")

# --- train a few steps -----------------------------------------------------
params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg),
                         jnp.float32)
opt_cfg = AdamWConfig(lr=1e-3)
opt = adamw_init(params, opt_cfg)
step, _ = make_train_step(cfg, None, opt_cfg,
                          TrainStepConfig(compute_dtype=jnp.float32))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
for i in range(10):
    toks, labels = data.next_batch()
    params, opt, m = step(params, opt, {"tokens": jnp.asarray(toks),
                                        "labels": jnp.asarray(labels)})
    if i % 3 == 0:
        print(f"  step {i}: loss {float(m['loss']):.4f}")

# --- the paper's co-design insight, executable -----------------------------
for plat in ("edge_tpu", "a17_pro", "tpu_v5e"):
    s = auto_dispatch(cfg.mla_config(), PLATFORMS[plat], cache_len=4096)
    print(f"auto_dispatch({plat:10s}) -> MLA scheme '{s}'")

# --- serve under every scheme: identical tokens ----------------------------
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
outs = {}
for scheme in ("naive", "seq", "rc", "ru"):
    from repro.launch.serve import _prepare_mla
    p = _prepare_mla(params, cfg, scheme)
    prefill = make_prefill_step(cfg, None, batch=2, capacity=24,
                                compute_dtype=jnp.float32, scheme=scheme)
    decode = make_serve_step(cfg, None, compute_dtype=jnp.float32,
                             scheme=scheme)
    logits, cache = prefill(p, prompt)
    toks = [int(jnp.argmax(logits[0]))]
    for t in range(6):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = decode(p, nxt, cache, 12 + t)
        toks.append(int(jnp.argmax(logits[0])))
    outs[scheme] = toks
    print(f"  scheme {scheme:6s}: {toks}")
assert all(v == outs["naive"] for v in outs.values()), \
    "schemes must emit identical tokens"
print("OK — all four execution schemes emit identical tokens.")
