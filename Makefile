# Tier-1 verification (ROADMAP.md).  -x fails fast; pytest exits non-zero
# on collection errors, so import-time breakage cannot hide behind a
# passing subset.  `make test` runs EVERYTHING and remains the union of
# what CI runs: ci.yml calls the lane targets below (test-lane-fast +
# test-kernels + test-mesh + test-audit), whose marker expressions all
# derive from the single KERNEL_MARKER/MESH_MARKER/AUDIT_MARKER variables
# — so the CI union stays provably equal to `make test` instead of
# drifting in two files.
#
# `make audit` runs the static hot-path auditor standalone (no pytest):
# compiles every serve-step cell (single-device + forced-8-device mesh),
# checks donation aliasing / pallas gather budget / dtype discipline /
# roofline conformance on the optimized HLO, and jaxlints src/repro.
# Exits non-zero on any unsuppressed finding.  The CI `audit` job runs
# the pytest lane (`make test-audit`), which drives the same matrix plus
# the injected-violation regression suite.
PY ?= python
# extra pytest flags (CI threads --junitxml=... through here)
PYTEST_FLAGS ?=

# ---- single source of truth for the test-lane markers -------------------
KERNEL_MARKER := kernel
MESH_MARKER := mesh
AUDIT_MARKER := audit
FAST_LANE_EXPR := not $(KERNEL_MARKER) and not $(MESH_MARKER) \
	and not $(AUDIT_MARKER)

.PHONY: test test-fast test-lane-fast test-kernels test-mesh test-audit \
	audit lint bench-serving bench-smoke bench-gate docs-check

test:
	PYTHONPATH=src $(PY) -m pytest -x -q $(PYTEST_FLAGS)

# CI lane 1: everything minus the kernel/mesh suites (their union with
# the two lanes below == `make test`).
test-lane-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "$(FAST_LANE_EXPR)" \
		$(PYTEST_FLAGS)

# CI lane 2: Pallas kernel oracle-parity suites alone
# (pl.pallas_call(interpret=True) on CPU — they EXECUTE, not skip).
test-kernels:
	PYTHONPATH=src $(PY) -m pytest -q -m "$(KERNEL_MARKER)" $(PYTEST_FLAGS)

# CI lane 3: multi-device sharded-serving parity suites.  The forced
# host-platform device count makes the sharded paths EXECUTE on a
# CPU-only box; the suites' subprocess drivers also force it themselves,
# so they pass under plain `make test` too.
test-mesh:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		PYTHONPATH=src $(PY) -m pytest -q -m "$(MESH_MARKER)" \
		$(PYTEST_FLAGS)

# CI lane 4: the static hot-path auditor suite (compile-only conformance
# checks + injected-violation regressions; the mesh cells run through a
# subprocess that forces 8 host devices itself).
test-audit:
	PYTHONPATH=src $(PY) -m pytest -q -m "$(AUDIT_MARKER)" $(PYTEST_FLAGS)

# Standalone auditor run (same checks, direct CLI output, no pytest).
audit:
	$(PY) scripts/audit_steps.py --matrix all

# Inner-loop development: the fast lane minus the slow dry-run compile
# cells on top.
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "$(FAST_LANE_EXPR)" \
		--ignore=tests/test_dryrun_small.py $(PYTEST_FLAGS)

# Lint gate (CI `lint` job; ruff ships via requirements-dev.txt).
# `ruff check` runs the error-class rules everywhere; `ruff format
# --check` is a RATCHET — FORMAT_PATHS lists the files already
# formatted, grow it file by file as they are cleaned up.
# Remaining outside the ratchet: tests/ and src/repro/ outside
# analysis/.
FORMAT_PATHS := \
	benchmarks/bench_fig2_ordering.py \
	benchmarks/bench_fig3_ops_mem.py \
	benchmarks/bench_fig4_oi.py \
	benchmarks/bench_fig5_throughput.py \
	benchmarks/bench_fig6_energy.py \
	benchmarks/bench_kernels.py \
	benchmarks/bench_serving.py \
	benchmarks/bench_table1_params.py \
	benchmarks/check_regression.py \
	benchmarks/common.py \
	benchmarks/roofline_report.py \
	benchmarks/run.py \
	scripts/audit_steps.py \
	scripts/check_docs.py \
	scripts/junit_summary.py \
	src/repro/analysis/__init__.py \
	src/repro/analysis/audit.py \
	src/repro/analysis/audit_allowlist.py \
	src/repro/analysis/hlo.py \
	src/repro/analysis/jaxlint.py
lint:
	ruff check .
	ruff format --check $(FORMAT_PATHS)

bench-serving:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 12 --steps 200

# Tiny CPU config wired into CI (exits non-zero if any serving check
# regresses: prefix hit rate, prefill-token/block savings, bounded
# prefill compiles, utilization vs the contiguous baseline, sharded-row
# token parity + per-device paged-byte scaling, spec-decode parity +
# acceptance + modeled amortization, telemetry parity + trace validity +
# roofline-drift coverage + disabled-mode overhead).  Artifacts include
# trace_serving.json / metrics_serving.json / bench_drift.json.
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 6 \
		--max-batch 2 --block-size 8 --prefill-chunk 8 \
		--shared-prefix-len 16 --steps 300

# CI `bench-gate` job: run the smoke bench, then diff its JSON artifacts
# against the committed baselines (benchmarks/baselines/) with
# per-metric tolerances.  Refresh after an intentional perf change with
# `python benchmarks/check_regression.py --update`.
bench-gate: bench-smoke
	$(PY) benchmarks/check_regression.py

# CI `docs` job: intra-repo markdown links resolve, the README flag
# table covers every launch/serve.py flag, and the serving CLIs'
# module docstrings document their own argparse (static — no jax).
docs-check:
	$(PY) scripts/check_docs.py
