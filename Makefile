# Tier-1 verification (ROADMAP.md).  -x fails fast; pytest exits non-zero
# on collection errors, so import-time breakage cannot hide behind a
# passing subset.  `make test` runs EVERYTHING and remains the union of
# what CI runs (ci.yml partitions it into not-kernel/not-mesh + kernel +
# mesh steps so each class of regression is visible at a glance).
PY ?= python

.PHONY: test test-fast test-kernels test-mesh bench-serving bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Pallas kernel oracle-parity suites alone (pl.pallas_call(interpret=True)
# on CPU — they EXECUTE in CI, not skip).  Fast inner loop for kernel work.
test-kernels:
	PYTHONPATH=src $(PY) -m pytest -q -m kernel

# Multi-device sharded-serving parity suites (tests/test_mesh_paged.py).
# The forced host-platform device count makes the sharded paths EXECUTE on
# a CPU-only box; the suites' subprocess drivers also force it themselves,
# so they pass under plain `make test` too — this target is the fast inner
# loop + the dedicated CI `mesh` job.
test-mesh:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		PYTHONPATH=src $(PY) -m pytest -q -m mesh

# Inner-loop development: skip the slow dry-run compile cells AND the
# kernel/mesh suites (interpret-mode Pallas and the 8-virtual-device
# subprocess sweeps are slow inner loops — they belong in `make test` /
# `make test-kernels` / `make test-mesh`).
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not kernel and not mesh" \
		--ignore=tests/test_dryrun_small.py

bench-serving:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 12 --steps 200

# Tiny CPU config wired into CI (exits non-zero if any serving check
# regresses: prefix hit rate, prefill-token/block savings, bounded
# prefill compiles, utilization vs the contiguous baseline, sharded-row
# token parity + per-device paged-byte scaling).
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 6 \
		--max-batch 2 --block-size 8 --prefill-chunk 8 \
		--shared-prefix-len 16 --steps 300
