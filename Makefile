# Tier-1 verification (ROADMAP.md).  -x fails fast; pytest exits non-zero
# on collection errors, so import-time breakage cannot hide behind a
# passing subset.
PY ?= python

.PHONY: test test-fast test-kernels bench-serving bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Pallas kernel oracle-parity suites alone (pl.pallas_call(interpret=True)
# on CPU — they EXECUTE in CI, not skip).  Fast inner loop for kernel work.
test-kernels:
	PYTHONPATH=src $(PY) -m pytest -q -m kernel

# Skip the slow dry-run compile cells during inner-loop development.
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q --ignore=tests/test_dryrun_small.py

bench-serving:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 12 --steps 200

# Tiny CPU config wired into CI (exits non-zero if any serving check
# regresses: prefix hit rate, prefill-token/block savings, bounded
# prefill compiles, utilization vs the contiguous baseline).
bench-smoke:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 6 \
		--max-batch 2 --block-size 8 --prefill-chunk 8 \
		--shared-prefix-len 16 --steps 300
