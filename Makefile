# Tier-1 verification (ROADMAP.md).  -x fails fast; pytest exits non-zero
# on collection errors, so import-time breakage cannot hide behind a
# passing subset.
PY ?= python

.PHONY: test test-fast bench-serving

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Skip the slow dry-run compile cells during inner-loop development.
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q --ignore=tests/test_dryrun_small.py

bench-serving:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py --requests 12 --steps 96
