"""While-loop-aware cost accounting over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body (i.e.
every ``lax.scan`` over layers, every interpreted Pallas grid) exactly ONCE,
under-reporting FLOPs/bytes by the trip count (verified empirically — see
EXPERIMENTS.md §Methodology).  This module parses ``compiled.as_text()``,
builds the computation call graph (fusion ``calls=``, while ``body=/
condition=``, ``to_apply=``), extracts static trip counts from while
conditions, and accumulates:

  * flops            — dot ops: 2 * |output| * prod(lhs contracting dims)
  * bytes            — Σ (operand sizes + output size) of scheduled ops
                       (fusion-internal ops are free, matching XLA's
                       post-fusion "bytes accessed" convention)
  * collective_bytes — ring-model traffic per chip:
        all-gather        (G-1) * operand
        reduce-scatter    (G-1)/G * operand
        all-reduce        2*(G-1)/G * operand
        all-to-all        (G-1)/G * operand
        collective-permute operand

All quantities are PER-DEVICE (the SPMD program is per-device); multiply by
#chips for global totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "s32": 4,
    "u32": 4,
    "s64": 8,
    "u64": 8,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "bf16": 2,
    "f16": 2,
    "f32": 4,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "token": 0,
    "opaque": 0,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# op type may be a tuple "(s32[], bf16[..]{1,0}, /*index=5*/f32[...], ...)"
# whose /*index=N*/ comments contain '=' — match balanced-paren-free body.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    shapes: Dict[str, str]  # op name -> type string


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_raw_operand_bytes: float = 0.0
    while_trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)


_SKIP_BYTES = {
    "parameter",
    "constant",
    "tuple",
    "get-tuple-element",
    "bitcast",
    "after-all",
    "partition-id",
    "replica-id",
    "while",
    "fusion-skip",
    "conditional",
    "call",
    "custom-call-skip",
    # 'convert' is free: on TPU dtype converts fuse into producers/consumers;
    # on the CPU lowering they additionally appear as float-normalization
    # artifacts (bf16 ops sandwiched in f32 converts) that do not exist in
    # the TPU executable.  See EXPERIMENTS.md §Methodology.
    "convert",
    "copy-done",
    "copy-start",
}

# Ops whose HBM traffic is the SLICE they move, not the buffer they index:
#   dynamic-slice  reads |output| bytes (+ tiny indices)
#   dynamic-update-slice updates |update| bytes in place (read+write)
# Counting the full operand would bill a 17 GB stacked decode cache once
# per layer per step (~1000 GB/step phantom traffic).
_SLICE_OPS = {
    "dynamic-slice",
    "dynamic-update-slice",
    "slice",
    "gather",
    "scatter",
    "pad",
}


def _parse_operands(argstr: str) -> List[str]:
    """Operand names from an op's argument list (up to the closing paren)."""
    depth, out, cur = 0, [], []
    for ch in argstr:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        cur.append(ch)
    body = "".join(cur)
    names = re.findall(r"%([\w.\-]+)", body)
    return names


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(2), bool(m.group(1)), [], {})
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, opcode, rest = m.groups()
            ops = _parse_operands(rest)
            cur.ops.append(Op(name, type_str, opcode, ops, rest))
            cur.shapes[name] = type_str
    return comps


def _group_size(attrs: str, num_partitions: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return num_partitions


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_dims = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    lhs_type = comp.shapes.get(op.operands[0], "") if op.operands else ""
    _, lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _while_trip_count(cond: Computation) -> Optional[int]:
    """Static trip count: the s32 constant in the condition region
    (scan/fori induction always starts at 0 and compares LT limit)."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant" and op.type_str.startswith("s32"):
            # op.attrs holds everything after "constant(", e.g. "6), meta..."
            m = re.match(r"(-?\d+)\)", op.attrs)
            if m:
                consts.append(int(m.group(1)))
    if len(consts) == 1:
        return consts[0]
    if consts:
        return max(consts)  # limit is the largest (offsets are small)
    return None


_TRANSPARENT_OPS = {
    "parameter",
    "convert",
    "bitcast",
    "reshape",
    "transpose",
    "copy",
    "tuple",
    "get-tuple-element",
    "constant",
}


def _transparent_comps(comps: Dict[str, Computation]) -> set:
    """Computations that only move/convert data (no math): fusions calling
    them are dtype/layout shims.  On TPU these fuse into their consumers;
    on the CPU lowering they are float-normalization artifacts (bf16 ops
    rewritten to f32 with convert sandwiches).  Their traffic is charged at
    the PRE-convert operand size via _EffectiveShapes."""
    out = set()
    for c in comps.values():
        if c.ops and all(op.opcode in _TRANSPARENT_OPS for op in c.ops):
            out.add(c.name)
    return out


class _EffectiveShapes:
    """Resolve an op name to the type it would have without convert shims."""

    def __init__(
        self, comp: Computation, comps: Dict[str, Computation], transparent: set
    ):
        self.comp, self.comps, self.transparent = comp, comps, transparent
        self.memo: Dict[str, str] = {}

    def type_of(self, name: str, depth: int = 0) -> str:
        if name in self.memo:
            return self.memo[name]
        t = self.comp.shapes.get(name, "")
        if depth < 8:
            op = next((o for o in self.comp.ops if o.name == name), None)
            if op is not None:
                if op.opcode == "convert" and op.operands:
                    t = self.type_of(op.operands[0], depth + 1)
                elif op.opcode == "fusion":
                    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                    if m and m.group(1) in self.transparent and op.operands:
                        # shim fusion: effective type = its largest operand
                        ts = [self.type_of(o, depth + 1) for o in op.operands]
                        t = max(ts, key=_shape_bytes, default=t)
                elif any(op.opcode.startswith(c) for c in COLLECTIVES) and op.operands:
                    # own dims, operand's effective dtype (a gather of a
                    # convert-shimmed tensor moves bf16 on TPU)
                    src = self.type_of(op.operands[0], depth + 1)
                    m_dt = _SHAPE_RE.search(src)
                    if m_dt:
                        t = re.sub(r"^\(?\w+\[", m_dt.group(1) + "[", t, count=1)
        self.memo[name] = t
        return t

    def bytes_of(self, name: str) -> int:
        return _shape_bytes(self.type_of(name))


def _fusion_dus_update_bytes(
    op: Op, comp: Computation, comps: Dict[str, Computation]
) -> Optional[float]:
    """If ``op`` is a fusion whose body performs a dynamic-update-slice of a
    loop-carried buffer, charge 2x the update slice (in-place read+write on
    TPU), not the full buffer."""
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    if not m or m.group(1) not in comps:
        return None
    inner = comps[m.group(1)]
    dus = [o for o in inner.ops if o.opcode == "dynamic-update-slice"]
    if not dus:
        return None
    total = 0.0
    for d in dus:
        upd = inner.shapes.get(d.operands[1], "") if len(d.operands) > 1 else ""
        total += 2.0 * _shape_bytes(upd)
    return total


def _fusion_operand_bytes(
    op: Op, comp: Computation, comps: Dict[str, Computation], eff: "_EffectiveShapes"
) -> float:
    """Fusion traffic = output + Σ operands, EXCEPT operands the fusion body
    consumes only through (dynamic-)slice ops: those read the slice, not
    the buffer (in-loop reads of stacked scan inputs — the weight/cache
    xs of a lax.scan — would otherwise be billed at full-stack size every
    iteration)."""
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    inner = comps.get(m.group(1)) if m else None
    total = eff.bytes_of(op.name)
    for idx, o in enumerate(op.operands):
        charged = None
        if inner is not None:
            pname = next(
                (
                    p.name
                    for p in inner.ops
                    if p.opcode == "parameter" and p.attrs.startswith(f"{idx})")
                ),
                None,
            )
            if pname is not None:
                users = [u for u in inner.ops if pname in u.operands]
                if users and all(
                    u.opcode in ("dynamic-slice", "slice") for u in users
                ):
                    charged = sum(_shape_bytes(u.type_str) for u in users)
        total += charged if charged is not None else eff.bytes_of(o)
    return total


def analyze(text: str, num_partitions: int = 1) -> HLOCost:
    comps = parse_computations(text)
    cost = HLOCost()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        cost.warnings.append("no ENTRY computation found")
        return cost
    transparent = _transparent_comps(comps)

    # ---- pre-pass: call edges (comp -> [(callee, factor)]) -------------
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                trip = None
                m_trip = _TRIP_RE.search(op.attrs)  # XLA backend_config
                if m_trip:
                    trip = int(m_trip.group(1))
                if trip is None and cond and cond.group(1) in comps:
                    trip = _while_trip_count(comps[cond.group(1)])
                if trip is None:
                    trip = 1
                    cost.warnings.append(f"unknown trip count for {op.name}")
                cost.while_trip_counts[op.name] = trip
                if body and body.group(1) in comps:
                    edges[comp.name].append((body.group(1), float(trip)))
                if cond and cond.group(1) in comps:
                    edges[comp.name].append((cond.group(1), float(trip + 1)))
            else:
                for attr_key in ("calls", "to_apply"):
                    mm = re.search(rf"{attr_key}=%?([\w.\-]+)", op.attrs)
                    if mm and mm.group(1) in comps:
                        edges[comp.name].append((mm.group(1), 1.0))

    # ---- multipliers via fixed-point over the call graph ---------------
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    for _ in range(len(comps) + 2):
        changed = False
        for name, comp_edges in edges.items():
            m_self = mult.get(name, 0.0)
            if m_self == 0.0:
                continue
            for callee, factor in comp_edges:
                new = m_self * factor
                if new > mult[callee] + 1e-9:
                    mult[callee] = new
                    changed = True
        if not changed:
            break

    # which computations are fusion-internal (bytes are free there)
    fusion_called: set = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                mm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if mm:
                    fusion_called.add(mm.group(1))

    # ---- accumulate ----------------------------------------------------
    for comp in comps.values():
        k = mult.get(comp.name, 0.0)
        if k == 0.0:
            continue
        scheduled = comp.name not in fusion_called
        eff = _EffectiveShapes(comp, comps, transparent)
        for op in comp.ops:
            if op.opcode == "dot":
                cost.flops += k * _dot_flops(op, comp)
            elif op.opcode == "convolution":
                # rare here; approximate: 2*|out|*prod(kernel spatial+cin)
                _, out_dims = _shape_dims(op.type_str)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                rhs_type = (
                    comp.shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
                )
                _, rhs_dims = _shape_dims(rhs_type)
                kern = 1
                for d in rhs_dims[:-1]:
                    kern *= d
                cost.flops += k * 2.0 * out_elems * kern
            if op.opcode in COLLECTIVES or any(
                op.opcode.startswith(c + "-") for c in COLLECTIVES
            ):
                base = next(c for c in COLLECTIVES if op.opcode.startswith(c))
                # pre-convert sizes: on TPU the gathered tensor stays bf16
                operand_bytes = sum(eff.bytes_of(o) for o in op.operands)
                g = _group_size(op.attrs, num_partitions)
                factor = {
                    "all-gather": float(g - 1),
                    "reduce-scatter": (g - 1) / max(g, 1),
                    "all-reduce": 2.0 * (g - 1) / max(g, 1),
                    "all-to-all": (g - 1) / max(g, 1),
                    "collective-permute": 1.0,
                }[base]
                traffic = k * operand_bytes * factor
                cost.collective_bytes += traffic
                cost.collective_raw_operand_bytes += k * operand_bytes
                cost.collective_by_kind[base] = (
                    cost.collective_by_kind.get(base, 0.0) + traffic
                )
            if scheduled and op.opcode not in _SKIP_BYTES:
                if op.opcode in _SLICE_OPS:
                    if op.opcode == "dynamic-update-slice":
                        upd = (
                            eff.bytes_of(op.operands[1]) if len(op.operands) > 1 else 0
                        )
                        b = 2 * upd  # read+write the slice
                    elif op.opcode == "scatter":
                        upd = eff.bytes_of(op.operands[-1]) if op.operands else 0
                        b = 2 * upd
                    else:  # ds/slice/gather/pad
                        b = 2 * _shape_bytes(op.type_str)
                elif op.opcode == "fusion":
                    mm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                    if mm and mm.group(1) in transparent:
                        b = 0  # dtype/layout shim
                    else:
                        dus_b = _fusion_dus_update_bytes(op, comp, comps)
                        if dus_b is not None:
                            b = dus_b
                        else:
                            b = _fusion_operand_bytes(op, comp, comps, eff)
                else:
                    b = eff.bytes_of(op.name) + sum(
                        eff.bytes_of(o) for o in op.operands
                    )
                cost.bytes += k * b
    return cost


def analyze_compiled(compiled, num_partitions: int = 1) -> HLOCost:
    return analyze(compiled.as_text(), num_partitions=num_partitions)
