"""Static analysis over compiled artifacts: the while-loop-aware HLO cost
parser (``hlo``), the compile-time hot-path auditor (``audit``), and the
JAX-footgun AST linter (``jaxlint``).  Driven by ``scripts/audit_steps.py``
and the ``make audit`` CI lane."""
