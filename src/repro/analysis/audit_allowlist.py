"""Committed allowlist for the static hot-path auditor.

Every entry documents a KNOWN, understood exception to an audit rule.  An
entry matches a finding when ``rule`` equals the finding's rule, ``where``
is a substring of the finding's location (step-matrix cell or file:line),
and ``match`` is a substring of the finding's detail text.  Matched
findings are reported as *suppressed* — still printed by
``scripts/audit_steps.py``, never counted toward the exit code.

Keep this list SHORT and justified: an allowlist entry is a debt marker,
not a mute button.  Adding one requires a ``reason`` naming why the
violation is acceptable (or what tracked work removes it).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class AllowlistEntry:
    rule: str  # audit rule name, exact match
    where: str  # substring of the finding location
    match: str  # substring of the finding detail
    reason: str  # why this is acceptable (documentation, not decoration)


ALLOWLIST: Tuple[AllowlistEntry, ...] = ()
