"""jaxlint: an AST pass over ``src/repro`` catching JAX footguns that
neither the type checker nor the test suite sees.

Rules (each finding carries file:line):

  JL001 prng-key-reuse      — the same PRNG key variable is consumed by
        two ``jax.random`` sampling calls on one control-flow path
        without being re-split or re-bound in between.  Reused keys
        silently correlate the two draws (same stream), which corrupts
        sampling-based evals without failing anything.
  JL002 tracer-python-if    — a Python ``if``/``while``/``assert`` whose
        test calls a jnp array-reducing function (``jnp.any``/``all``/
        ``max``/...) inside a jitted function.  Under jit this either
        raises a ConcretizationTypeError at trace time or, worse, bakes
        one branch in forever when the value happens to be concrete.
  JL003 captured-mutation   — a function decorated with ``jax.jit`` (or
        ``functools.partial(jax.jit, ...)``) assigns to / mutates a name
        captured from an enclosing scope.  The mutation runs ONCE at
        trace time, then never again — classic silent-staleness.
  JL004 use-after-donation  — a buffer passed in a donated position of a
        literal ``jax.jit(f, donate_argnums=...)(...)`` call is read
        again afterwards without rebinding.  Donated buffers are
        deleted; the read raises at runtime only on the paths that
        hit it.

The pass is deliberately first-order: it tracks plain ``Name`` nodes
within one function scope (branch bodies checked independently, nested
scopes excluded), preferring false negatives over noisy false positives —
every finding it emits should be worth reading.  Known exceptions go in
``audit_allowlist``.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .audit import Finding

# jax.random callables that CONSUME a key passed as first argument.
# fold_in/split derive fresh keys and are the sanctioned way to re-use.
_KEY_CONSUMERS = {
    "ball",
    "bernoulli",
    "beta",
    "categorical",
    "cauchy",
    "choice",
    "dirichlet",
    "exponential",
    "gamma",
    "gumbel",
    "laplace",
    "logistic",
    "maxwell",
    "multivariate_normal",
    "normal",
    "pareto",
    "permutation",
    "poisson",
    "rademacher",
    "randint",
    "truncated_normal",
    "uniform",
}

# jnp reductions that return arrays (tracers under jit), not Python bools.
_ARRAY_REDUCERS = {"any", "all", "max", "min", "sum", "prod", "mean", "isnan"}

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _walk_shallow(node: ast.AST) -> Iterator[ast.AST]:
    """``ast.walk`` that does not descend into nested function/class
    scopes (they are linted as their own scopes)."""
    stack: List[ast.AST] = [node]
    while stack:
        n = stack.pop()
        yield n
        for c in ast.iter_child_nodes(n):
            if isinstance(c, _SCOPE_NODES):
                continue
            stack.append(c)


def _call_name(node: ast.Call) -> Tuple[str, str]:
    """('jax.random', 'normal') style (module-path, attr) best effort."""
    f = node.func
    parts: List[str] = []
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    parts.reverse()
    if not parts:
        return "", ""
    return ".".join(parts[:-1]), parts[-1]


def _name_of(node: ast.expr) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _name_of(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _is_jit_decorator(dec: ast.expr) -> bool:
    """jax.jit / jit / jax.jit(...) / functools.partial(jax.jit, ...)."""
    if isinstance(dec, ast.Call):
        _, attr = _call_name(dec)
        if attr == "jit":
            return True
        if attr == "partial":
            return any(
                isinstance(a, (ast.Attribute, ast.Name))
                and _name_of(a).endswith("jit")
                for a in dec.args
            )
        return False
    return _name_of(dec).endswith("jit")


def _rebound_names(stmt: ast.stmt) -> List[str]:
    """Names (re)bound anywhere inside ``stmt`` — assignment targets,
    loop variables, with-as targets."""
    out: List[str] = []
    for node in _walk_shallow(stmt):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        out.append(n.id)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    out.append(n.id)
        elif isinstance(node, ast.comprehension):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    out.append(n.id)
        elif isinstance(node, ast.withitem) and node.optional_vars is not None:
            for n in ast.walk(node.optional_vars):
                if isinstance(n, ast.Name):
                    out.append(n.id)
    return out


def _branch_bodies(stmt: ast.stmt) -> List[Sequence[ast.stmt]]:
    """The independent statement lists of a compound statement."""
    bodies: List[Sequence[ast.stmt]] = []
    for attr in ("body", "orelse", "finalbody"):
        sub = getattr(stmt, attr, None)
        if sub:
            bodies.append(sub)
    for h in getattr(stmt, "handlers", []) or []:
        bodies.append(h.body)
    return bodies


def _donated_positions(call: ast.Call) -> Optional[List[int]]:
    """For ``jax.jit(f, donate_argnums=(...))(...)`` style calls, the
    donated positions; None when the callee is not a literal jitted fn."""
    if not isinstance(call.func, ast.Call):
        return None
    _, attr = _call_name(call.func)
    if attr != "jit":
        return None
    for kw in call.func.keywords:
        if kw.arg == "donate_argnums":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                out = []
                for e in kw.value.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, int):
                        out.append(e.value)
                return out
            if isinstance(kw.value, ast.Constant) and isinstance(
                kw.value.value, int
            ):
                return [kw.value.value]
    return None


class _ScopeLint:
    """Lints one function body (or the module top level) first-order."""

    def __init__(self, path: str, findings: List[Finding]):
        self.path = path
        self.findings = findings

    def _emit(self, rule: str, line: int, detail: str):
        self.findings.append(
            Finding("jaxlint", f"{self.path}:{line}", f"{rule} {detail}")
        )

    # --- JL001 ------------------------------------------------------------
    def check_key_reuse(
        self, body: Sequence[ast.stmt], consumed: Optional[Dict[str, int]] = None
    ):
        """Track key consumption along straight-line paths; compound
        statements are recursed branch-by-branch with a copy of the state
        (consumption in one branch never taints a sibling branch)."""
        consumed = dict(consumed or {})
        for stmt in body:
            if isinstance(stmt, _SCOPE_NODES):
                continue
            branches = _branch_bodies(stmt)
            if branches:
                for name in _rebound_names(stmt):
                    consumed.pop(name, None)
                for sub in branches:
                    self.check_key_reuse(sub, consumed)
                continue
            for name in _rebound_names(stmt):
                consumed.pop(name, None)
            calls = [
                n for n in _walk_shallow(stmt) if isinstance(n, ast.Call)
            ]
            for node in sorted(calls, key=lambda n: (n.lineno, n.col_offset)):
                mod, attr = _call_name(node)
                if attr not in _KEY_CONSUMERS or "random" not in mod:
                    continue
                if not node.args or not isinstance(node.args[0], ast.Name):
                    continue
                key = node.args[0].id
                if key in consumed:
                    self._emit(
                        "JL001",
                        node.lineno,
                        f"PRNG key `{key}` consumed at line {consumed[key]} "
                        f"is reused by jax.random.{attr} — split or fold_in "
                        "between draws",
                    )
                else:
                    consumed[key] = node.lineno

    # --- JL002 ------------------------------------------------------------
    def check_tracer_branch(self, body: Sequence[ast.stmt], in_jit: bool):
        if not in_jit:
            return
        for stmt in body:
            if isinstance(stmt, _SCOPE_NODES):
                continue
            for node in _walk_shallow(stmt):
                test = None
                if isinstance(node, (ast.If, ast.While, ast.Assert)):
                    test = node.test
                if test is None:
                    continue
                for sub in ast.walk(test):
                    if not isinstance(sub, ast.Call):
                        continue
                    mod, attr = _call_name(sub)
                    base = mod.split(".")[-1] if mod else ""
                    if attr in _ARRAY_REDUCERS and base in ("jnp", "numpy"):
                        self._emit(
                            "JL002",
                            node.lineno,
                            f"Python branch on `{mod}.{attr}(...)` inside a "
                            "jitted function — a tracer is not a bool; use "
                            "lax.cond / jnp.where",
                        )

    # --- JL003 ------------------------------------------------------------
    def check_captured_mutation(self, fn: ast.FunctionDef):
        local = set()
        args = fn.args
        for a in args.args + args.kwonlyargs + args.posonlyargs:
            local.add(a.arg)
        if args.vararg:
            local.add(args.vararg.arg)
        if args.kwarg:
            local.add(args.kwarg.arg)
        for node in _walk_shallow(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    if isinstance(t, ast.Name):
                        local.add(t.id)
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                for n in ast.walk(node.target):
                    if isinstance(n, ast.Name):
                        local.add(n.id)
        mutated: List[Tuple[str, int, str]] = []
        for node in _walk_shallow(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                for name in node.names:
                    mutated.append((name, node.lineno, "global/nonlocal"))
            elif isinstance(node, ast.AugAssign) and isinstance(
                node.target, ast.Name
            ):
                if node.target.id not in local:
                    mutated.append(
                        (node.target.id, node.lineno, "augmented-assign")
                    )
            elif isinstance(node, ast.Call):
                _, attr = _call_name(node)
                if attr in ("append", "extend", "update", "add") and isinstance(
                    node.func, ast.Attribute
                ):
                    base = node.func.value
                    if isinstance(base, ast.Name) and base.id not in local:
                        mutated.append((base.id, node.lineno, f".{attr}()"))
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Subscript) and isinstance(
                        t.value, ast.Name
                    ):
                        if t.value.id not in local:
                            mutated.append(
                                (t.value.id, node.lineno, "subscript-assign")
                            )
        for name, line, how in mutated:
            self._emit(
                "JL003",
                line,
                f"jitted `{fn.name}` mutates captured `{name}` ({how}) — "
                "runs once at trace time, never per call",
            )

    # --- JL004 ------------------------------------------------------------
    def check_use_after_donation(self, body: Sequence[ast.stmt]):
        donated: Dict[str, int] = {}
        for stmt in body:
            if isinstance(stmt, _SCOPE_NODES):
                continue
            if donated:
                for node in _walk_shallow(stmt):
                    if (
                        isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id in donated
                    ):
                        self._emit(
                            "JL004",
                            node.lineno,
                            f"`{node.id}` was donated at line "
                            f"{donated[node.id]} and read again — donated "
                            "buffers are deleted; rebind the result",
                        )
                        donated.pop(node.id)
            rebound = _rebound_names(stmt)
            for name in rebound:
                donated.pop(name, None)
            for node in _walk_shallow(stmt):
                if isinstance(node, ast.Call):
                    pos = _donated_positions(node)
                    if not pos:
                        continue
                    for p in pos:
                        if p < len(node.args) and isinstance(
                            node.args[p], ast.Name
                        ):
                            name = node.args[p].id
                            if name not in rebound:
                                donated[name] = node.lineno


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text; findings carry ``path:line``."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [
            Finding(
                "jaxlint", f"{path}:{e.lineno}", f"JL000 syntax error: {e.msg}"
            )
        ]

    scopes: List[Tuple[Sequence[ast.stmt], Optional[ast.AST], bool]] = [
        (tree.body, None, False)
    ]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            jitted = any(_is_jit_decorator(d) for d in node.decorator_list)
            scopes.append((node.body, node, jitted))

    lint = _ScopeLint(path, findings)
    for body, fn, jitted in scopes:
        lint.check_key_reuse(body)
        lint.check_tracer_branch(body, in_jit=jitted)
        lint.check_use_after_donation(body)
        if jitted and isinstance(fn, ast.FunctionDef):
            lint.check_captured_mutation(fn)
    return findings


def lint_file(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_tree(root: str) -> List[Finding]:
    """Lint every ``.py`` file under ``root`` (deterministic order)."""
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for name in sorted(filenames):
            if name.endswith(".py"):
                findings += lint_file(os.path.join(dirpath, name))
    return findings
