"""Static hot-path auditor: compile-time conformance checks for the serve
runtime.

The paper's thesis (arxiv 2506.02523) is a bytes-vs-FLOPs argument: MLA's
compact latent cache shifts decode toward the compute-bound regime, and
``hwmodel/`` prices exactly that claim.  Nothing in the test suite verifies
that the executables XLA actually compiles HONOR the properties those
numbers assume — one silently dropped pool donation (a full pool copy per
step), one materialized (B, S) gather on a 'pallas' path, or one bf16->f32
promotion on a pool-sized buffer invalidates every modeled crossover
without failing a single numeric test.  This module compiles (never
executes) every hot-path step factory and asserts those invariants on the
optimized HLO / jaxpr:

  donation   — every ``donate_argnums`` buffer has real input-output
               aliasing in the compiled executable (``input_output_alias``
               in the HLO module header).  A dropped donation (sharding /
               layout / dtype mismatch) fails loudly instead of silently
               doubling pool traffic.
  gather     — 'pallas' executables contain no gather/scatter/slice op
               that moves more than a block-size-derived element budget
               out of a POOL-shaped buffer (the "no (B, S) gather ever
               materialized" claim of the fused kernels, checked
               statically).  Weight streaming and scan layer-slicing are
               exempt by shape, not by allowlist.
  dtype      — no f64 anywhere, and no f32 intermediate with a pool
               (leaf) shape when the config says bf16.  Checked on the
               JAXPR (platform-independent) because the CPU lowering
               legally rewrites bf16 ops into f32 convert sandwiches that
               do not exist in the TPU executable.
  roofline   — ``analysis.hlo``-extracted bytes/FLOPs must agree with the
               ``hwmodel.attention_costs`` prediction for the same
               (step kind, impl, scheme) point within the committed
               per-metric tolerance table (``TOLERANCES``), turning the
               cost model from documentation into a CI-gated contract.

``scripts/audit_steps.py`` is the CLI; ``make audit`` runs the pytest lane
(tests/test_audit.py) that drives the full matrix plus the jaxlint AST
pass (``analysis.jaxlint``).  Known, documented exceptions live in
``analysis.audit_allowlist`` and are reported as suppressed, never hidden.
"""

from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..hwmodel import attention_costs as ac
from ..models.common import ModelConfig
from . import hlo as hloa
from .audit_allowlist import ALLOWLIST

# --------------------------------------------------------------- findings --


@dataclasses.dataclass
class Finding:
    """One audit violation: ``rule`` is the check that fired, ``where`` the
    step-matrix cell or file:line, ``detail`` the human-readable evidence."""

    rule: str
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.where}: {self.detail}"


def split_allowlisted(
    findings: Sequence[Finding],
) -> Tuple[List[Finding], List[Finding]]:
    """(kept, suppressed): a finding is suppressed when an allowlist entry
    matches its rule exactly and its ``where`` + ``detail`` by substring."""
    kept, suppressed = [], []
    for f in findings:
        hit = any(
            a.rule == f.rule and a.where in f.where and a.match in (f.detail or "")
            for a in ALLOWLIST
        )
        (suppressed if hit else kept).append(f)
    return kept, suppressed


# ---------------------------------------------------------- audit fixture --

# The canonical audit model: a small dense MLA decoder with the same
# structural knobs as the deepseek configs (scanned layers, latent cache,
# rope split).  Small enough to compile in seconds on CPU, large enough
# that weights and cache dominate the byte count over per-op activation
# noise (d_model * vocab and the S=128-token table extent).
AUDIT_CFG = ModelConfig(
    name="audit-mla-dense",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    attn_kind="mla",
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    max_seq=256,
    remat=False,
)

BLOCK_SIZE = 8
TABLE_BLOCKS = 16  # block-table width nb; static table extent S = 128
NUM_BLOCKS = 1 + TABLE_BLOCKS * 8  # pool capacity (block 0 = null)
CHUNK = 4  # prefill chunk C == verify window k + 1
COMPUTE_DTYPE = jnp.bfloat16
# Roofline conformance compiles a SECOND, f32 variant of each cell: the CPU
# backend rewrites bf16 arithmetic into f32 with convert materializations
# that neither exist on TPU nor in the cost model, so measuring the bf16
# executable would force uselessly wide tolerance bands.  The f32 program
# has identical structure (same gathers, same donation, same loops) with
# no normalization artifacts; the model prices it with dtype_bytes=4.
ROOFLINE_DTYPE = jnp.float32

# Element budget for pool-indexed data movement on 'pallas' paths: the
# fused kernels touch at most one block per (row, grid step), so any
# pool-sourced op moving more than GATHER_SLACK x batch x one block of
# elements is a materialized view, not a block walk.
GATHER_SLACK = 4


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """One cell of the audit matrix."""

    kind: str  # decode | prefill | verify
    impl: str  # gather | pallas
    scheme: str  # seq | rc | ru | naive
    mesh_shape: Optional[Tuple[int, int]] = None  # (data, model) or None
    cache_dtype: str = "bf16"  # bf16 | int8 | fp8 (pool storage dtype)

    @property
    def topo(self) -> str:
        if self.mesh_shape is None:
            return "1dev"
        return f"mesh{self.mesh_shape[0]}x{self.mesh_shape[1]}"

    @property
    def where(self) -> str:
        base = f"{self.kind}/{self.impl}/{self.scheme}/{self.topo}"
        if self.cache_dtype != "bf16":
            base += f"/{self.cache_dtype}"
        return base


def single_device_matrix() -> List[StepSpec]:
    """decode/prefill/verify x {gather, pallas} x schemes, single device.
    'naive' has no kernel path by design (it up-projects the cache), so it
    appears under 'gather' only.  The quantized-pool cells rerun both
    impls at cache_dtype='int8' under 'seq' (the scheme only reorders the
    query transform — the pool path the quantization touches is scheme-
    independent, so one scheme per dtype keeps the matrix compile time
    bounded)."""
    specs = []
    for kind in ("decode", "prefill", "verify"):
        for scheme in ("seq", "rc", "ru"):
            for impl in ("gather", "pallas"):
                specs.append(StepSpec(kind, impl, scheme))
        specs.append(StepSpec(kind, "gather", "naive"))
        for impl in ("gather", "pallas"):
            specs.append(StepSpec(kind, impl, "seq", cache_dtype="int8"))
    return specs


def mesh_matrix() -> List[StepSpec]:
    """Forced-8-device matrix.  The (8, 1) DP-only mesh carries the full
    audit including roofline conformance (weights replicate, so the
    closed-form per-device model applies via dp_shards); the (2, 2)
    DP x MP mesh additionally checks donation/gather/dtype under head
    sharding (roofline is skipped there — the closed-form model does not
    price model-parallel weight sharding)."""
    specs = []
    for kind in ("decode", "prefill", "verify"):
        for scheme in ("seq", "ru"):
            for impl in ("gather", "pallas"):
                specs.append(StepSpec(kind, impl, scheme, (8, 1)))
        specs.append(StepSpec(kind, "pallas", "seq", (2, 2)))
    return specs


def _dp_size(mesh_shape: Optional[Tuple[int, int]]) -> int:
    return 1 if mesh_shape is None else mesh_shape[0]


def _batch_of(spec: StepSpec) -> int:
    # batch must be a DP multiple (the engine pads max_batch the same way)
    return max(2, _dp_size(spec.mesh_shape))


@dataclasses.dataclass
class CompiledStep:
    spec: StepSpec
    compiled: object  # jax compiled executable
    jaxpr: object
    pool_tree: Dict
    batch: int
    donation_warnings: List[str]
    dtype: object = COMPUTE_DTYPE


def _build_mesh(mesh_shape: Optional[Tuple[int, int]]):
    if mesh_shape is None:
        return None
    from ..launch.mesh import make_mesh

    need = mesh_shape[0] * mesh_shape[1]
    if jax.device_count() < need:
        raise RuntimeError(
            f"mesh {mesh_shape} needs {need} devices, found "
            f"{jax.device_count()} — force them BEFORE jax init: "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}"
        )
    return make_mesh(mesh_shape, ("data", "model"))


def compile_step(
    spec: StepSpec, cfg: ModelConfig = AUDIT_CFG, dtype=COMPUTE_DTYPE
) -> CompiledStep:
    """Build, lower and compile one step-matrix cell — never executed."""
    from .. import models
    from ..core import mla as mlalib
    from ..nn import module as nnm
    from ..runtime import steps as rsteps

    mesh = _build_mesh(spec.mesh_shape)
    params = nnm.init_params(jax.random.PRNGKey(0), models.model_defs(cfg), dtype)
    if spec.scheme == "ru":
        params = mlalib.attach_absorbed_tree(params, cfg.mla_config())
    if mesh is not None:
        params = rsteps.commit_params(params, cfg, mesh)
    cache_dtype = None if spec.cache_dtype == "bf16" else spec.cache_dtype
    pool = models.init_paged_cache(
        cfg, NUM_BLOCKS, BLOCK_SIZE, dtype, cache_dtype=cache_dtype
    )

    impl = {"gather": "ref", "pallas": "kernel"}[spec.impl]
    B = _batch_of(spec)
    tables = jnp.zeros((B, TABLE_BLOCKS), jnp.int32)
    lengths = jnp.zeros((B,), jnp.int32)
    if spec.kind == "decode":
        step = rsteps.make_paged_serve_step(
            cfg,
            mesh,
            compute_dtype=dtype,
            impl=impl,
            scheme=spec.scheme,
            cache_dtype=cache_dtype,
        )
        args = (params, jnp.zeros((B,), jnp.int32), pool, tables, lengths)
    else:
        maker = {
            "prefill": rsteps.make_chunked_prefill_step,
            "verify": rsteps.make_verify_step,
        }[spec.kind]
        step = maker(
            cfg,
            mesh,
            compute_dtype=dtype,
            impl=impl,
            scheme=spec.scheme,
            cache_dtype=cache_dtype,
        )
        args = (
            params,
            jnp.zeros((B, CHUNK), jnp.int32),
            pool,
            tables,
            lengths,
            jnp.zeros((B,), jnp.int32),
        )

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = step.lower(*args).compile()
    donation_warnings = [
        str(w.message) for w in caught if "donated" in str(w.message).lower()
    ]
    jaxpr = jax.make_jaxpr(lambda *a: step(*a))(*args)
    return CompiledStep(spec, compiled, jaxpr, pool, B, donation_warnings, dtype)


# ---------------------------------------------------------------- helpers --

_JNP_TO_HLO = {
    "bfloat16": "bf16",
    "float16": "f16",
    "float32": "f32",
    "float64": "f64",
    "int8": "s8",
    "int32": "s32",
    "int64": "s64",
    "uint32": "u32",
    "float8_e4m3fn": "f8e4m3fn",
    "bool": "pred",
}


def _hlo_leaf_types(tree) -> List[Tuple[str, Tuple[int, ...]]]:
    return [
        (_JNP_TO_HLO.get(str(x.dtype), str(x.dtype)), tuple(x.shape))
        for x in jax.tree.leaves(tree)
    ]


def _pool_core_shapes(pool_tree) -> Dict[Tuple[int, ...], int]:
    """Map pool-leaf CORE shape (num_blocks, block_size, D) — stacked layer
    dims stripped — to its trailing feature dim.  Used to recognize ops
    whose source buffer is (a per-layer view of) the pool."""
    out: Dict[Tuple[int, ...], int] = {}
    for x in jax.tree.leaves(pool_tree):
        core = tuple(x.shape[-3:])
        out[core] = core[-1]
    return out


# --------------------------------------------------------- donation audit --

_ALIAS_RE = re.compile(r"\{([\d,\s]*)\}:\s*\((\d+)")


def _entry_param_types(header: str) -> List[str]:
    m = re.search(r"entry_computation_layout=\{\(", header)
    if not m:
        return []
    depth, out, cur = 1, [], []
    for ch in header[m.end() :]:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [t.split("*/")[-1].strip() for t in out]


def audit_donation(compiled, donated_tree, where: str, warns=()) -> List[Finding]:
    """Every leaf of ``donated_tree`` must be input-output aliased in the
    compiled module header — XLA drops unusable donations silently (plus a
    python warning the engine never surfaces), which turns the in-place
    pool update into a full pool copy per step."""
    header = compiled.as_text().split("\n", 1)[0]
    findings = [
        Finding("donation", where, f"compile warned: {w.splitlines()[0]}")
        for w in warns
    ]
    # _ALIAS_RE's "{out}: (param" shape only occurs inside the
    # input_output_alias block, so scanning the whole header is safe
    entries = _ALIAS_RE.findall(header) if "input_output_alias" in header else []
    aliased_params = [int(p) for _, p in entries]
    param_types = _entry_param_types(header)
    aliased_types: List[str] = []
    for i in aliased_params:
        if i < len(param_types):
            aliased_types.append(param_types[i])
    leaves = _hlo_leaf_types(donated_tree)
    for dt, shape in leaves:
        want = dt + "[" + ",".join(str(d) for d in shape) + "]"
        hit = next((t for t in aliased_types if t.startswith(want)), None)
        if hit is None:
            findings.append(
                Finding(
                    "donation",
                    where,
                    f"donated leaf {want} has no input_output_alias entry "
                    f"({len(entries)} aliased of {len(leaves)} donated leaves)"
                    " — the pool is being copied, not updated in place",
                )
            )
        else:
            aliased_types.remove(hit)
    return findings


# ----------------------------------------------------------- gather audit --

_MOVERS = ("gather", "scatter", "dynamic-slice", "dynamic-update-slice", "slice")


def _elems(dims: Sequence[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def audit_gather(
    compiled, pool_tree, batch: int, where: str, slack: int = GATHER_SLACK
) -> List[Finding]:
    """No pool-sourced gather/scatter/slice may move more elements than
    ``slack * batch * block_size * feature`` — the block-walk budget of the
    fused kernels.  Scan plumbing (leading-dim-1 layer slices / writes that
    keep the (num_blocks, block_size) dims whole) is exempt by shape."""
    core = _pool_core_shapes(pool_tree)
    findings = []
    comps = hloa.parse_computations(compiled.as_text())
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode not in _MOVERS:
                continue
            src_name = op.operands[0] if op.operands else ""
            _, src_dims = hloa._shape_dims(comp.shapes.get(src_name, ""))
            pool_feature = None
            for cshape, feat in core.items():
                if tuple(src_dims[-3:]) == cshape or tuple(src_dims[-4:-1]) == cshape:
                    pool_feature = feat
                    break
            if pool_feature is None:
                continue
            if op.opcode in ("gather", "dynamic-slice", "slice"):
                _, moved_dims = hloa._shape_dims(op.type_str)
            elif op.opcode == "dynamic-update-slice":
                upd = op.operands[1] if len(op.operands) > 1 else ""
                _, moved_dims = hloa._shape_dims(comp.shapes.get(upd, ""))
            else:  # scatter: the updates operand
                upd = op.operands[-1] if op.operands else ""
                _, moved_dims = hloa._shape_dims(comp.shapes.get(upd, ""))
            if (
                op.opcode in ("dynamic-slice", "slice", "dynamic-update-slice")
                and len(moved_dims) == len(src_dims)
                and moved_dims[0] == 1
                and tuple(moved_dims[1:]) == tuple(src_dims[1:])
            ):
                continue  # layer select / single-block access: scan plumbing
            moved = _elems(moved_dims)
            budget = slack * batch * BLOCK_SIZE * pool_feature
            if moved > budget:
                findings.append(
                    Finding(
                        "gather",
                        where,
                        f"{op.opcode} %{op.name} moves {moved} elements "
                        f"from pool-shaped {src_dims} (budget {budget}) — "
                        "a materialized block-table view on a pallas path",
                    )
                )
    return findings


# ------------------------------------------------------------ dtype audit --


def _walk_jaxpr(jaxpr, seen: set, visit):
    if id(jaxpr) in seen:
        return
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                visit(eqn, aval)
        for p in eqn.params.values():
            for child in p if isinstance(p, (tuple, list)) else [p]:
                inner = getattr(child, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk_jaxpr(inner, seen, visit)
                elif hasattr(child, "eqns"):
                    _walk_jaxpr(child, seen, visit)


_QUANT_DTYPES = tuple(
    jnp.dtype(n) for n in ("int8",) + (("float8_e4m3fn",) if hasattr(jnp, "float8_e4m3fn") else ())
)


def audit_dtypes(
    jaxpr, pool_tree, where: str, compute_dtype=COMPUTE_DTYPE, hlo_text: str = ""
) -> List[Finding]:
    """No f64 anywhere; no f32 value with a pool(-leaf) shape when the
    config says bf16.  Runs on the jaxpr: the CPU backend legally rewrites
    bf16 arithmetic into f32 convert sandwiches in the HLO, so the HLO is
    only scanned for f64 (which no backend introduces).

    Quantized pools (int8/fp8 data leaves + f32 per-row scale leaves) add
    two rules: the SCALE shapes are exempt from the f32-promotion check
    (they are f32 by design — flagging them would outlaw the layout), and
    NO wide float (f32/bf16/f16) value may carry a quantized data-leaf
    shape — a dequantized full-pool copy in HBM is exactly the hoisted
    buffer that silently restores bf16-sized cache traffic."""
    findings = []
    pool_shapes = set()
    quant_shapes = set()  # shapes of int8/fp8 payload leaves
    scale_shapes = set()  # shapes of the f32 per-row scale leaves
    for x in jax.tree.leaves(pool_tree):
        shapes = {tuple(x.shape), tuple(x.shape[-3:])}
        pool_shapes |= shapes
        if x.dtype in _QUANT_DTYPES:
            quant_shapes |= shapes
        elif x.dtype == jnp.float32 and x.shape[-1] == 1:
            scale_shapes |= shapes
    pool_shapes -= scale_shapes
    want_promotion_check = compute_dtype in (jnp.bfloat16, jnp.float16)

    def visit(eqn, aval):
        if aval.dtype == jnp.float64:
            findings.append(
                Finding(
                    "dtype",
                    where,
                    f"f64 value {aval.shape} in `{eqn.primitive.name}` "
                    "(x64 leaked into a serve step)",
                )
            )
        elif (
            quant_shapes
            and aval.dtype in (jnp.float32, jnp.bfloat16, jnp.float16)
            and tuple(aval.shape) in quant_shapes
        ):
            findings.append(
                Finding(
                    "dtype",
                    where,
                    f"{jnp.dtype(aval.dtype).name} value with quantized "
                    f"pool shape {aval.shape} in `{eqn.primitive.name}` — "
                    "a dequantized pool-sized buffer forfeits the int8/fp8 "
                    "cache-traffic win (dequantize gathered views, never "
                    "the pool)",
                )
            )
        elif (
            want_promotion_check
            and aval.dtype == jnp.float32
            and tuple(aval.shape) in pool_shapes
        ):
            findings.append(
                Finding(
                    "dtype",
                    where,
                    f"f32 value with pool shape {aval.shape} in "
                    f"`{eqn.primitive.name}` while compute dtype is "
                    f"{jnp.dtype(compute_dtype).name} — a promoted "
                    "pool-sized buffer doubles cache traffic",
                )
            )

    _walk_jaxpr(jaxpr.jaxpr, set(), visit)
    if hlo_text and re.search(r"\bf64\[", hlo_text):
        findings.append(
            Finding("dtype", where, "f64 buffer in the compiled HLO module")
        )
    return findings


# --------------------------------------------------------- roofline audit --

# Ratio bounds (measured HLO / closed-form model) per (kind, impl, topo)
# and metric, committed as the conformance contract.  FLOPs on the gather
# cells agree with the model to ~2% (the parser's while-trip accounting is
# exact); pallas interpret-mode kernels measure ~0.79-0.88x (masked-block
# work the grid skips).  Bytes carry the lowering's structural
# multipliers — XLA CPU materializes each weight slice before its GEMM
# (~4.5x the raw weight stream on gather cells) and the interpret-mode
# grid loop round-trips block state per step (~31x on pallas cells, ~16x
# under DP-8 where the model halves nothing but batch terms).  The bands
# sit ~+/-25% around the calibrated ratios: tight enough that a dropped
# donation pattern, a gather materialized on a kernel path (pallas bytes
# would FALL to the gather band — breaching the lower bound), or a skewed
# cost term breaches them.  Re-calibrate deliberately via
# scripts/audit_steps.py when the model or the step factories change; see
# tests/test_audit.py for the injected-violation proofs.
TOLERANCES: Dict[Tuple[str, str, str], Dict[str, Tuple[float, float]]] = {
    ("decode", "gather", "1dev"): {"flops": (0.95, 1.10), "bytes": (3.0, 5.7)},
    ("decode", "pallas", "1dev"): {"flops": (0.65, 1.05), "bytes": (24.0, 39.0)},
    ("prefill", "gather", "1dev"): {"flops": (0.95, 1.10), "bytes": (3.7, 6.2)},
    ("prefill", "pallas", "1dev"): {"flops": (0.65, 1.05), "bytes": (25.0, 40.0)},
    ("verify", "gather", "1dev"): {"flops": (0.95, 1.10), "bytes": (3.0, 6.2)},
    ("verify", "pallas", "1dev"): {"flops": (0.65, 1.05), "bytes": (25.0, 40.0)},
    ("decode", "gather", "mesh8x1"): {"flops": (0.95, 1.10), "bytes": (2.0, 3.4)},
    ("decode", "pallas", "mesh8x1"): {"flops": (0.65, 1.05), "bytes": (12.0, 20.0)},
    ("prefill", "gather", "mesh8x1"): {"flops": (0.95, 1.10), "bytes": (2.7, 4.5)},
    ("prefill", "pallas", "mesh8x1"): {"flops": (0.65, 1.05), "bytes": (13.0, 21.0)},
    ("verify", "gather", "mesh8x1"): {"flops": (0.95, 1.10), "bytes": (2.5, 4.2)},
    ("verify", "pallas", "mesh8x1"): {"flops": (0.65, 1.05), "bytes": (13.0, 21.0)},
}

# Quantized-pool cells get their OWN bands, keyed (kind, impl, topo,
# cache_dtype): the model prices the int8 payload + f32 scale streams
# (cache_element_bytes), but the measured side shifts differently — the
# gather path reads 1-byte pool leaves yet still materializes the
# dequantized view at f32 width, and the interpret-mode pallas grid loop
# round-trips the SAME block state whatever its width, so the structural
# multipliers land on a smaller modeled denominator.  Calibrated the same
# way as TOLERANCES (scripts/audit_steps.py); unknown dtype keys fall
# back to the unquantized band via :func:`tolerances_for`.
QUANT_TOLERANCES: Dict[Tuple[str, str, str, str], Dict[str, Tuple[float, float]]] = {
    ("decode", "gather", "1dev", "int8"): {"flops": (0.95, 1.10), "bytes": (2.7, 4.4)},
    ("decode", "pallas", "1dev", "int8"): {"flops": (0.65, 1.05), "bytes": (9.5, 15.5)},
    ("prefill", "gather", "1dev", "int8"): {"flops": (0.95, 1.10), "bytes": (3.0, 4.8)},
    ("prefill", "pallas", "1dev", "int8"): {"flops": (0.65, 1.05), "bytes": (10.5, 17.0)},
    ("verify", "gather", "1dev", "int8"): {"flops": (0.95, 1.10), "bytes": (3.0, 4.8)},
    ("verify", "pallas", "1dev", "int8"): {"flops": (0.65, 1.05), "bytes": (10.5, 17.0)},
}


def tolerances_for(spec: StepSpec) -> Dict[str, Tuple[float, float]]:
    """Conformance band for one cell: the dtype-specific entry when the
    cell stores a quantized pool, else the committed unquantized band."""
    if spec.cache_dtype != "bf16":
        key = (spec.kind, spec.impl, spec.topo, spec.cache_dtype)
        if key in QUANT_TOLERANCES:
            return QUANT_TOLERANCES[key]
    return TOLERANCES[(spec.kind, spec.impl, spec.topo)]


def modeled_step_cost(
    spec: StepSpec,
    cfg: ModelConfig = AUDIT_CFG,
    term_scale: Optional[Dict[str, float]] = None,
) -> Dict[str, float]:
    """Closed-form (flops, bytes) prediction for one compiled step cell —
    ``hwmodel.attention_costs`` per layer plus the non-attention terms the
    step factories actually compile (MLP, embed, unembed, final norm).

    The STATIC program masks rather than shortens: every compiled cell
    scores against the full block-table extent S = TABLE_BLOCKS x
    BLOCK_SIZE regardless of the runtime ``lengths``, so the model is
    evaluated at cache_len = S.  ``term_scale`` multiplies named breakdown
    terms (e.g. {"cache_read": 3.0}) — the injection hook the audit tests
    use to prove a skewed cost term fails the lane.  Prices the
    ROOFLINE_DTYPE (f32) variant of the cell — see that constant's note."""
    mla = cfg.mla_config()
    w = jnp.dtype(ROOFLINE_DTYPE).itemsize
    dp = _dp_size(spec.mesh_shape)
    B = _batch_of(spec)
    B_local = -(-B // dp)
    S = TABLE_BLOCKS * BLOCK_SIZE
    C = 1 if spec.kind == "decode" else CHUNK
    impl = {"gather": "gather", "pallas": "pallas"}[spec.impl]
    # quantized cells price the cache streams at the pool's effective
    # element width (1-byte payload + amortized f32 scales); the roofline
    # compile keeps the pool quantized regardless of its compute dtype
    from ..core.cache import cache_element_bytes

    cw = cache_element_bytes(
        mla.kv_lora_rank,
        mla.qk_rope_dim,
        dtype_bytes=w,
        cache_dtype=None if spec.cache_dtype == "bf16" else spec.cache_dtype,
    )

    if spec.kind == "decode":
        attn = ac.mla_decode_cost(
            mla,
            scheme=spec.scheme,
            cache_len=S,
            batch=B,
            dtype_bytes=w,
            rope=True,
            paged_block=BLOCK_SIZE,
            dp_shards=dp,
            cache_dtype_bytes=cw,
        )
    elif spec.kind == "verify":
        attn = ac.mla_verify_cost(
            mla,
            scheme=spec.scheme,
            cache_len=S - C,
            k=C - 1,
            batch=B,
            dtype_bytes=w,
            rope=True,
            paged_block=BLOCK_SIZE,
            dp_shards=dp,
            cache_dtype_bytes=cw,
        )
    else:
        attn = ac.mla_prefill_chunk_cost(
            mla,
            seq_len=S,
            chunk=C,
            paged_block=BLOCK_SIZE,
            batch=B_local,
            dtype_bytes=w,
            rope=True,
            cached_prefix=S - C,
            impl=impl,
            include_io=False,
            cache_dtype_bytes=cw,
        )

    breakdown: Dict[str, float] = {}
    for k, v in attn.breakdown.items():
        breakdown[k.replace("B:", "bytes:")] = v

    # the prefill model prices the 'seq' absorption; rc/ru reorder the
    # nope-query transform exactly as in mla_decode_cost
    if spec.kind == "prefill" and spec.scheme in ("rc", "ru"):
        _, H, Q, K, dn, dr, _ = ac._dims(mla, True)
        breakdown.pop("q_up", None)
        breakdown["q_up_rope"] = 2.0 * B_local * C * Q * H * dr
        breakdown["q_latent"] = 2.0 * B_local * C * H * Q * K
        if spec.scheme == "rc":
            breakdown["absorb_recompute"] = 2.0 * H * Q * dn * K

    # the reference decode/verify paths ALSO materialize the (B, S) view
    # in HBM (gather read + attention re-read); the prefill-chunk model
    # already carries this as its own gather_materialize term.
    if impl == "gather" and spec.kind != "prefill":
        K, dr = mla.kv_lora_rank, mla.qk_rope_dim
        breakdown["bytes:gather_materialize"] = 2.0 * B_local * S * (K + dr) * w

    D, V, dff, nl = cfg.d_model, cfg.vocab, cfg.d_ff, cfg.n_layers
    # attention terms above are PER LAYER
    for k in list(breakdown):
        breakdown[k] *= nl
    # dense swiglu MLP per layer: wi (D, 2, dff) + wo (dff, D)
    breakdown["mlp"] = nl * 6.0 * B_local * C * D * dff
    breakdown["bytes:w_mlp"] = nl * 3.0 * D * dff * w
    # embed gather in, unembed matmul out (verify scores every position,
    # decode/prefill only one row per request)
    logit_rows = B_local * (C if spec.kind == "verify" else 1)
    breakdown["bytes:embed"] = 2.0 * B_local * C * D * w
    breakdown["unembed"] = 2.0 * logit_rows * D * V
    breakdown["bytes:w_embed"] = (1.0 + (logit_rows > 0)) * V * D * w
    breakdown["bytes:logits"] = logit_rows * V * w

    for name, scale in (term_scale or {}).items():
        for k in list(breakdown):
            if k == name or k == f"bytes:{name}":
                breakdown[k] *= scale
    flops = sum(v for k, v in breakdown.items() if not k.startswith("bytes:"))
    bytes_ = sum(v for k, v in breakdown.items() if k.startswith("bytes:"))
    return {"flops": flops, "bytes": bytes_, "breakdown": breakdown}


def roofline_applicable(spec: StepSpec) -> bool:
    """Cells the closed-form model prices: no model-parallel weight
    sharding (mp > 1) and no naive-scheme prefill (the prefill model
    prices seq-family absorption only)."""
    if spec.mesh_shape is not None and spec.mesh_shape[1] != 1:
        return False
    if spec.kind == "prefill" and spec.scheme == "naive":
        return False
    return True


def audit_roofline(
    compiled,
    spec: StepSpec,
    where: str,
    term_scale: Optional[Dict[str, float]] = None,
    measured: Optional[hloa.HLOCost] = None,
) -> List[Finding]:
    """Measured-vs-modeled conformance: the hlo parser's bytes/FLOPs for
    the f32-compiled cell must sit inside the committed TOLERANCES ratios
    of the ``modeled_step_cost`` prediction."""
    if not roofline_applicable(spec):
        return []
    nparts = 1 if spec.mesh_shape is None else spec.mesh_shape[0] * spec.mesh_shape[1]
    if measured is None:
        measured = hloa.analyze(compiled.as_text(), num_partitions=nparts)
    model = modeled_step_cost(spec, term_scale=term_scale)
    tol = tolerances_for(spec)
    findings = []
    for metric in ("flops", "bytes"):
        got = getattr(measured, metric)
        want = model[metric]
        ratio = got / max(want, 1.0)
        lo, hi = tol[metric]
        if not lo <= ratio <= hi:
            findings.append(
                Finding(
                    "roofline",
                    where,
                    f"{metric}: HLO {got:.3e} vs modeled {want:.3e} "
                    f"(ratio {ratio:.2f} outside [{lo}, {hi}]) — the "
                    "compiled step no longer matches the cost model",
                )
            )
    return findings


# ------------------------------------------------------------- the matrix --


def audit_step(
    spec: StepSpec,
    compiled_step: Optional[CompiledStep] = None,
    term_scale: Optional[Dict[str, float]] = None,
    roofline_step: Optional[CompiledStep] = None,
) -> List[Finding]:
    """All four static audits for one matrix cell.  Donation, gather and
    dtype run on the production-dtype (bf16) compile; roofline runs on
    the f32 compile (see ROOFLINE_DTYPE)."""
    cs = compiled_step if compiled_step is not None else compile_step(spec)
    where = spec.where
    text = cs.compiled.as_text()
    findings = audit_donation(cs.compiled, cs.pool_tree, where, cs.donation_warnings)
    if spec.impl == "pallas" and spec.scheme != "naive":
        findings += audit_gather(cs.compiled, cs.pool_tree, cs.batch, where)
    findings += audit_dtypes(
        cs.jaxpr, cs.pool_tree, where, compute_dtype=cs.dtype, hlo_text=text
    )
    rs = roofline_step
    if rs is None and roofline_applicable(spec):
        rs = compile_step(spec, dtype=ROOFLINE_DTYPE)
    if rs is not None:
        findings += audit_roofline(rs.compiled, spec, where, term_scale=term_scale)
        findings += audit_donation(
            rs.compiled, rs.pool_tree, where + "/f32", rs.donation_warnings
        )
    return findings


def run_matrix(
    specs: Sequence[StepSpec], progress=None
) -> Tuple[List[Finding], List[Finding]]:
    """Compile + audit every spec; returns (findings, suppressed)."""
    findings: List[Finding] = []
    for spec in specs:
        if progress is not None:
            progress(spec)
        findings += audit_step(spec)
    return split_allowlisted(findings)
