"""Checkpointing: sharded npz store with async save, keep-k GC, and
ELASTIC restore (re-shard to a different mesh on load).

Layout:   <dir>/step_<n>/arrays.npz   flat {path -> np.ndarray}
          <dir>/step_<n>/meta.json    {step, data_state, user_meta, done}
The ``done`` marker is written LAST — a crash mid-save leaves a directory
without it and ``latest_step`` skips it (atomic-commit semantics).

Elastic restore: arrays are saved as full (host-gathered) values; ``restore``
device_puts each leaf with the sharding derived from the *current* mesh, so
a job restarted on a different topology (e.g. 256 -> 512 chips) re-shards
transparently.  On a real multi-host pod each host would write its shard
(ocdbt-style); the single-process layout keeps identical semantics.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(flat: Dict[str, Any], template):
    """Rebuild ``template``'s structure from the flat dict."""
    def build(node, prefix):
        if isinstance(node, dict):
            return {k: build(v, f"{prefix}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(build(v, f"{prefix}/{i}") for i, v in enumerate(node))
        if prefix not in flat:
            raise KeyError(f"checkpoint missing leaf {prefix}")
        return flat[prefix]
    return build(template, "")


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree, *, data_state: Optional[dict] = None,
             meta: Optional[dict] = None, blocking: bool = True) -> None:
        """Host-gather the tree and write step_<n>.  ``blocking=False``
        returns immediately and writes on a background thread (compute for
        the next step overlaps the serialization — async checkpointing)."""
        # Materialize on host NOW (cheap copy) so training can mutate
        # donated buffers while the writer thread streams to disk.
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        payload_meta = {"step": int(step), "data_state": data_state or {},
                        "meta": meta or {}}

        def write():
            d = os.path.join(self.dir, f"step_{step:08d}")
            tmp = d + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k.lstrip("/").replace("/", "__"): v for k, v in flat.items()})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({**payload_meta, "done": True}, f)
            if os.path.exists(d):
                shutil.rmtree(d)
            os.replace(tmp, d)
            self._gc()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if not m:
                continue
            meta = os.path.join(self.dir, name, "meta.json")
            try:
                with open(meta) as f:
                    if json.load(f).get("done"):
                        out.append(int(m.group(1)))
            except (OSError, json.JSONDecodeError):
                continue  # partial save (crash mid-write): skip
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, *,
                sharding_for: Optional[Callable[[str, Any], Any]] = None
                ) -> Tuple[Any, dict]:
        """Load step_<n> into ``template``'s structure.

        ``sharding_for(path, np_array) -> jax.sharding.Sharding | None``
        implements elastic restore: each leaf is device_put with the
        sharding computed for the CURRENT mesh (or left on host if None).
        """
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        zf = np.load(os.path.join(d, "arrays.npz"))
        flat = {"/" + k.replace("__", "/"): zf[k] for k in zf.files}
        if sharding_for is not None:
            flat = {k: jax.device_put(v, sharding_for(k, v)) if
                    sharding_for(k, v) is not None else v
                    for k, v in flat.items()}
        return _unflatten_into(flat, template), meta
