from .store import CheckpointStore
