"""Radix prefix cache over the paged latent-KV block pool.

Requests sharing a prompt prefix (system prompt, few-shot preamble) should
share pool *blocks* instead of recomputing and re-storing the same latents
— the serving-side dual of the paper's bytes-per-token result: MLA's
compact ``{ckv|krope}`` cache cuts the bytes each token costs; prefix
sharing cuts the redundant *tokens* entirely.

Design (vLLM/SGLang-style, at block granularity):

  * A trie ("radix tree" at full-block granularity) keyed by the CONTENT
    of each full token block: every edge is a ``block_size``-tuple of
    token ids, every node owns one pool block holding the latents of
    exactly those tokens in that prefix position.  Matching a new prompt
    walks the trie block-by-block from the root.
  * Blocks are REF-COUNTED in the :class:`~.scheduler.BlockAllocator`:
    a trie hit ``fork``s the block (refcount += 1) and maps the request's
    leading block-table entries onto it; ``release`` (refcount -= 1)
    replaces raw ``free`` everywhere in the scheduler.
  * Copy-on-write boundary: full matched blocks are mapped read-only; a
    hit may additionally end MID-BLOCK (``MatchResult.partial_len``
    tokens into one more cached block) — the scheduler materializes that
    tail by allocating a private block and queueing a device-side block
    copy (``core.cache.copy_block_paged``), so only the genuinely novel
    suffix runs through prefill.  Matches are capped at ``plen - 1``
    tokens so at least one prompt token always prefills — the
    last-position logits are what samples the first generated token.
    Should a write ever target a block that is shared or trie-registered
    (decode-block registration, n-way forks), the scheduler breaks the
    share with the same block copy before writing.
  * DECODE blocks are registered too: as a request's length crosses each
    block boundary, the just-completed block of generated-token latents
    enters the trie under its token content
    (``scheduler.register_decode_blocks``) — a follow-up conversation
    turn whose prompt embeds the previous turn's output re-hits its own
    generation instead of re-prefilling it.
  * Eviction is LRU over refcount-ZERO cached blocks instead of the
    immediate reuse of PR-1: when a request releases its blocks, the
    trie-registered ones stay resident (refcount 0, evictable) so a later
    request with the same prefix revives them with a ``fork``; the free
    list is replenished lazily by :meth:`PrefixCache.alloc` evicting the
    least-recently-used childless trie nodes.

Intra-tick ordering: a request's blocks are registered (``insert``) only
AFTER its prefill has scattered their latents into the pool, so a match
can never hand out blocks whose contents are not yet written.

Host-side and model-agnostic, like the rest of ``runtime.scheduler`` —
the engine owns the device pool; this module only deals in block ids.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _block_keys(tokens: Sequence[int], block_size: int) -> List[Tuple[int, ...]]:
    """Content keys of the FULL blocks of ``tokens`` (partial tail dropped)."""
    toks = np.asarray(tokens).tolist()
    n_full = len(toks) // block_size
    return [tuple(toks[i * block_size:(i + 1) * block_size])
            for i in range(n_full)]


def _common_prefix_len(a: Tuple[int, ...], b: Tuple[int, ...]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class MatchResult(List[int]):
    """A :meth:`PrefixCache.match` hit.

    The LIST CONTENT is the full-block part of the match (forked pool
    block ids, exactly what match() always returned), so every existing
    ``len(shared)`` / ``shared + fresh`` / ``match(...) == [...]`` caller
    keeps working.  On top of that a hit may end mid-block:

      partial_src   pool block id whose leading ``partial_len`` token
                    slots extend the match (forked on behalf of the
                    caller, like the full blocks), or None
      partial_len   tokens matched inside ``partial_src`` (0 = none)

    The caller materializes the partial tail copy-on-write: allocate a
    private block, queue a device copy ``partial_src -> private``, then
    ``release([partial_src])`` — the trie block is only ever READ by the
    copy, which the engine orders before any later pool write.
    """

    def __init__(self, blocks: Sequence[int] = (),
                 partial_src: Optional[int] = None, partial_len: int = 0):
        super().__init__(blocks)
        self.partial_src = partial_src
        self.partial_len = int(partial_len)

    def n_tokens(self, block_size: int) -> int:
        """Total prompt tokens this match serves from the cache."""
        return len(self) * block_size + self.partial_len


class _Node:
    """One cached block: an edge of the trie (keyed by its token content in
    the parent) plus the pool block id holding those tokens' latents."""
    __slots__ = ("key", "block", "parent", "children", "last_used")

    def __init__(self, key, block, parent):
        self.key = key                      # Tuple[int, ...] | None (root)
        self.block = block                  # pool block id | None (root)
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0            # match() calls
    hits: int = 0               # match() calls serving >= 1 token
    hit_tokens: int = 0         # tokens served from the cache (incl. partial)
    lookup_tokens: int = 0      # prompt tokens offered for matching
    partial_hits: int = 0       # matches that ended mid-block
    partial_hit_tokens: int = 0  # tokens served from partial tail blocks
    inserted_blocks: int = 0    # total trie registrations (prompt + decode)
    decode_blocks_inserted: int = 0  # registrations from decode boundaries
    evictions: int = 0
    cow_copies: int = 0

    @property
    def hit_rate(self) -> float:
        """Token-level hit rate over all offered prompt tokens."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0


class PrefixCache:
    """Radix index + refcount/eviction policy over a ``BlockAllocator``.

    ``enabled=False`` degrades to a transparent pass-through (every alloc /
    release behaves exactly like PR-1's raw free-list) so the scheduler
    carries one code path.
    """

    def __init__(self, allocator, block_size: int, *, enabled: bool = True,
                 partial: bool = True):
        self.allocator = allocator
        self.block_size = block_size
        self.enabled = enabled
        # token-granular partial-block matching; False restores the
        # block-granular PR-9 behavior (the bench's A/B baseline)
        self.partial = partial
        self.root = _Node(None, None, None)
        self._node_of: Dict[int, _Node] = {}     # registered block -> node
        self._evictable: Dict[int, _Node] = {}   # refcount-0 cached blocks
        self._clock = 0
        self.stats = PrefixCacheStats()
        # duck-typed telemetry hook (repro.obs.Telemetry); the engine
        # attaches it when telemetry is on.  None (the default) costs one
        # ``is None`` check at the eviction / CoW sites.
        self.tel = None

    # ------------------------------------------------------------ lookup ---

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int]) -> MatchResult:
        """Longest cached prefix of ``tokens``: full blocks as the list
        content, plus (``partial=True``) a token-granular tail —
        ``partial_len`` tokens into one more cached block whose content
        extends the prefix (``MatchResult``).  Every returned block,
        including the partial source, is ``fork``ed (refcount +1) on
        behalf of the caller.

        Capped at ``len(tokens) - 1`` tokens: a full-prompt hit would
        leave nothing to prefill, but the last position's logits are
        needed to sample the first generated token — at least the final
        prompt token is always recomputed privately.
        """
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        if not self.enabled:
            return MatchResult()
        budget = max(len(tokens) - 1, 0)
        node, blocks = self.root, []
        for key in _block_keys(tokens, self.block_size)[:budget // self.block_size]:
            child = node.children.get(key)
            if child is None:
                break
            self.allocator.fork([child.block])
            self._evictable.pop(child.block, None)
            child.last_used = self._tick()
            blocks.append(child.block)
            node = child
        partial_src, partial_len = None, 0
        if self.partial:
            tail = self._partial_child(node, tokens, len(blocks), budget)
            if tail is not None:
                child, partial_len = tail
                self.allocator.fork([child.block])
                self._evictable.pop(child.block, None)
                child.last_used = self._tick()
                partial_src = child.block
        if blocks or partial_len:
            self.stats.hits += 1
            self.stats.hit_tokens += len(blocks) * self.block_size \
                + partial_len
        if partial_len:
            self.stats.partial_hits += 1
            self.stats.partial_hit_tokens += partial_len
        return MatchResult(blocks, partial_src, partial_len)

    def _partial_child(self, node: _Node, tokens: Sequence[int],
                       n_full: int, budget: int):
        """The cached block extending the match past its last full block:
        the child of ``node`` sharing the longest non-empty token prefix
        with the remainder of ``tokens``, clipped to the ``budget``-token
        cap.  Returns (node, matched_len) or None."""
        start = n_full * self.block_size
        tail_budget = min(budget - start, self.block_size)
        if tail_budget <= 0 or not node.children:
            return None
        rest = tuple(np.asarray(tokens).tolist()[start:start + tail_budget])
        best, best_len = None, 0
        for key, child in node.children.items():
            n = _common_prefix_len(key, rest)
            if n > best_len:
                best, best_len = child, n
        return (best, best_len) if best is not None else None

    def lookup_len(self, tokens: Sequence[int]) -> int:
        """Tokens a :meth:`match` would serve right now — NO forks, no
        stats, no LRU touch.  The cache-aware admission policy probes
        every waiting request with this each tick; only the request
        actually admitted runs the real (side-effecting) match."""
        if not self.enabled:
            return 0
        budget = max(len(tokens) - 1, 0)
        node, n_full = self.root, 0
        for key in _block_keys(tokens, self.block_size)[:budget // self.block_size]:
            child = node.children.get(key)
            if child is None:
                break
            n_full += 1
            node = child
        n = n_full * self.block_size
        if self.partial:
            tail = self._partial_child(node, tokens, n_full, budget)
            if tail is not None:
                n += tail[1]
        return n

    def cancel_match(self, tokens: Sequence[int],
                     blocks: "MatchResult") -> None:
        """Undo a ``match`` whose admission was refused: release the forked
        blocks (full AND partial source) and back out the stats, so the
        reported hit rate counts only tokens actually served from the
        cache (a pool-pressured queue head re-matching every scheduler
        tick must not inflate it)."""
        self.release(blocks)
        psrc = getattr(blocks, "partial_src", None)
        plen = getattr(blocks, "partial_len", 0)
        if psrc is not None:
            self.release([psrc])
        self.stats.lookups -= 1
        self.stats.lookup_tokens -= len(tokens)
        if blocks or plen:
            self.stats.hits -= 1
            self.stats.hit_tokens -= len(blocks) * self.block_size + plen
        if plen:
            self.stats.partial_hits -= 1
            self.stats.partial_hit_tokens -= plen

    def insert(self, tokens: Sequence[int], blocks: Sequence[int],
               decode: bool = False) -> int:
        """Register a request's FULL sequence blocks in the trie — prompt
        blocks after prefill (the engine's ``commit_prefill``), or
        prompt+generated blocks as decode crosses each block boundary
        (``decode=True``, scheduler.register_decode_blocks).

        ``blocks[i]`` must hold the latents of tokens
        ``[i*bs, (i+1)*bs)`` — i.e. call this only after those latents
        are in the pool (or their writes are enqueued ahead of any
        future reader, the async dispatch-order argument).  Paths
        already present keep their existing block (the caller's duplicate
        stays private and is simply freed on release); new paths adopt
        the caller's block without taking an extra refcount — trie
        residency is tracked separately and only pins a block once its
        refcount drops to zero (it becomes LRU-evictable, not free).
        Returns the number of newly registered blocks.
        """
        if not self.enabled:
            return 0
        node, added = self.root, 0
        for key, blk in zip(_block_keys(tokens, self.block_size), blocks):
            child = node.children.get(key)
            if child is None:
                if blk in self._node_of:     # already registered elsewhere
                    break                    # (defensive; ids are unique)
                child = _Node(key, blk, node)
                node.children[key] = child
                self._node_of[blk] = child
                added += 1
                self.stats.inserted_blocks += 1
                if decode:
                    self.stats.decode_blocks_inserted += 1
            child.last_used = self._tick()
            node = child
        return added

    # ------------------------------------------------- refcount lifecycle --

    def release(self, blocks: Sequence[int]) -> None:
        """Drop one reference per block.  Blocks reaching refcount 0 go to
        the LRU-evictable set if trie-registered (their latents stay warm
        for future matches), straight back to the free list otherwise."""
        zeroed = self.allocator.release(blocks)
        for b in zeroed:
            node = self._node_of.get(b)
            if node is not None:
                node.last_used = self._tick()
                self._evictable[b] = node
            else:
                self.allocator.free([b])

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh private blocks (refcount 1), evicting LRU
        cached blocks as needed.  None (and no state change) if the pool
        cannot cover the request even after evicting everything."""
        short = n - self.allocator.num_free
        if short > 0:
            self.evict(short)
        return self.allocator.alloc(n)

    def evict(self, n: int) -> int:
        """Evict up to ``n`` refcount-zero cached blocks, least recently
        used childless nodes first (a node with children cannot go or it
        would orphan deeper cached blocks).  Returns the number evicted.

        The per-eviction scan over the evictable set is O(cached) — fine
        at this pool scale; a last_used heap with stale-entry filtering
        is the drop-in upgrade when pools reach many thousands of
        blocks."""
        evicted = 0
        while evicted < n:
            leaves = [nd for nd in self._evictable.values()
                      if not nd.children]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            self._drop_node(victim)
            evicted += 1
            self.stats.evictions += 1
        if evicted and self.tel is not None:
            self.tel.tracer.instant("prefix_evict", args={"n": evicted})
            if self.tel.metrics is not None:
                self.tel.metrics.counter("prefix_cache.evictions").inc(evicted)
        return evicted

    def _drop_node(self, node: _Node) -> None:
        del node.parent.children[node.key]
        del self._node_of[node.block]
        del self._evictable[node.block]
        self.allocator.free([node.block])

    # ----------------------------------------------------- write guarding --

    def is_write_shared(self, block: int) -> bool:
        """True if writing ``block`` in place would corrupt state another
        holder can see: refcount > 1 (another request maps it) or trie-
        registered (a future match would read it)."""
        return self.allocator.refcount.get(block, 0) > 1 \
            or block in self._node_of

    def count_cow(self) -> None:
        self.stats.cow_copies += 1
        if self.tel is not None:
            self.tel.tracer.instant("cow_copy")
            if self.tel.metrics is not None:
                self.tel.metrics.counter("prefix_cache.cow_copies").inc()

    # ------------------------------------------------------------- stats ---

    @property
    def num_cached(self) -> int:
        """Blocks resident in the trie (shared or evictable)."""
        return len(self._node_of)

    @property
    def num_evictable(self) -> int:
        return len(self._evictable)

    def summary(self) -> Dict[str, float]:
        s = self.stats
        return {
            "prefix_lookups": float(s.lookups),
            "prefix_hits": float(s.hits),
            "prefix_hit_tokens": float(s.hit_tokens),
            "prefix_lookup_tokens": float(s.lookup_tokens),
            "prefix_hit_rate": s.hit_rate,
            "prefix_partial_hits": float(s.partial_hits),
            "prefix_partial_hit_tokens": float(s.partial_hit_tokens),
            "prefix_inserted_blocks": float(s.inserted_blocks),
            "prefix_decode_inserted_blocks": float(s.decode_blocks_inserted),
            "prefix_evictions": float(s.evictions),
            "prefix_cow_copies": float(s.cow_copies),
            "prefix_cached_blocks": float(self.num_cached),
        }

    # ---------------------------------------------------------- invariants -

    def check_invariants(self, live_refs: Dict[int, int]) -> None:
        """Assert the refcount bookkeeping matches ``live_refs`` (block ->
        number of live block-table references); used by the hypothesis
        property test.  Raises AssertionError on violation."""
        rc = self.allocator.refcount
        for b, n in live_refs.items():
            assert rc.get(b, 0) == n, \
                f"block {b}: refcount {rc.get(b, 0)} != {n} live references"
        for b, c in rc.items():
            if c == 0:
                assert b in self._evictable, \
                    f"block {b} has refcount 0 but is not evictable"
            else:
                assert live_refs.get(b, 0) == c, \
                    f"block {b}: refcount {c} but {live_refs.get(b, 0)} refs"
        free = set(self.allocator._free)
        assert not (free & set(rc)), "freed block still carries a refcount"
        assert not (free & set(self._node_of)), "freed block still cached"
