"""Speculative-decoding support for the paged MLA runtime: draft-model
construction + host-side acceptance.

The paper's core finding is that MLA's compact latent cache pushes decode
toward the compute-bound regime — exactly where speculative decoding pays
off: verifying k draft tokens re-uses the same latent-cache read a
single-token step already pays for (the k+1-query verify step is the
prefill-chunk machinery with chunk = k+1; see TransMLA, arXiv:2502.07864,
for the same argument made for latent attention at large).

Pieces (all host-side; the device steps live in runtime.steps):

  * :func:`shallow_draft` — SELF-speculation: the draft model is the
    target's own first ``n_layers`` (embedding / final norm / unembedding
    shared by reference, layer weights sliced out of the target tree, re-
    stacked to match the draft's own scan plan).  No second checkpoint,
    no tokenizer mismatch by construction.
  * :func:`identity_draft` — the degenerate draft == target.  Every draft
    token matches the target's choice, so the engine must accept all k
    per round — the end-to-end validity oracle for the accept/rewind
    machinery (tests/test_spec_decode.py, bench_serving's spec row).
  * :func:`parse_draft_spec` — CLI surface: 'self' | 'shallow:N'.
  * :func:`accept_length` — the token-exact acceptance rule.  The target
    samples its OWN token at every verify position with the same
    fold(rid, absolute position) keys plain decode uses, and a draft
    token is accepted iff it EQUALS that token.  Emitted tokens are
    therefore byte-identical to plain paged decode under greedy AND
    seeded sampling — draft quality only moves throughput, never tokens.

Rollback needs no device work beyond the natural overwrite: lengths are
host-global numpy on every topology (PR 4), so rejecting drafts is a
length rewind — stale pool entries sit beyond ``lengths``, are never
attended, and are overwritten by the very next writes at those positions.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import ModelConfig


# ------------------------------------------------------------ acceptance ---


def accept_length(drafts: np.ndarray, targets: np.ndarray) -> int:
    """Number of leading draft tokens equal to the target's own choices.

    drafts: (k,) proposed tokens d_1..d_k; targets: (nv,) the target
    model's sampled token at each verify position (nv <= k + 1).  The
    round emits ``targets[:accept_length + 1]`` — the accepted drafts ARE
    the target's tokens, plus one bonus/correction token, so the emitted
    stream is exactly what plain decode would have produced."""
    n = 0
    for j in range(min(len(drafts), len(targets) - 1)):
        if int(drafts[j]) != int(targets[j]):
            break
        n += 1
    return n


# ----------------------------------------------------------- draft models --


def identity_draft(cfg: ModelConfig, params) -> Tuple[ModelConfig, object]:
    """Draft == target.  Proposals always match, acceptance is exactly k
    every round — the validity oracle (and an upper bound on speedup)."""
    return cfg, params


def shallow_draft(cfg: ModelConfig, params, n_layers: int
                  ) -> Tuple[ModelConfig, object]:
    """Self-speculation draft: the target's first ``n_layers`` layers.

    Returns (draft_cfg, draft_params) where draft_params REUSES the
    target's leaves (no copies beyond re-stacking scanned layers):
    embed / ln_f by reference, layer weights sliced from the target's
    prefix/period/suffix tree and reassembled to match
    ``lm_defs(draft_cfg)``'s own layer plan.  Requires an MLA decoder-only
    target (the paged runtime's precondition anyway)."""
    if not 1 <= n_layers < cfg.n_layers:
        raise ValueError(
            f"shallow draft needs 1 <= n_layers < {cfg.n_layers}, "
            f"got {n_layers}")
    if cfg.family == "encdec":
        raise NotImplementedError("shallow drafts target decoder-only LMs")
    draft_cfg = dataclasses.replace(
        cfg, name=f"{cfg.name}-draft{n_layers}", n_layers=n_layers)
    layers = _flat_layer_params(params, cfg)[:n_layers]
    draft_params = {"embed": params["embed"], "ln_f": params["ln_f"]}
    draft_params.update(_assemble_layer_params(layers, draft_cfg))
    return draft_cfg, draft_params


def _flat_layer_params(params, cfg: ModelConfig) -> List[dict]:
    """The per-layer param dicts of an lm tree, in layer order (scanned
    periods unstacked)."""
    prefix, period, n_periods, suffix = cfg.layer_plan()
    out = [params["prefix"][f"l{i}"] for i in range(len(prefix))]
    for p in range(n_periods):
        for i in range(len(period)):
            out.append(jax.tree.map(lambda a, p=p: a[p],
                                    params["period"][f"s{i}"]))
    out.extend(params["suffix"][f"l{i}"] for i in range(len(suffix)))
    return out


def _assemble_layer_params(layers: List[dict], cfg: ModelConfig) -> dict:
    """Inverse of :func:`_flat_layer_params` for ``cfg``'s own plan."""
    prefix, period, n_periods, suffix = cfg.layer_plan()
    it = iter(layers)
    out = {"prefix": {f"l{i}": next(it) for i in range(len(prefix))}}
    if n_periods:
        slices = [[next(it) for _ in range(len(period))]
                  for _ in range(n_periods)]
        out["period"] = {
            f"s{i}": jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[s[i] for s in slices])
            for i in range(len(period))}
    out["suffix"] = {f"l{i}": next(it) for i in range(len(suffix))}
    try:
        next(it)
    except StopIteration:
        return out
    raise ValueError(f"{len(layers)} layer param dicts for a "
                     f"{cfg.n_layers}-layer plan")


def parse_draft_spec(spec: str, cfg: ModelConfig, params
                     ) -> Tuple[ModelConfig, object]:
    """CLI draft spec: 'self' (identity oracle) or 'shallow:N' (first N
    layers of the target, self-speculation)."""
    if spec == "self":
        return identity_draft(cfg, params)
    if spec.startswith("shallow:"):
        try:
            n = int(spec.split(":", 1)[1])
        except ValueError:
            raise SystemExit(f"--draft shallow:N needs an int, got {spec!r}")
        return shallow_draft(cfg, params, n)
    raise SystemExit(f"unknown --draft spec {spec!r} "
                     "(expected 'self' or 'shallow:N')")
