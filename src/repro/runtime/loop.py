"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested on CPU):
  * auto-resume     — on construction, restores the latest complete
    checkpoint (params + optimizer + data-iterator state) and continues;
    a run killed at any point replays to an IDENTICAL final state
    (deterministic data pipeline + deterministic init).
  * async save      — checkpoint serialization overlaps the next steps.
  * keep-k GC       — bounded disk usage.
  * failure drills  — ``fail_at_step`` raises SimulatedFailure mid-run
    (tests restart the loop and assert bitwise state equality vs an
    uninterrupted run).
  * straggler policy — per-step deadline = ``straggler_factor`` x running
    median step time; a breach is recorded and the step is re-dispatched
    (recomputed — deterministic, so semantics are unchanged).  On a real
    pod the re-dispatch would target a hot spare; the policy/bookkeeping
    here is the same code path.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointStore
from ..data import SyntheticLM
from ..obs import as_logger


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_save: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_warmup: int = 8       # steps before the deadline activates
    fail_at_step: Optional[int] = None   # failure drill


class TrainLoop:
    def __init__(self, cfg: LoopConfig, step_fn: Callable, params, opt_state,
                 data: SyntheticLM, *, make_batch: Optional[Callable] = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.step_fn = step_fn
        self.data = data
        # ``log`` keeps its legacy bare-callable contract (tests pass
        # ``log=lambda *_: None``); internally every line goes through
        # the structured logger so it can be silenced / JSON-formatted
        # like the serving engine's.
        self.log = log
        self.slog = as_logger(log, "loop")
        self.store = CheckpointStore(cfg.ckpt_dir, keep=cfg.keep)
        self.params, self.opt_state = params, opt_state
        self.step = 0
        self.step_times: List[float] = []
        self.straggler_events: List[Dict[str, Any]] = []
        self.make_batch = make_batch or (lambda toks, labels: {
            "tokens": jax.numpy.asarray(toks), "labels": jax.numpy.asarray(labels)})
        self._maybe_resume()

    # ---------------------------------------------------------- resume ----
    def _maybe_resume(self):
        latest = self.store.latest_step()
        if latest is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        restored, meta = self.store.restore(latest, jax.tree.map(np.asarray, tree))
        # elastic: device_put with the CURRENT shardings (taken from the
        # live params tree, which the caller built for the current mesh).
        self.params = jax.tree.map(
            lambda live, new: jax.device_put(new, live.sharding)
            if hasattr(live, "sharding") else jax.numpy.asarray(new),
            self.params, restored["params"])
        self.opt_state = jax.tree.map(
            lambda live, new: jax.device_put(new, live.sharding)
            if hasattr(live, "sharding") else jax.numpy.asarray(new),
            self.opt_state, restored["opt"])
        self.data.load_state_dict(meta["data_state"])
        self.step = meta["step"]
        self.slog.info("resumed from checkpoint", step=self.step)

    # ------------------------------------------------------------- run ----
    def _deadline(self) -> Optional[float]:
        if len(self.step_times) < self.cfg.straggler_warmup:
            return None
        return self.cfg.straggler_factor * statistics.median(self.step_times[-64:])

    def _run_step(self, batch) -> Dict[str, Any]:
        t0 = time.monotonic()
        self.params, self.opt_state, metrics = self.step_fn(
            self.params, self.opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        deadline = self._deadline()
        if deadline is not None and dt > deadline:
            # straggler: record + re-dispatch (deterministic recompute).
            self.straggler_events.append(
                {"step": self.step, "time": dt, "deadline": deadline})
            self.slog.warning("straggler re-dispatched", step=self.step,
                              time_s=dt, deadline_s=deadline)
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
        self.step_times.append(dt)
        return metrics

    def run(self) -> Dict[str, Any]:
        last_metrics: Dict[str, Any] = {}
        while self.step < self.cfg.total_steps:
            toks, labels = self.data.next_batch()
            batch = self.make_batch(toks, labels)
            last_metrics = self._run_step(batch)
            self.step += 1
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                self.slog.info("step", step=self.step,
                               loss=float(last_metrics["loss"]),
                               ms=self.step_times[-1] * 1e3)
            if self.step % self.cfg.ckpt_every == 0 or \
                    self.step == self.cfg.total_steps:
                self.store.save(
                    self.step, {"params": self.params, "opt": self.opt_state},
                    data_state=self.data.state_dict(),
                    blocking=not self.cfg.async_save)
            if self.cfg.fail_at_step is not None and \
                    self.step == self.cfg.fail_at_step:
                self.store.wait()
                raise SimulatedFailure(f"injected failure at step {self.step}")
        self.store.wait()
        return last_metrics
