"""Driver loops: the serving request driver (``drive``) shared by both
paged engines, and the fault-tolerant training loop (``TrainLoop``).

Serving: ``drive(engine, requests)`` feeds a pre-built arrival-stamped
request list into the engine tick by tick and steps until ``engine.idle``
— for the synchronous engine that is scheduler-drained; the async engine
also keeps ticking until its in-flight device step is accounted, so the
pipeline drains through the same loop with no special-casing.  Live
traffic (the HTTP frontend) uses launch/server.py's worker instead,
which calls ``engine.submit`` / ``engine.step`` directly.

Training-loop production behaviors (unit-tested on CPU):
  * auto-resume     — on construction, restores the latest complete
    checkpoint (params + optimizer + data-iterator state) and continues;
    a run killed at any point replays to an IDENTICAL final state
    (deterministic data pipeline + deterministic init).
  * async save      — checkpoint serialization overlaps the next steps.
  * keep-k GC       — bounded disk usage.
  * failure drills  — ``fail_at_step`` raises SimulatedFailure mid-run
    (tests restart the loop and assert bitwise state equality vs an
    uninterrupted run).
  * straggler policy — per-step deadline = ``straggler_factor`` x running
    median step time; a breach is recorded and the step is re-dispatched
    (recomputed — deterministic, so semantics are unchanged).  On a real
    pod the re-dispatch would target a hot spare; the policy/bookkeeping
    here is the same code path.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointStore
from ..data import SyntheticLM
from ..obs import as_logger


class SimulatedFailure(RuntimeError):
    pass


def drive(engine, requests, *, max_steps: int = 100_000,
          log_every: int = 0, log=print) -> Dict[str, float]:
    """Drive a request stream to completion on either paged engine.

    ``req.arrival`` is the step index at which a request joins the
    waiting queue (Poisson arrivals in the example driver).  The loop
    runs until every request is submitted and ``engine.idle`` — the
    async engine stays non-idle while a dispatched step is unaccounted,
    so its pipeline drains here without a separate flush call.  ``log``
    may be a bare callable (legacy ``log=print`` API) or an
    ``obs.StructLogger``; a telemetry logger, if configured, wins."""
    slog = engine.tel.logger if engine.tel.logger is not None \
        else as_logger(log, "engine")
    todo = sorted(requests, key=lambda r: r.arrival)
    i = 0
    while not (i >= len(todo) and engine.idle):
        while i < len(todo) and todo[i].arrival <= engine.stats.steps:
            engine.submit(todo[i])
            i += 1
        engine.step()
        if log_every and engine.stats.steps % log_every == 0:
            u = engine.sched.utilization()
            slog.info("step", step=engine.stats.steps,
                      active=engine.sched.n_active,
                      waiting=len(engine.sched.waiting),
                      done=len(engine.sched.finished),
                      util=u["valid_frac"], pool=u["pool_frac"],
                      scheme=engine._last_scheme)
        if engine.stats.steps >= max_steps:
            raise RuntimeError(f"did not drain in {max_steps} steps")
    return engine.summary()


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_save: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_warmup: int = 8       # steps before the deadline activates
    fail_at_step: Optional[int] = None   # failure drill


class TrainLoop:
    def __init__(self, cfg: LoopConfig, step_fn: Callable, params, opt_state,
                 data: SyntheticLM, *, make_batch: Optional[Callable] = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.step_fn = step_fn
        self.data = data
        # ``log`` keeps its legacy bare-callable contract (tests pass
        # ``log=lambda *_: None``); internally every line goes through
        # the structured logger so it can be silenced / JSON-formatted
        # like the serving engine's.
        self.log = log
        self.slog = as_logger(log, "loop")
        self.store = CheckpointStore(cfg.ckpt_dir, keep=cfg.keep)
        self.params, self.opt_state = params, opt_state
        self.step = 0
        self.step_times: List[float] = []
        self.straggler_events: List[Dict[str, Any]] = []
        self.make_batch = make_batch or (lambda toks, labels: {
            "tokens": jax.numpy.asarray(toks), "labels": jax.numpy.asarray(labels)})
        self._maybe_resume()

    # ---------------------------------------------------------- resume ----
    def _maybe_resume(self):
        latest = self.store.latest_step()
        if latest is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        restored, meta = self.store.restore(latest, jax.tree.map(np.asarray, tree))
        # elastic: device_put with the CURRENT shardings (taken from the
        # live params tree, which the caller built for the current mesh).
        self.params = jax.tree.map(
            lambda live, new: jax.device_put(new, live.sharding)
            if hasattr(live, "sharding") else jax.numpy.asarray(new),
            self.params, restored["params"])
        self.opt_state = jax.tree.map(
            lambda live, new: jax.device_put(new, live.sharding)
            if hasattr(live, "sharding") else jax.numpy.asarray(new),
            self.opt_state, restored["opt"])
        self.data.load_state_dict(meta["data_state"])
        self.step = meta["step"]
        self.slog.info("resumed from checkpoint", step=self.step)

    # ------------------------------------------------------------- run ----
    def _deadline(self) -> Optional[float]:
        if len(self.step_times) < self.cfg.straggler_warmup:
            return None
        return self.cfg.straggler_factor * statistics.median(self.step_times[-64:])

    def _run_step(self, batch) -> Dict[str, Any]:
        t0 = time.monotonic()
        self.params, self.opt_state, metrics = self.step_fn(
            self.params, self.opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        deadline = self._deadline()
        if deadline is not None and dt > deadline:
            # straggler: record + re-dispatch (deterministic recompute).
            self.straggler_events.append(
                {"step": self.step, "time": dt, "deadline": deadline})
            self.slog.warning("straggler re-dispatched", step=self.step,
                              time_s=dt, deadline_s=deadline)
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
        self.step_times.append(dt)
        return metrics

    def run(self) -> Dict[str, Any]:
        last_metrics: Dict[str, Any] = {}
        while self.step < self.cfg.total_steps:
            toks, labels = self.data.next_batch()
            batch = self.make_batch(toks, labels)
            last_metrics = self._run_step(batch)
            self.step += 1
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                self.slog.info("step", step=self.step,
                               loss=float(last_metrics["loss"]),
                               ms=self.step_times[-1] * 1e3)
            if self.step % self.cfg.ckpt_every == 0 or \
                    self.step == self.cfg.total_steps:
                self.store.save(
                    self.step, {"params": self.params, "opt": self.opt_state},
                    data_state=self.data.state_dict(),
                    blocking=not self.cfg.async_save)
            if self.cfg.fail_at_step is not None and \
                    self.step == self.cfg.fail_at_step:
                self.store.wait()
                raise SimulatedFailure(f"injected failure at step {self.step}")
        self.store.wait()
        return last_metrics
