"""Continuous-batching MLA serving engine over the paged latent-KV pool.

Glues the host-side ``ContinuousScheduler`` (admission, block tables,
eviction) to the jitted device steps:

  * per-request prefill (bucketed capacities to bound recompiles) feeding
    ``scatter_prefill_to_paged`` — the prefill->pool handoff;
  * one paged decode step per scheduler tick over ALL slots (inactive
    slots ride along pointing at the null block; their logits are
    discarded);
  * ``schemes.auto_dispatch`` re-run EVERY step on the live
    (batch, max cache_len) point with the paged-bytes cost term, so the
    rc/ru/seq choice tracks the batch composition — jitted steps are
    cached per scheme and swapped freely because all schemes compute the
    same function with identical weights (the paper's core claim).

Used by examples/serve_mla.py, benchmarks/bench_serving.py and
``python -m repro.launch.serve --paged``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from .. import models
from ..core import mla as mlalib
from ..core.schemes import PlatformPoint, auto_dispatch
from ..models.common import ModelConfig
from .scheduler import ContinuousScheduler, Request, blocks_for
from .steps import (make_paged_serve_step, make_prefill_step,
                    scatter_prefill_to_paged)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    admissions: int = 0
    mid_gen_admissions: int = 0     # admitted while other slots were decoding
    preemptions: int = 0
    scheme_switches: int = 0
    util_valid_sum: float = 0.0     # time-avg of valid/allocated
    util_pool_sum: float = 0.0
    util_samples: int = 0
    wall: float = 0.0
    schemes_used: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, float]:
        n = max(self.util_samples, 1)
        return {
            "steps": self.steps,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "admissions": self.admissions,
            "mid_gen_admissions": self.mid_gen_admissions,
            "preemptions": self.preemptions,
            "scheme_switches": self.scheme_switches,
            "tokens_per_s": (self.decode_tokens / self.wall)
            if self.wall > 0 else 0.0,
            "cache_utilization": self.util_valid_sum / n,
            "pool_occupancy": self.util_pool_sum / n,
            "schemes_used": dict(self.schemes_used),
        }


class PagedMLAEngine:
    def __init__(self, cfg: ModelConfig, params, *, num_blocks: int,
                 block_size: int, max_batch: int,
                 max_blocks_per_req: Optional[int] = None,
                 compute_dtype=jnp.float32, impl: str = "ref",
                 scheme: str = "auto",
                 platform: Optional[PlatformPoint] = None):
        if cfg.attn_kind != "mla":
            raise NotImplementedError("PagedMLAEngine requires an MLA model")
        if scheme == "auto" and platform is None:
            raise ValueError("scheme='auto' needs a PlatformPoint")
        self.cfg = cfg
        self.mla = cfg.mla_config()
        # 'ru' streams the precomputed absorbed weights; attach them once
        # so every scheme's jitted step sees the same param tree.  A fixed
        # non-ru scheme never reads them — skip the compute and memory.
        self.params = mlalib.attach_absorbed_tree(params, self.mla) \
            if scheme in ("auto", "ru") else params
        self.compute_dtype = compute_dtype
        self.impl = impl
        self.scheme = scheme
        self.platform = platform
        self.block_size = block_size
        # max_blocks_per_req bounds the block-table WIDTH, i.e. the extent
        # every decode step scans per request — size it to the workload's
        # longest request, not the pool (nb = pool size would make each
        # step's cost scale with total pool capacity).
        self.sched = ContinuousScheduler(
            num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_blocks_per_req=max_blocks_per_req)
        self.pool = models.init_paged_cache(cfg, num_blocks, block_size,
                                            compute_dtype)
        self.pending = np.zeros((max_batch,), np.int32)   # next token to feed
        self._decode_steps: Dict[str, object] = {}
        self._prefills: Dict[int, object] = {}
        self._last_scheme: Optional[str] = None
        self.stats = EngineStats()

    # ------------------------------------------------------------ build ---

    def _decode_step(self, scheme: str):
        if scheme not in self._decode_steps:
            self._decode_steps[scheme] = make_paged_serve_step(
                self.cfg, None, compute_dtype=self.compute_dtype,
                impl=self.impl, scheme=scheme)
        return self._decode_steps[scheme]

    def _prefill(self, cap: int):
        if cap not in self._prefills:
            # prefill attention runs in "MHA mode"; the scheme only matters
            # at decode, so one prefill serves every scheme.
            self._prefills[cap] = make_prefill_step(
                self.cfg, None, batch=1, capacity=cap,
                compute_dtype=self.compute_dtype, impl=self.impl)
        return self._prefills[cap]

    def _pick_scheme(self) -> str:
        if self.scheme != "auto":
            self._last_scheme = self.scheme
            return self.scheme
        active = self.sched.active_slots
        cache_len = int(self.sched.lengths[active].max()) + 1 if active else 1
        s = auto_dispatch(self.mla, self.platform, cache_len=cache_len,
                          batch=max(len(active), 1),
                          paged_block=self.block_size)
        if self._last_scheme is not None and s != self._last_scheme:
            self.stats.scheme_switches += 1
        self._last_scheme = s
        return s

    # ------------------------------------------------------------- run ----

    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def step(self) -> None:
        """One scheduler tick: admit + prefill, then one batched decode
        step over all slots."""
        t0 = time.perf_counter()
        step_i = self.stats.steps
        was_decoding = self.sched.n_active > 0

        # grow running requests BEFORE admitting: otherwise a just-admitted
        # request could take the last blocks, get preempted immediately,
        # and throw away the prefill it just paid for.
        self.stats.preemptions += len(self.sched.ensure_step_capacity())

        for slot, req in self.sched.try_admit(step_i):
            # cache capacity buckets to a block multiple; the token array
            # stays unpadded so prefill's last-position logits are the
            # real prompt end (jit retraces per distinct prompt length —
            # drivers should quantize prompt lengths).
            cap = blocks_for(req.plen, self.block_size) * self.block_size
            logits, entries = self._prefill(cap)(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None])
            pages = jnp.asarray(self.sched.block_table[slot], jnp.int32)
            self.pool = scatter_prefill_to_paged(self.pool, entries, pages)
            tok = int(jnp.argmax(logits[0]))
            self.stats.admissions += 1
            self.stats.prefill_tokens += req.plen
            if was_decoding:
                self.stats.mid_gen_admissions += 1
            if self.sched.record_prefill_sample(slot, tok, step_i) is None:
                self.pending[slot] = tok

        active = self.sched.active_slots
        if active:
            scheme = self._pick_scheme()
            self.stats.schemes_used[scheme] = \
                self.stats.schemes_used.get(scheme, 0) + 1
            step_fn = self._decode_step(scheme)
            logits, self.pool = step_fn(
                self.params, jnp.asarray(self.pending),
                self.pool, jnp.asarray(self.sched.block_table),
                jnp.asarray(self.sched.lengths))
            sampled = np.asarray(jnp.argmax(logits, axis=-1))
            picks = {s: int(sampled[s]) for s in active}
            self.sched.advance(picks, step_i)
            for s, t in picks.items():
                self.pending[s] = t
            self.stats.decode_tokens += len(active)

        u = self.sched.utilization()
        self.stats.util_valid_sum += u["valid_frac"]
        self.stats.util_pool_sum += u["pool_frac"]
        self.stats.util_samples += 1
        self.stats.steps += 1
        self.stats.wall += time.perf_counter() - t0

    def run(self, requests: List[Request], *, max_steps: int = 100_000,
            log_every: int = 0, log=print) -> Dict[str, float]:
        """Drive a request stream to completion.  ``req.arrival`` is the
        step index at which a request joins the waiting queue (Poisson
        arrivals in the example driver)."""
        todo = sorted(requests, key=lambda r: r.arrival)
        i = 0
        while not (i >= len(todo) and self.sched.all_done):
            while i < len(todo) and todo[i].arrival <= self.stats.steps:
                self.submit(todo[i])
                i += 1
            self.step()
            if log_every and self.stats.steps % log_every == 0:
                u = self.sched.utilization()
                log(f"[engine] step {self.stats.steps}: "
                    f"active={self.sched.n_active} "
                    f"waiting={len(self.sched.waiting)} "
                    f"done={len(self.sched.finished)} "
                    f"util={u['valid_frac']:.2f} "
                    f"pool={u['pool_frac']:.2f} "
                    f"scheme={self._last_scheme}")
            if self.stats.steps >= max_steps:
                raise RuntimeError(f"did not drain in {max_steps} steps")
        return self.stats.summary()




