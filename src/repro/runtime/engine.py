"""Continuous-batching MLA serving engine over the paged latent-KV pool.

Glues the host-side ``ContinuousScheduler`` (admission, radix prefix
cache, block tables, eviction) to the jitted device steps:

  * batched CHUNKED prefill straight into the pool
    (``make_chunked_prefill_step``): admitted requests prefill together,
    fixed-size chunk by chunk, attending their prefix-cache hits through
    the block table — one compiled step shape per chunk size instead of
    one retrace per prompt length, and no contiguous-entries detour.
    (``prefill_mode='per_request'`` keeps PR-1's bucketed per-request
    prefill + scatter for A/B comparison; it forces the prefix cache off
    because it recomputes and rewrites whole prompts.)
  * one paged decode step per scheduler tick over ALL slots (inactive
    slots ride along pointing at the null block; their logits are
    discarded);
  * sampling: greedy argmax by default; ``temperature > 0`` switches to
    temperature / top-k sampling with a per-request PRNG key folded with
    the ABSOLUTE token position, so recompute-preemption replay remains
    deterministic (replayed tokens live in the prompt; fresh tokens
    re-land on the same fold(rid, position) stream);
  * ``schemes.auto_dispatch`` re-run EVERY step on the live
    (batch, max cache_len) point with the paged-bytes cost term, so the
    rc/ru/seq choice tracks the batch composition — jitted steps are
    cached per scheme and swapped freely because all schemes compute the
    same function with identical weights (the paper's core claim);
  * optional ``mesh``/``shard_policy``: decode and chunked prefill run
    sharded — batch (token / block-table / length rows) over the DP axes,
    heads over 'model', the latent pool replicated over every axis (its
    compactness is what makes full replication affordable — the paper's
    bandwidth argument scaled out).  ``max_batch`` is padded up to a DP
    multiple (free: inactive slots carry length 0 and null tables), the
    scheduler stays host-global and unsharded, and outputs are
    token-identical to single-host serving (tests/test_mesh_paged.py).

Two engines share this machinery (and the scheduler, steps and stats):

  * ``PagedMLAEngine`` — the synchronous reference tick: schedule ->
    device step -> host sample, one barrier per tick.  Ground truth for
    every parity gate.
  * ``AsyncPagedMLAEngine`` — the double-buffered production tick: the
    host runs tick N+1's scheduling (admission, block growth, CoW drain)
    while the device still executes tick N, sampling is folded into the
    compiled step (``make_paged_sample_step``) so only the (B,) accepted
    tokens ever sync back, and token values are accounted one tick late —
    token-identical to the synchronous engine (docs/architecture.md walks
    the argument; tests/test_async_engine.py pins it).

Both engines expose ``request_cancel`` (thread-safe, processed at tick
start) and honor per-request ``stop`` sequences / ``max_new`` budgets via
the scheduler — the frontend hooks (launch/server.py) need nothing else.

Used by examples/serve_mla.py, benchmarks/bench_serving.py and
``python -m repro.launch.serve --paged`` (``--serve`` puts the HTTP/SSE
frontend on top).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import models
from ..core import cache as cachelib
from ..core import mla as mlalib
from ..core.schemes import PlatformPoint, auto_dispatch
from ..models.common import ModelConfig
from ..obs import OFF_TELEMETRY, Telemetry
from ..obs.trace import PID_ENGINE
from . import spec as speclib
from .scheduler import ContinuousScheduler, Request, blocks_for
from .steps import (make_chunked_prefill_step, make_paged_sample_step,
                    make_paged_serve_step, make_prefill_step,
                    make_verify_step, scatter_prefill_to_paged)

# PID_ENGINE tid 0 carries the host-phase spans; the async engine's
# device spans live on their own track so a device step spanning two host
# ticks cannot break tid-0 span nesting (obs.trace.validate_trace).
TID_DEVICE = 1


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0         # tokens actually prefilled (cache
    prompt_tokens: int = 0          # hits excluded) vs tokens submitted
    prefill_chunks: int = 0
    admissions: int = 0
    mid_gen_admissions: int = 0     # admitted while other slots were decoding
    preemptions: int = 0
    scheme_switches: int = 0
    spec_rounds: int = 0            # speculative draft+verify ticks
    spec_slot_rounds: int = 0       # per-slot verify rows across rounds
    spec_drafted: int = 0           # draft tokens proposed
    spec_accepted: int = 0          # draft tokens accepted by the target
    util_valid_sum: float = 0.0     # time-avg of valid/allocated
    util_pool_sum: float = 0.0
    util_samples: int = 0
    wall: float = 0.0
    schemes_used: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, float]:
        n = max(self.util_samples, 1)
        return {
            "steps": self.steps,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "prompt_tokens": self.prompt_tokens,
            "prefill_chunks": self.prefill_chunks,
            "admissions": self.admissions,
            "mid_gen_admissions": self.mid_gen_admissions,
            "preemptions": self.preemptions,
            "scheme_switches": self.scheme_switches,
            "tokens_per_s": (self.decode_tokens / self.wall)
            if self.wall > 0 else 0.0,
            "spec_rounds": self.spec_rounds,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_accept_rate": self.spec_accepted / self.spec_drafted
            if self.spec_drafted else 0.0,
            # per-REQUEST tokens per verify: the quantity the hwmodel
            # break-even E* is stated in (1 <= E <= k + 1)
            "spec_mean_emitted": self.decode_tokens / self.spec_slot_rounds
            if self.spec_slot_rounds else 0.0,
            "cache_utilization": self.util_valid_sum / n,
            "pool_occupancy": self.util_pool_sum / n,
            "schemes_used": dict(self.schemes_used),
        }


class PagedMLAEngine:
    def __init__(self, cfg: ModelConfig, params, *, num_blocks: int,
                 block_size: int, max_batch: int,
                 max_blocks_per_req: Optional[int] = None,
                 compute_dtype=jnp.float32, impl: str = "ref",
                 scheme: str = "auto",
                 platform: Optional[PlatformPoint] = None,
                 enable_prefix_cache: bool = True,
                 prefill_chunk: int = 32,
                 prefill_mode: str = "chunked",
                 prefill_impl: Optional[str] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0,
                 mesh=None, shard_policy: str = "serve",
                 spec_k: int = 0, draft_cfg: Optional[ModelConfig] = None,
                 draft_params=None,
                 cache_dtype: str = "bf16",
                 admission: str = "cache_aware",
                 admission_age_bound: int = 64,
                 decode_block_reuse: bool = True,
                 partial_match: bool = True,
                 telemetry: Optional[Telemetry] = None):
        if cfg.attn_kind != "mla":
            raise NotImplementedError("PagedMLAEngine requires an MLA model")
        if scheme == "auto" and platform is None:
            raise ValueError("scheme='auto' needs a PlatformPoint")
        if prefill_mode not in ("chunked", "per_request"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        cache_dtype = "bf16" if cache_dtype is None else cache_dtype
        cachelib.cache_dtype_info(cache_dtype)   # validate the name early
        if cache_dtype != "bf16" and prefill_mode != "chunked":
            raise NotImplementedError(
                "quantized cache_dtype requires prefill_mode='chunked' "
                "(the per-request scatter carries no scales)")
        if mesh is not None and prefill_mode != "chunked":
            # the per-request path jits an UNSHARDED contiguous prefill and
            # scatters into the (replicated) pool — keep the A/B baseline
            # single-host rather than half-shard it
            raise NotImplementedError(
                "mesh serving requires prefill_mode='chunked' (the "
                "per-request A/B path is single-host)")
        if impl == "pallas":        # alias: the kernel impl IS Pallas
            impl = "kernel"
        if prefill_impl in ("auto", ""):
            prefill_impl = None
        if prefill_impl not in (None, "gather", "pallas"):
            raise ValueError(f"unknown prefill_impl {prefill_impl!r} "
                             "(None/'auto' | 'gather' | 'pallas')")
        if prefill_mode != "chunked" and enable_prefix_cache:
            # the per-request path recomputes + rewrites WHOLE prompts,
            # which would scatter over read-only shared blocks
            enable_prefix_cache = False
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k:
            if prefill_mode != "chunked":
                raise NotImplementedError(
                    "speculative decoding requires prefill_mode='chunked' "
                    "(the draft pool is filled by the same chunked path)")
            if draft_cfg is None or draft_params is None:
                raise ValueError(
                    "spec_k > 0 needs draft_cfg + draft_params — build "
                    "them with runtime.spec.shallow_draft / identity_draft")
            if draft_cfg.vocab != cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab} != target {cfg.vocab}")
            if draft_cfg.attn_kind != "mla":
                raise NotImplementedError("drafts must be MLA models "
                                          "(they share the paged runtime)")
        self.cfg = cfg
        self.mla = cfg.mla_config()
        self.mesh = mesh
        self.shard_policy = shard_policy
        # DP shard count: the batch dim (token/table/length rows) shards
        # over ('pod', 'data'); 'model' shards heads and replicates the
        # pool (see steps.cache_pspecs paged=).
        self._dp = 1
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for a in ("pod", "data"):
                self._dp *= sizes.get(a, 1)
            # pad the slot count to a DP multiple so PS(dp) divides the
            # batch dim.  Free: the extra slots are ordinary empty slots
            # (length 0, null block table) until the scheduler admits
            # into them — and more slots never hurts admission.
            max_batch = -(-max_batch // self._dp) * self._dp
        # 'ru' streams the precomputed absorbed weights; attach them once
        # so every scheme's jitted step sees the same param tree.  A fixed
        # non-ru scheme never reads them — skip the compute and memory.
        self.params = mlalib.attach_absorbed_tree(params, self.mla) \
            if scheme in ("auto", "ru") else params
        if mesh is not None:
            from .steps import commit_params
            self.params = commit_params(self.params, cfg, mesh,
                                        shard_policy)
        self.compute_dtype = compute_dtype
        self.cache_dtype = cache_dtype
        self.impl = impl
        self.scheme = scheme
        self.platform = platform
        self.block_size = block_size
        self.prefill_mode = prefill_mode
        # chunked-prefill attention path: None follows ``impl`` ('ref' ->
        # gather view, 'kernel' -> Pallas); 'gather'/'pallas' override it
        # so the prefill path can be A/B'd with the decode path pinned
        # (bench_serving's prefill-kernel row does exactly that).
        self.prefill_impl = prefill_impl
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sample_seed = int(sample_seed)
        self._sample_key = jax.random.PRNGKey(sample_seed)
        # cancellation flags from other threads (the HTTP frontend),
        # drained at the start of every tick
        self._cancel_lock = threading.Lock()
        self._cancels: set = set()
        # max_blocks_per_req bounds the block-table WIDTH, i.e. the extent
        # every decode step scans per request — size it to the workload's
        # longest request, not the pool (nb = pool size would make each
        # step's cost scale with total pool capacity).
        self.sched = ContinuousScheduler(
            num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_blocks_per_req=max_blocks_per_req,
            enable_prefix_cache=enable_prefix_cache,
            decode_window=spec_k + 1,
            admission=admission,
            admission_age_bound=admission_age_bound,
            decode_block_reuse=decode_block_reuse,
            partial_match=partial_match)
        self.pool = models.init_paged_cache(cfg, num_blocks, block_size,
                                            compute_dtype,
                                            cache_dtype=cache_dtype)
        # -- speculative decoding: draft model + its own paged pool -------
        # The draft pool shares the scheduler's GEOMETRY (block size, block
        # ids, tables) with the target pool — one host-side allocator and
        # one block table serve both — so accept/reject is a shared length
        # rewind and every block-level op (CoW copies, eviction reuse)
        # applies to both pools in lockstep.
        self.spec_k = int(spec_k)
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_pool = None
        # drafts always decode with 'seq' (all schemes compute the same
        # function; 'seq' needs no absorbed leaves, so shallow drafts
        # sliced from an un-absorbed tree work for every engine scheme)
        self._draft_scheme = "seq"
        if spec_k:
            self.draft_pool = models.init_paged_cache(
                draft_cfg, num_blocks, block_size, compute_dtype,
                cache_dtype=cache_dtype)
            if mesh is not None and draft_params is not params:
                # shallow drafts alias embed/ln_f/first-N-layer leaves of
                # the target: reuse the committed buffers instead of
                # device_put-ing a second copy of each shared weight
                from .steps import commit_draft_params
                self.draft_params = commit_draft_params(
                    draft_params, draft_cfg, mesh, shard_policy,
                    target_host=params, target_committed=self.params)
        if mesh is not None:
            # the pool replicates over every mesh axis (host-global block
            # tables may point any DP shard at any block); committing it
            # here keeps the donated in/out shardings copy-free.
            from jax.sharding import NamedSharding, PartitionSpec as PS
            repl = lambda tree: jax.device_put(
                tree, jax.tree.map(lambda _: NamedSharding(mesh, PS()),
                                   tree))
            self.pool = repl(self.pool)
            if self.draft_pool is not None:
                self.draft_pool = repl(self.draft_pool)
        if spec_k and self.draft_params is params:
            # identity draft ('self'): share the engine's prepared tree
            # (absorbed leaves attached / mesh-committed above)
            self.draft_params = self.params
        self.pending = np.zeros((max_batch,), np.int32)   # next token to feed
        self._decode_steps: Dict[str, object] = {}
        self._prefills: Dict[int, object] = {}     # per_request: cap -> fn
        self._chunk_steps: Dict[int, object] = {}  # chunked: chunk size -> fn
        self._verify_steps: Dict[str, object] = {}  # spec: scheme -> fn
        self._draft_decode_step = None
        self._draft_chunk_steps: Dict[int, object] = {}
        self._copy_block = jax.jit(cachelib.copy_block_paged,
                                   donate_argnums=(0,))
        self._copy_blocks = jax.jit(cachelib.copy_blocks_paged,
                                    donate_argnums=(0,))
        self._last_scheme: Optional[str] = None
        self._last_point = (1, 1)     # (batch, cache_len) of the last pick
        self.stats = EngineStats()
        # bytes one cached token occupies across ALL layers at the POOL's
        # storage dtype — the occupancy gauges below convert allocated
        # blocks to HBM bytes through this, so telemetry prices the same
        # pool the dispatcher does (int8 pools would otherwise report
        # bf16-sized occupancy; pinned by tests/test_quant_cache.py)
        self.cache_token_bytes = cfg.n_layers * cachelib.bytes_per_token_latent(
            cfg.kv_lora_rank, cfg.qk_rope_dim,
            dtype_bytes=jnp.dtype(compute_dtype).itemsize,
            cache_dtype=cache_dtype)
        # -- telemetry (repro.obs): default is the no-op singleton, whose
        # span() returns a shared null context manager — the instrumented
        # hot path below costs one attribute check per site when off.
        self.tel = telemetry if telemetry is not None else OFF_TELEMETRY
        if self.tel.drift is not None and not self.tel.drift.active \
                and platform is not None:
            self.tel.drift.bind(mla=self.mla, platform=platform,
                                paged_block=block_size, dp_shards=self._dp,
                                cache_dtype=cache_dtype)
        if self.tel.enabled:
            self.sched.prefix.tel = self.tel

    # ------------------------------------------------------------ build ---

    def _decode_step(self, scheme: str):
        if scheme not in self._decode_steps:
            self._decode_steps[scheme] = make_paged_serve_step(
                self.cfg, self.mesh, compute_dtype=self.compute_dtype,
                impl=self.impl, scheme=scheme, policy=self.shard_policy,
                cache_dtype=self.cache_dtype)
        return self._decode_steps[scheme]

    def _prefill(self, cap: int):
        if cap not in self._prefills:
            # prefill attention runs in "MHA mode"; the scheme only matters
            # at decode, so one prefill serves every scheme.
            self._prefills[cap] = make_prefill_step(
                self.cfg, None, batch=1, capacity=cap,
                compute_dtype=self.compute_dtype, impl=self.impl)
        return self._prefills[cap]

    def _chunk_impl(self) -> str:
        """Chunk-attention impl of the prefill AND verify steps: follows
        ``prefill_impl`` when overridden, else the engine ``impl``."""
        return {"gather": "ref", "pallas": "kernel",
                None: self.impl}[self.prefill_impl]

    def _chunk_step(self, chunk: int):
        if chunk not in self._chunk_steps:
            # a FIXED engine scheme prefills with the same absorption
            # ordering (all schemes compute the same function); 'auto'
            # pins prefill to 'seq' so the per-step decode dispatch does
            # not multiply compiled chunk shapes, and 'naive' has no
            # latent chunk path.
            scheme = self.scheme if self.scheme in ("seq", "rc", "ru") \
                else "seq"
            self._chunk_steps[chunk] = make_chunked_prefill_step(
                self.cfg, self.mesh, compute_dtype=self.compute_dtype,
                impl=self._chunk_impl(), scheme=scheme,
                policy=self.shard_policy, cache_dtype=self.cache_dtype)
        return self._chunk_steps[chunk]

    def _draft_chunk_step(self, chunk: int):
        """Draft-model sibling of :meth:`_chunk_step`: keeps the draft
        pool prompt-complete so drafting can start right after prefill."""
        if chunk not in self._draft_chunk_steps:
            self._draft_chunk_steps[chunk] = make_chunked_prefill_step(
                self.draft_cfg, self.mesh,
                compute_dtype=self.compute_dtype, impl=self._chunk_impl(),
                scheme=self._draft_scheme, policy=self.shard_policy,
                cache_dtype=self.cache_dtype)
        return self._draft_chunk_steps[chunk]

    def _draft_step(self):
        if self._draft_decode_step is None:
            self._draft_decode_step = make_paged_serve_step(
                self.draft_cfg, self.mesh,
                compute_dtype=self.compute_dtype, impl=self.impl,
                scheme=self._draft_scheme, policy=self.shard_policy,
                cache_dtype=self.cache_dtype)
        return self._draft_decode_step

    def _verify_step(self, scheme: str):
        if scheme not in self._verify_steps:
            self._verify_steps[scheme] = make_verify_step(
                self.cfg, self.mesh, compute_dtype=self.compute_dtype,
                impl=self._chunk_impl(), scheme=scheme,
                policy=self.shard_policy, cache_dtype=self.cache_dtype)
        return self._verify_steps[scheme]

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill step shapes built so far: bounded by the number
        of chunk sizes (chunked mode) instead of prompt-length buckets."""
        return (len(self._chunk_steps) + len(self._prefills)
                + len(self._draft_chunk_steps))

    @property
    def spec_compiles(self) -> int:
        """Distinct speculative step shapes: verify steps (<= one per
        scheme, all at chunk k+1) + the single draft decode step."""
        return len(self._verify_steps) + (self._draft_decode_step is not None)

    def _pick_scheme(self, verify_k: int = 0) -> str:
        active = self.sched.active_slots
        cache_len = int(self.sched.lengths[active].max()) + 1 if active else 1
        # the live dispatch point, kept for the roofline drift channel —
        # predictions must be evaluated at the point the dispatch saw
        self._last_point = (max(len(active), 1), cache_len)
        if self.scheme != "auto":
            self._last_scheme = self.scheme
            return self.scheme
        s = auto_dispatch(self.mla, self.platform, cache_len=cache_len,
                          batch=max(len(active), 1),
                          paged_block=self.block_size,
                          dp_shards=self._dp, verify_k=verify_k,
                          cache_dtype=self.cache_dtype)
        if self._last_scheme is not None and s != self._last_scheme:
            self.stats.scheme_switches += 1
        self._last_scheme = s
        return s

    # -------------------------------------------------------- sampling ----

    def _sample_tokens(self, rows, slots) -> Dict[int, int]:
        """Sample one token per slot; ``rows`` (len(slots), V) carries the
        logits row of each listed slot (still occupied by its request).

        temperature <= 0: one batched greedy argmax.  Otherwise
        temperature / top-k sampling, one batched device call: per-slot
        keys fold(fold(seed, rid), position), position = absolute index
        of the sampled token in the request's full sequence — invariant
        under recompute preemption (the folded prompt grows by exactly
        the generated tokens), so replay drains the same PRNG stream per
        request regardless of batch composition and reproduces the same
        output."""
        if self.temperature <= 0.0:
            arg = np.asarray(jnp.argmax(rows, axis=-1))
            return {s: int(arg[i]) for i, s in enumerate(slots)}
        rids, poss = [], []
        for s in slots:
            req = self.sched.slots[s]
            rids.append(req.rid)
            poss.append(req.plen + len(req.tokens))
        toks = self._sample_rows(rows, rids, poss)
        return {s: int(toks[i]) for i, s in enumerate(slots)}

    def _sample_rows(self, rows, rids, poss) -> np.ndarray:
        """Temperature / top-k sample one token per logits row with the
        fold(fold(seed, rid), position) key stream (see _sample_tokens;
        also the verify positions of a speculative round — same keys, so
        spec-decode emits the exact tokens plain decode would)."""
        if self.mesh is not None:
            # Gather the (few-KB) logits rows to the host and re-feed them
            # as a single-device array: under the pre-0.5 jax default
            # (threefry_partitionable=False) the SAME random op lowered
            # over a sharded operand draws DIFFERENT bits than unsharded,
            # so sampling straight from the vocab-sharded logits would
            # silently fork the PRNG stream from the single-host engine.
            # Host-side rows make the sampled stream topology-invariant.
            rows = jnp.asarray(np.asarray(rows))
        return np.asarray(self._sample_fn(
            rows, jnp.asarray(rids, jnp.uint32),
            jnp.asarray(poss, jnp.uint32)))

    @functools.cached_property
    def _sample_fn(self):
        base, temp, top_k = self._sample_key, self.temperature, self.top_k

        def run(rows, rids, poss):
            keys = jax.vmap(lambda r, p: jax.random.fold_in(
                jax.random.fold_in(base, r), p))(rids, poss)
            rows = rows.astype(jnp.float32) / temp
            if top_k > 0:
                kth = jnp.sort(rows, axis=-1)[:, -top_k]
                rows = jnp.where(rows >= kth[:, None], rows, -jnp.inf)
            return jax.vmap(jax.random.categorical)(keys, rows)

        return jax.jit(run)

    # --------------------------------------------------------- prefill ----

    def _run_chunked_prefill(self, admitted, step_i: int) -> None:
        """Prefill every just-admitted request's UN-CACHED prompt suffix as
        a batch, ``prefill_chunk`` tokens per request per step, scattering
        latents straight into the pool.  Rows that exhaust their prompt in
        a chunk sample generated token #1 from that chunk's last-valid
        logits and register their blocks in the radix cache."""
        C = self.prefill_chunk
        step_fn = self._chunk_step(C)
        tr = self.tel.tracer
        drift = self.tel.drift if (self.tel.drift is not None
                                   and self.tel.drift.active) else None
        t_pf = time.perf_counter() if drift else 0.0
        pending = dict(admitted)
        fill = {slot: req.n_cached for slot, req in admitted}
        while pending:
            tokens = np.zeros((self.sched.max_batch, C), np.int32)
            lens = np.zeros((self.sched.max_batch,), np.int32)
            nv = np.zeros((self.sched.max_batch,), np.int32)
            finishing = []
            for slot, req in list(pending.items()):
                start = fill[slot]
                take = min(req.plen - start, C)
                tokens[slot, :take] = req.prompt[start:start + take]
                lens[slot] = start
                nv[slot] = take
                fill[slot] = start + take
                if fill[slot] >= req.plen:
                    finishing.append((slot, req))
                    del pending[slot]
            with tr.span("prefill_chunk"):
                logits, self.pool = step_fn(
                    self.params, jnp.asarray(tokens), self.pool,
                    jnp.asarray(self.sched.block_table), jnp.asarray(lens),
                    jnp.asarray(nv))
                if self.spec_k:
                    # the draft prefills the SAME chunk into its own pool,
                    # so a request can start drafting the moment it is
                    # admitted (prefix-cache hits skip both pools
                    # symmetrically: shared block ids carry valid latents
                    # in each)
                    _, self.draft_pool = self._draft_chunk_step(C)(
                        self.draft_params, jnp.asarray(tokens),
                        self.draft_pool, jnp.asarray(self.sched.block_table),
                        jnp.asarray(lens), jnp.asarray(nv))
            self.stats.prefill_tokens += int(nv.sum())
            self.stats.prefill_chunks += 1
            for slot, req in finishing:
                tok = self._sample_tokens(logits[slot][None], [slot])[slot]
                # register blocks only now — their latents are in the pool
                self.sched.commit_prefill(slot)
                self._fork_and_seed(slot, logits[slot][None], step_i)
                if self.sched.record_prefill_sample(slot, tok, step_i) is None:
                    self.pending[slot] = tok
        if drift:
            # one drift row per admitted batch, over the whole chunk walk
            # (the cost model predicts a full prompt's chunk sequence);
            # measured time includes the finishing rows' first-token
            # sampling — a constant overhead the stable-ratio gate absorbs
            self._sync_device()
            seq_len = max(req.plen for _, req in admitted)
            cached = min(req.n_cached for _, req in admitted)
            if seq_len > cached:
                scheme = self.scheme if self.scheme in ("seq", "rc", "ru") \
                    else "seq"
                impl = "pallas" if self._chunk_impl() == "kernel" \
                    else "gather"
                drift.record_prefill(scheme, len(admitted), seq_len, C,
                                     impl, time.perf_counter() - t_pf,
                                     cached_prefix=cached)

    def _run_per_request_prefill(self, admitted, step_i: int) -> None:
        """PR-1's path: contiguous per-request prefill (bucketed capacities
        to bound recompiles) + whole-block scatter into the pool.  Kept
        for A/B benchmarking; incompatible with prefix sharing."""
        for slot, req in admitted:
            cap = blocks_for(req.plen, self.block_size) * self.block_size
            logits, entries = self._prefill(cap)(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None])
            pages = jnp.asarray(self.sched.block_table[slot], jnp.int32)
            self.pool = scatter_prefill_to_paged(self.pool, entries, pages)
            self.stats.prefill_tokens += req.plen
            tok = self._sample_tokens(logits[0][None], [slot])[slot]
            self.sched.commit_prefill(slot)
            self._fork_and_seed(slot, logits[0][None], step_i)
            if self.sched.record_prefill_sample(slot, tok, step_i) is None:
                self.pending[slot] = tok

    # ------------------------------------------------------------- run ----

    def validate_sampling(self, sp) -> None:
        """Raise ValueError unless the per-request ``SamplingParams`` are
        servable by THIS engine.  Knobs that are engine-global
        (temperature / top_k / seed) must MATCH the engine's configuration
        when set: the async engine bakes them into the compiled fused
        step (make_paged_sample_step), so honoring a per-request override
        would mint a new compiled-step variant per value — exactly what
        the hot-path auditor's compiled-variant matrix forbids.  None
        always means 'inherit'.  The HTTP frontend calls this on the
        handler thread so a mismatch becomes a 400, not a worker death."""
        if sp is None:
            return
        if sp.temperature is not None \
                and float(sp.temperature) != self.temperature:
            raise ValueError(
                f"temperature={sp.temperature} != engine temperature "
                f"{self.temperature}; per-request overrides are baked "
                f"into the compiled step — set it engine-wide or leave "
                f"None to inherit")
        if sp.top_k is not None and int(sp.top_k) != self.top_k:
            raise ValueError(
                f"top_k={sp.top_k} != engine top_k {self.top_k}; set it "
                f"engine-wide or leave None")
        if sp.seed is not None and int(sp.seed) != self._sample_seed:
            raise ValueError(
                f"seed={sp.seed} != engine sample_seed "
                f"{self._sample_seed}; set it engine-wide or leave None")

    def submit(self, req: Request) -> None:
        self.validate_sampling(req.sampling)
        self.sched.submit(req)

    @property
    def idle(self) -> bool:
        """No queued, running or otherwise unaccounted work — the driver
        loop (runtime.loop.drive) may stop ticking."""
        return self.sched.all_done

    def request_cancel(self, rid: int) -> None:
        """Flag ``rid`` for cancellation.  Thread-safe: the frontend's
        connection handlers call this from their own threads; the engine
        drains the flags at the start of its next tick and releases the
        request's slot and blocks (scheduler.cancel)."""
        with self._cancel_lock:
            self._cancels.add(rid)

    def _process_cancels(self, step_i: int) -> None:
        with self._cancel_lock:
            rids, self._cancels = self._cancels, set()
        for rid in sorted(rids):
            self.sched.cancel(rid, step_i)

    def _drain_cow(self) -> None:
        """Apply the scheduler's queued copy-on-write block copies to the
        device pool(s).  Independent pairs batch into ONE device op per
        pool (core.cache.copy_blocks_paged), padded to the next power of
        two with (0, 0) null pairs — block 0 is the reserved NULL block,
        so copying it onto itself is a no-op — bounding compiled variants
        to log2(max batch).  A CHAINED batch (some dst re-read as a later
        src, e.g. preemption-replay cascades) must apply in queue order
        and falls back to sequential single-block copies."""
        pairs = self.sched.drain_cow()
        if not pairs:
            return
        srcs = [p[0] for p in pairs]
        dsts = [p[1] for p in pairs]
        if len(pairs) == 1 or (set(srcs) & set(dsts)):
            for src, dst in pairs:
                self.pool = self._copy_block(self.pool,
                                             jnp.asarray(src, jnp.int32),
                                             jnp.asarray(dst, jnp.int32))
                if self.draft_pool is not None:
                    # block-level ops track both pools (same geometry)
                    self.draft_pool = self._copy_block(
                        self.draft_pool, jnp.asarray(src, jnp.int32),
                        jnp.asarray(dst, jnp.int32))
            return
        n = 1
        while n < len(pairs):
            n *= 2
        pad = n - len(pairs)
        s = jnp.asarray(srcs + [0] * pad, jnp.int32)
        d = jnp.asarray(dsts + [0] * pad, jnp.int32)
        self.pool = self._copy_blocks(self.pool, s, d)
        if self.draft_pool is not None:
            self.draft_pool = self._copy_blocks(self.draft_pool, s, d)

    def _fork_and_seed(self, slot: int, row, step_i: int) -> None:
        """Fork a just-prefilled n > 1 parent (scheduler.fork_group) and
        sample every child's first token from the parent's last-position
        prefill logits — each on its OWN fold(child rid, position) key
        stream, so the group is token-identical to n independent
        requests.  Runs between commit_prefill and the parent's own
        record_prefill_sample: a parent finishing instantly (max_tokens
        == 1) has then already handed its children their refcounts."""
        kids = self.sched.fork_group(slot)
        if not kids:
            return
        cslots = [cs for cs, _ in kids]
        rows = jnp.broadcast_to(row, (len(kids),) + tuple(row.shape[1:]))
        picks = self._sample_tokens(rows, cslots)
        for cs in cslots:
            tok = picks[cs]
            if self.sched.record_prefill_sample(cs, tok, step_i) is None:
                self.pending[cs] = tok

    def _sync_device(self) -> None:
        """Block until this tick's device work has retired.  jax dispatch
        is asynchronous: without this barrier the step wall clock stops
        while decode/prefill launches are still in flight and ``wall`` /
        ``tokens_per_s`` measure dispatch, not compute (pinned by
        tests/test_obs.py)."""
        jax.block_until_ready(self.pool)
        if self.draft_pool is not None:
            jax.block_until_ready(self.draft_pool)

    def step(self) -> None:
        """One scheduler tick: admit + batched prefill, then one batched
        decode step over all slots."""
        t0 = time.perf_counter()
        step_i = self.stats.steps
        self._process_cancels(step_i)
        was_decoding = self.sched.n_active > 0
        tr = self.tel.tracer
        drift = self.tel.drift if (self.tel.drift is not None
                                   and self.tel.drift.active) else None

        with tr.span("step"):
            with tr.span("schedule"):
                # grow running requests BEFORE admitting: otherwise a
                # just-admitted request could take the last blocks, get
                # preempted immediately, and throw away the prefill it
                # just paid for.
                self.stats.preemptions += len(
                    self.sched.ensure_step_capacity())
                self._drain_cow()
                admitted = self.sched.try_admit(step_i)
                # partial-hit tail copies queued by try_admit must land
                # before prefill gathers/writes touch the pool
                self._drain_cow()
            for _, req in admitted:
                self.stats.admissions += 1
                self.stats.prompt_tokens += req.plen
                if was_decoding:
                    self.stats.mid_gen_admissions += 1
            if admitted:
                with tr.span("prefill"):
                    if self.prefill_mode == "chunked":
                        self._run_chunked_prefill(admitted, step_i)
                    else:
                        self._run_per_request_prefill(admitted, step_i)
                # fork-group tail copies queued by fork_group must land
                # before both forks' decode writes dispatch
                self._drain_cow()

            active = self.sched.active_slots
            if active and self.spec_k:
                self._spec_round(active, step_i)
            elif active:
                scheme = self._pick_scheme()
                self.stats.schemes_used[scheme] = \
                    self.stats.schemes_used.get(scheme, 0) + 1
                step_fn = self._decode_step(scheme)
                t_dev = time.perf_counter() if drift else 0.0
                with tr.span("device_step"):
                    logits, self.pool = step_fn(
                        self.params, jnp.asarray(self.pending),
                        self.pool, jnp.asarray(self.sched.block_table),
                        jnp.asarray(self.sched.lengths))
                    jax.block_until_ready(self.pool)
                if drift:
                    b, cl = self._last_point
                    drift.record_decode(scheme, b, cl,
                                        time.perf_counter() - t_dev)
                with tr.span("host_sample"):
                    picks = self._sample_tokens(logits[jnp.asarray(active)],
                                                active)
                    self.sched.advance(picks, step_i)
                for s, t in picks.items():
                    self.pending[s] = t
                self.stats.decode_tokens += len(active)

            u = self.sched.utilization()
            self.stats.util_valid_sum += u["valid_frac"]
            self.stats.util_pool_sum += u["pool_frac"]
            self.stats.util_samples += 1
            # close the wall clock only after the device work dispatched
            # this tick has retired — prefill-only and spec ticks return
            # before the pool write lands otherwise
            self._sync_device()
        self.stats.steps += 1
        dt = time.perf_counter() - t0
        self.stats.wall += dt
        if self.tel.metrics is not None:
            m = self.tel.metrics
            m.histogram("step_ms").record(dt * 1e3)
            m.histogram("pool_occupancy").record(u["pool_frac"])
            m.histogram("pool_allocated_bytes").record(
                u["allocated_blocks"] * self.block_size
                * self.cache_token_bytes)

    # ------------------------------------------------ speculative round ----

    def _spec_round(self, active, step_i: int) -> None:
        """One draft + verify + accept tick over all active slots.

        1. DRAFT: the draft model proposes up to k tokens per slot by
           plain paged decode against its own pool.  Proposals use the
           SAME decision rule as the target — greedy argmax, or
           temperature/top-k with the shared fold(rid, absolute position)
           key stream — so an identity draft proposes exactly what the
           target will sample (100% acceptance, the oracle property)
           under seeded sampling too, not just greedy.  The loop runs
           each slot's full write window (budget-clipped nv[s] = min(k+1,
           remaining)) so the LAST draft's latent is written too — the
           final iteration's proposal is discarded but its write is what
           keeps the draft pool complete when all k drafts are accepted.
           Slots whose window is exhausted freeze (same token re-written
           at the same position — idempotent), so one fixed step shape
           serves ragged windows.
        2. VERIFY: one chunked multi-query forward of the TARGET over
           [pending, d_1 .. d_{nv-1}] (runtime.steps.make_verify_step,
           chunk = k+1): the resident latent prefix streams from HBM once
           for all positions.  The target's own token at every position
           comes from the same greedy argmax / fold(rid, position) key
           stream plain decode uses.
        3. ACCEPT: leading drafts equal to the target's tokens are
           accepted; the round emits the accepted run plus one bonus /
           correction token (exactly what plain decode would have
           produced — runtime.spec.accept_length).  Rejection is a pure
           host-side length rewind: advance_multi moves ``lengths`` past
           the accepted run only; stale latents beyond it are masked by
           every attention path and overwritten before they can become
           visible.  Topology-independent: lengths are host numpy under
           any mesh (PR 4).
        """
        k = self.spec_k
        B = self.sched.max_batch
        tr = self.tel.tracer
        drift = self.tel.drift if (self.tel.drift is not None
                                   and self.tel.drift.active) else None
        nv = np.zeros((B,), np.int32)
        for s in active:
            nv[s] = self.sched._window(self.sched.slots[s])
        # ---- 1. draft ---------------------------------------------------
        drafts = np.zeros((B, k), np.int32)
        d_pending = self.pending.copy()
        d_lens = self.sched.lengths.copy()
        bt = jnp.asarray(self.sched.block_table)
        d_step = self._draft_step()
        with tr.span("draft"):
            for j in range(int(nv.max())):
                d_logits, self.draft_pool = d_step(
                    self.draft_params, jnp.asarray(d_pending),
                    self.draft_pool, bt, jnp.asarray(d_lens))
                if self.temperature <= 0.0:
                    prop = np.asarray(jnp.argmax(d_logits, axis=-1))
                else:
                    # proposal at absolute position d_lens + 1 draws the
                    # same fold(rid, position) key the target uses to
                    # sample THAT position in verify — identical models
                    # propose identical tokens under seeded sampling
                    live = [s for s in active if j < nv[s] - 1]
                    prop = np.zeros((B,), np.int64)
                    if live:
                        toks = self._sample_rows(
                            d_logits[jnp.asarray(live)],
                            [self.sched.slots[s].rid for s in live],
                            [int(d_lens[s]) + 1 for s in live])
                        for i, s in enumerate(live):
                            prop[s] = toks[i]
                for s in active:
                    if j < nv[s] - 1:
                        drafts[s, j] = prop[s]
                        self.stats.spec_drafted += 1
                    if j + 1 < nv[s]:    # still drafting next iteration
                        d_pending[s] = prop[s]
                        d_lens[s] += 1
        # ---- 2. verify --------------------------------------------------
        tokens_v = np.zeros((B, k + 1), np.int32)
        for s in active:
            tokens_v[s, 0] = self.pending[s]
            tokens_v[s, 1:nv[s]] = drafts[s, :nv[s] - 1]
        scheme = self._pick_scheme(verify_k=k)
        self.stats.schemes_used[scheme] = \
            self.stats.schemes_used.get(scheme, 0) + 1
        t_v = time.perf_counter() if drift else 0.0
        with tr.span("verify"):
            logits_v, self.pool = self._verify_step(scheme)(
                self.params, jnp.asarray(tokens_v), self.pool, bt,
                jnp.asarray(self.sched.lengths), jnp.asarray(nv))
            jax.block_until_ready(self.pool)
        if drift:
            b, cl = self._last_point
            drift.record_verify(scheme, b, cl, k,
                                time.perf_counter() - t_v)
        with tr.span("host_sample"):
            if self.temperature <= 0.0:
                target = np.asarray(jnp.argmax(logits_v, axis=-1))  # (B, k+1)
            else:
                flat, rids, poss = [], [], []
                for s in active:
                    req = self.sched.slots[s]
                    base = req.plen + len(req.tokens)  # abs pos, next sample
                    for j in range(int(nv[s])):
                        flat.append((s, j))
                        rids.append(req.rid)
                        poss.append(base + j)
                rows = logits_v[jnp.asarray([s for s, _ in flat]),
                                jnp.asarray([j for _, j in flat])]
                toks = self._sample_rows(rows, rids, poss)
                target = np.zeros((B, k + 1), np.int64)
                for i, (s, j) in enumerate(flat):
                    target[s, j] = toks[i]
            # ---- 3. accept + host-side length rewind --------------------
            emitted = {}
            for s in active:
                t_s = target[s, :nv[s]]
                n_acc = speclib.accept_length(drafts[s, :nv[s] - 1], t_s)
                emitted[s] = [int(t) for t in t_s[:n_acc + 1]]
                self.stats.spec_accepted += n_acc
            self.sched.advance_multi(emitted, step_i)
        for s, toks in emitted.items():
            if self.sched.slots[s] is not None:
                self.pending[s] = toks[-1]
        self.stats.decode_tokens += sum(len(t) for t in emitted.values())
        self.stats.spec_rounds += 1
        self.stats.spec_slot_rounds += len(active)

    def run(self, requests: List[Request], *, max_steps: int = 100_000,
            log_every: int = 0, log=print) -> Dict[str, float]:
        """Drive a request stream to completion — delegates to
        :func:`runtime.loop.drive` (shared with the async engine and the
        HTTP frontend's worker)."""
        from .loop import drive
        return drive(self, requests, max_steps=max_steps,
                     log_every=log_every, log=log)

    def summary(self) -> Dict[str, float]:
        """Engine stats + prefix-cache stats + allocator totals."""
        out = self.stats.summary()
        out.update(self.sched.prefix.summary())
        out["total_blocks_allocated"] = float(
            self.sched.allocator.total_allocs)
        out["fork_groups"] = float(self.sched.fork_groups)
        out["fork_children"] = float(self.sched.forked_children)
        out["prefill_compiles"] = float(self.prefill_compiles)
        out["spec_compiles"] = float(self.spec_compiles)
        out["cache_dtype"] = self.cache_dtype
        out["cache_token_bytes"] = float(self.cache_token_bytes)
        return out


# --------------------------------------------------------- async engine ----


@dataclasses.dataclass
class _Inflight:
    """One dispatched-but-unaccounted fused decode step."""
    tokens: object                       # (B,) int32 device array (future)
    entries: List[Tuple[int, Request]]   # (dispatch slot, request)
    deferred: List[Tuple[int, Request]]  # slot released, token value pending
    t_disp_tr: float                     # tracer ``now()`` clock at dispatch
    t_disp_perf: float                   # perf_counter at dispatch (drift)
    scheme: str
    point: Tuple[int, int]               # (batch, cache_len) dispatch point
    fetched: Optional[np.ndarray] = None  # host copy, once someone needed it


class AsyncPagedMLAEngine(PagedMLAEngine):
    """Double-buffered async engine: host work for tick N+1 overlaps the
    device's execution of tick N.

    Per tick (plain decode; ``spec_k > 0`` rounds drain the pipeline and
    run the synchronous :meth:`PagedMLAEngine._spec_round` — accept /
    rewind is value-dependent host work):

      1. *schedule* — while the device still runs the step dispatched
         last tick: requests whose in-flight token is structurally their
         last (``len(tokens) + 1 >= max_new``) release their slot and
         blocks immediately (the token VALUE arrives in step 3); block
         growth, preemption, CoW drain and admission run as usual.  CoW /
         prefill device ops enqueue AFTER the in-flight step in stream
         order, so the device-side op sequence is exactly the synchronous
         engine's.
      2. *prefill* — admitted prompts chunk-prefill (this syncs on the
         finishing rows' logits, serializing the tick — admission ticks
         pay the pipeline bubble, steady-state decode ticks don't).
      3. *host_sample* — fetch the in-flight (B,) token array (the only
         device->host transfer; blocks for however much device time the
         host did NOT overlap), emit the retrospective ``device_step``
         span on the device-stream track, and account token values:
         append, stop-sequence checks, deferred finishes, ``pending``.
      4. *dispatch* — launch the fused decode+sample step
         (``make_paged_sample_step``) for the current actives and advance
         ``lengths`` structurally; the host returns without waiting.

    Token identity with the synchronous engine (greedy and seeded) holds
    because sampling keys fold (rid, absolute position) — invariant under
    batch composition and admission timing — and each logits row depends
    only on its own request's tokens/cache.  The one-tick-late accounting
    only shifts WHEN slots free up, never what any request's next token
    is.  Preempted victims with an unaccounted in-flight token fold it
    into their replayed prompt first (:meth:`_fixup_preempted` — the rare
    forced sync), so replay matches the synchronous fold exactly.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._sample_steps: Dict[str, object] = {}
        self._inflight: Optional[_Inflight] = None

    @property
    def idle(self) -> bool:
        return self.sched.all_done and self._inflight is None

    def _sample_step(self, scheme: str):
        if scheme not in self._sample_steps:
            self._sample_steps[scheme] = make_paged_sample_step(
                self.cfg, self.mesh, compute_dtype=self.compute_dtype,
                impl=self.impl, scheme=scheme, policy=self.shard_policy,
                cache_dtype=self.cache_dtype,
                temperature=self.temperature, top_k=self.top_k,
                sample_seed=self._sample_seed)
        return self._sample_steps[scheme]

    # ------------------------------------------------------------- tick ----

    def step(self) -> None:
        if self.spec_k:
            # drain, then run the synchronous spec tick: double-buffering
            # applies to plain decode; spec rounds are host-interactive.
            self._drain_inflight()
            return super().step()
        t0 = time.perf_counter()
        step_i = self.stats.steps
        self._process_cancels(step_i)
        was_decoding = self.sched.n_active > 0 or self._inflight is not None
        tr = self.tel.tracer

        with tr.span("step"):
            with tr.span("schedule"):
                self._release_structural_finishes()
                preempted = self.sched.ensure_step_capacity()
                self.stats.preemptions += len(preempted)
                if preempted:
                    self._fixup_preempted(preempted, step_i)
                self._drain_cow()
                admitted = self.sched.try_admit(step_i)
                # partial-hit tail copies queued by try_admit must land
                # before prefill gathers/writes touch the pool
                self._drain_cow()
            for _, req in admitted:
                self.stats.admissions += 1
                self.stats.prompt_tokens += req.plen
                if was_decoding:
                    self.stats.mid_gen_admissions += 1
            if admitted:
                with tr.span("prefill"):
                    if self.prefill_mode == "chunked":
                        self._run_chunked_prefill(admitted, step_i)
                    else:
                        self._run_per_request_prefill(admitted, step_i)
                # fork-group tail copies queued by fork_group must land
                # before both forks' decode writes dispatch
                self._drain_cow()

            self._account(step_i)

            active = self.sched.active_slots
            if active:
                self._dispatch(active)

            u = self.sched.utilization()
            self.stats.util_valid_sum += u["valid_frac"]
            self.stats.util_pool_sum += u["pool_frac"]
            self.stats.util_samples += 1
        self.stats.steps += 1
        dt = time.perf_counter() - t0
        self.stats.wall += dt
        if self.tel.metrics is not None:
            m = self.tel.metrics
            m.histogram("step_ms").record(dt * 1e3)
            m.histogram("pool_occupancy").record(u["pool_frac"])
            m.histogram("pool_allocated_bytes").record(
                u["allocated_blocks"] * self.block_size
                * self.cache_token_bytes)

    # --------------------------------------------------- pipeline stages ---

    def _release_structural_finishes(self) -> None:
        """Free the slots of in-flight requests whose pending token is
        structurally their last (budget-predicted — stop hits cannot be
        predicted and are discovered at account time, one tick later).
        Their blocks become admissible NOW, overlapping the device."""
        inf = self._inflight
        if inf is None:
            return
        keep = []
        for slot, req in inf.entries:
            if req.slot == slot and not req.finish_reason \
                    and len(req.tokens) + 1 >= req.max_new:
                self.sched._release_slot(slot)
                inf.deferred.append((slot, req))
            else:
                keep.append((slot, req))
        inf.entries = keep

    def _fixup_preempted(self, preempted: List[Request],
                         step_i: int) -> None:
        """Recompute-preemption under an unaccounted in-flight token: the
        scheduler already folded ``tokens`` into the victim's prompt; the
        in-flight token must join that fold for the replay to match the
        synchronous engine.  This is the one place the async engine is
        forced to sync early (preemptions are the overloaded-pool path)."""
        inf = self._inflight
        if inf is None:
            return
        victims = {id(r) for r in preempted}
        keep, fix = [], []
        for slot, req in inf.entries:
            (fix if id(req) in victims else keep).append((slot, req))
        inf.entries = keep
        if not fix:
            return
        if inf.fetched is None:
            inf.fetched = np.asarray(inf.tokens)
        for slot, req in fix:
            tok = int(inf.fetched[slot])
            req.prompt = np.concatenate(
                [req.prompt, np.asarray([tok], np.int32)])
            req.max_new -= 1
            self.stats.decode_tokens += 1
            # the folded token may complete a stop sequence — the sync
            # engine would have finished the request instead of
            # preempting it; finish it here (it is back on the waiting
            # queue) so it never replays past its stop.
            if self.sched._check_stop(req):
                self.sched.waiting.remove(req)
                req.finished_step = step_i
                req.finish_t = time.perf_counter()
                self.sched.finished.append(req)

    def _account(self, step_i: int) -> None:
        """Fetch and account the in-flight step's token values (the only
        device->host sync of a steady-state tick)."""
        inf = self._inflight
        if inf is None:
            return
        self._inflight = None
        tr = self.tel.tracer
        drift = self.tel.drift if (self.tel.drift is not None
                                   and self.tel.drift.active) else None
        with tr.span("host_sample"):
            already = inf.fetched is not None
            toks = inf.fetched if already else np.asarray(inf.tokens)
            if tr.enabled:
                tr.set_thread_name(PID_ENGINE, TID_DEVICE, "device stream")
                tr.complete(
                    "device_step", PID_ENGINE, TID_DEVICE,
                    inf.t_disp_tr, tr.now(),
                    args={"scheme": inf.scheme,
                          "batch": len(inf.entries) + len(inf.deferred)})
            if drift and not already:
                # dispatch->ready wall: equals device time when the device
                # is the bottleneck, an upper bound otherwise
                b, cl = inf.point
                drift.record_decode(inf.scheme, b, cl,
                                    time.perf_counter() - inf.t_disp_perf)
            for slot, req in inf.entries:
                if req.finish_reason == "cancelled" or req.slot != slot:
                    continue
                tok = int(toks[slot])
                req.tokens.append(tok)
                self.stats.decode_tokens += 1
                self.sched._check_stop(req)
                if req.done:
                    self.sched._finish(slot, step_i)
                else:
                    self.pending[slot] = tok
            for slot, req in inf.deferred:
                if req.finish_reason == "cancelled":
                    continue
                tok = int(toks[slot])
                req.tokens.append(tok)
                self.stats.decode_tokens += 1
                self.sched._check_stop(req)
                if not req.finish_reason:
                    req.finish_reason = "length"
                req.finished_step = step_i
                req.finish_t = time.perf_counter()
                self.sched.finished.append(req)

    def _dispatch(self, active: List[int]) -> None:
        """Launch the fused decode+sample step for the current actives and
        return WITHOUT waiting; ``lengths`` advance structurally (the
        step writes each fed token's latent at position lengths[s])."""
        scheme = self._pick_scheme()
        self.stats.schemes_used[scheme] = \
            self.stats.schemes_used.get(scheme, 0) + 1
        step_fn = self._sample_step(scheme)
        B = self.sched.max_batch
        rids = np.zeros((B,), np.uint32)
        poss = np.zeros((B,), np.uint32)
        entries = []
        for s in active:
            req = self.sched.slots[s]
            rids[s] = req.rid
            poss[s] = req.plen + len(req.tokens)
            entries.append((s, req))
        tr = self.tel.tracer
        t_tr, t_perf = tr.now(), time.perf_counter()
        tokens, self.pool = step_fn(
            self.params, jnp.asarray(self.pending), self.pool,
            jnp.asarray(self.sched.block_table),
            jnp.asarray(self.sched.lengths),
            jnp.asarray(rids), jnp.asarray(poss))
        self._inflight = _Inflight(
            tokens=tokens, entries=entries, deferred=[], t_disp_tr=t_tr,
            t_disp_perf=t_perf, scheme=scheme, point=self._last_point)
        for s in active:
            self.sched.lengths[s] += 1
            if int(self.sched.lengths[s]) % self.block_size == 0:
                # a generated block just structurally completed; its
                # latent write is in-flight, but any future consumer's
                # gather enqueues AFTER it in stream order
                self.sched.register_decode_blocks(s)

    def _drain_inflight(self) -> None:
        """Account any in-flight step immediately (spec ticks and external
        sync points need the pipeline empty)."""
        self._account(self.stats.steps)
