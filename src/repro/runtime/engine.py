"""Continuous-batching MLA serving engine over the paged latent-KV pool.

Glues the host-side ``ContinuousScheduler`` (admission, radix prefix
cache, block tables, eviction) to the jitted device steps:

  * batched CHUNKED prefill straight into the pool
    (``make_chunked_prefill_step``): admitted requests prefill together,
    fixed-size chunk by chunk, attending their prefix-cache hits through
    the block table — one compiled step shape per chunk size instead of
    one retrace per prompt length, and no contiguous-entries detour.
    (``prefill_mode='per_request'`` keeps PR-1's bucketed per-request
    prefill + scatter for A/B comparison; it forces the prefix cache off
    because it recomputes and rewrites whole prompts.)
  * one paged decode step per scheduler tick over ALL slots (inactive
    slots ride along pointing at the null block; their logits are
    discarded);
  * sampling: greedy argmax by default; ``temperature > 0`` switches to
    temperature / top-k sampling with a per-request PRNG key folded with
    the ABSOLUTE token position, so recompute-preemption replay remains
    deterministic (replayed tokens live in the prompt; fresh tokens
    re-land on the same fold(rid, position) stream);
  * ``schemes.auto_dispatch`` re-run EVERY step on the live
    (batch, max cache_len) point with the paged-bytes cost term, so the
    rc/ru/seq choice tracks the batch composition — jitted steps are
    cached per scheme and swapped freely because all schemes compute the
    same function with identical weights (the paper's core claim);
  * optional ``mesh``/``shard_policy``: decode and chunked prefill run
    sharded — batch (token / block-table / length rows) over the DP axes,
    heads over 'model', the latent pool replicated over every axis (its
    compactness is what makes full replication affordable — the paper's
    bandwidth argument scaled out).  ``max_batch`` is padded up to a DP
    multiple (free: inactive slots carry length 0 and null tables), the
    scheduler stays host-global and unsharded, and outputs are
    token-identical to single-host serving (tests/test_mesh_paged.py).

Used by examples/serve_mla.py, benchmarks/bench_serving.py and
``python -m repro.launch.serve --paged``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import models
from ..core import cache as cachelib
from ..core import mla as mlalib
from ..core.schemes import PlatformPoint, auto_dispatch
from ..models.common import ModelConfig
from .scheduler import ContinuousScheduler, Request, blocks_for
from .steps import (make_chunked_prefill_step, make_paged_serve_step,
                    make_prefill_step, scatter_prefill_to_paged)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0         # tokens actually prefilled (cache
    prompt_tokens: int = 0          # hits excluded) vs tokens submitted
    prefill_chunks: int = 0
    admissions: int = 0
    mid_gen_admissions: int = 0     # admitted while other slots were decoding
    preemptions: int = 0
    scheme_switches: int = 0
    util_valid_sum: float = 0.0     # time-avg of valid/allocated
    util_pool_sum: float = 0.0
    util_samples: int = 0
    wall: float = 0.0
    schemes_used: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, float]:
        n = max(self.util_samples, 1)
        return {
            "steps": self.steps,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "prompt_tokens": self.prompt_tokens,
            "prefill_chunks": self.prefill_chunks,
            "admissions": self.admissions,
            "mid_gen_admissions": self.mid_gen_admissions,
            "preemptions": self.preemptions,
            "scheme_switches": self.scheme_switches,
            "tokens_per_s": (self.decode_tokens / self.wall)
            if self.wall > 0 else 0.0,
            "cache_utilization": self.util_valid_sum / n,
            "pool_occupancy": self.util_pool_sum / n,
            "schemes_used": dict(self.schemes_used),
        }


class PagedMLAEngine:
    def __init__(self, cfg: ModelConfig, params, *, num_blocks: int,
                 block_size: int, max_batch: int,
                 max_blocks_per_req: Optional[int] = None,
                 compute_dtype=jnp.float32, impl: str = "ref",
                 scheme: str = "auto",
                 platform: Optional[PlatformPoint] = None,
                 enable_prefix_cache: bool = True,
                 prefill_chunk: int = 32,
                 prefill_mode: str = "chunked",
                 prefill_impl: Optional[str] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0,
                 mesh=None, shard_policy: str = "serve"):
        if cfg.attn_kind != "mla":
            raise NotImplementedError("PagedMLAEngine requires an MLA model")
        if scheme == "auto" and platform is None:
            raise ValueError("scheme='auto' needs a PlatformPoint")
        if prefill_mode not in ("chunked", "per_request"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if mesh is not None and prefill_mode != "chunked":
            # the per-request path jits an UNSHARDED contiguous prefill and
            # scatters into the (replicated) pool — keep the A/B baseline
            # single-host rather than half-shard it
            raise NotImplementedError(
                "mesh serving requires prefill_mode='chunked' (the "
                "per-request A/B path is single-host)")
        if impl == "pallas":        # alias: the kernel impl IS Pallas
            impl = "kernel"
        if prefill_impl in ("auto", ""):
            prefill_impl = None
        if prefill_impl not in (None, "gather", "pallas"):
            raise ValueError(f"unknown prefill_impl {prefill_impl!r} "
                             "(None/'auto' | 'gather' | 'pallas')")
        if prefill_mode != "chunked" and enable_prefix_cache:
            # the per-request path recomputes + rewrites WHOLE prompts,
            # which would scatter over read-only shared blocks
            enable_prefix_cache = False
        self.cfg = cfg
        self.mla = cfg.mla_config()
        self.mesh = mesh
        self.shard_policy = shard_policy
        # DP shard count: the batch dim (token/table/length rows) shards
        # over ('pod', 'data'); 'model' shards heads and replicates the
        # pool (see steps.cache_pspecs paged=).
        self._dp = 1
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for a in ("pod", "data"):
                self._dp *= sizes.get(a, 1)
            # pad the slot count to a DP multiple so PS(dp) divides the
            # batch dim.  Free: the extra slots are ordinary empty slots
            # (length 0, null block table) until the scheduler admits
            # into them — and more slots never hurts admission.
            max_batch = -(-max_batch // self._dp) * self._dp
        # 'ru' streams the precomputed absorbed weights; attach them once
        # so every scheme's jitted step sees the same param tree.  A fixed
        # non-ru scheme never reads them — skip the compute and memory.
        self.params = mlalib.attach_absorbed_tree(params, self.mla) \
            if scheme in ("auto", "ru") else params
        if mesh is not None:
            from .steps import commit_params
            self.params = commit_params(self.params, cfg, mesh,
                                        shard_policy)
        self.compute_dtype = compute_dtype
        self.impl = impl
        self.scheme = scheme
        self.platform = platform
        self.block_size = block_size
        self.prefill_mode = prefill_mode
        # chunked-prefill attention path: None follows ``impl`` ('ref' ->
        # gather view, 'kernel' -> Pallas); 'gather'/'pallas' override it
        # so the prefill path can be A/B'd with the decode path pinned
        # (bench_serving's prefill-kernel row does exactly that).
        self.prefill_impl = prefill_impl
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sample_key = jax.random.PRNGKey(sample_seed)
        # max_blocks_per_req bounds the block-table WIDTH, i.e. the extent
        # every decode step scans per request — size it to the workload's
        # longest request, not the pool (nb = pool size would make each
        # step's cost scale with total pool capacity).
        self.sched = ContinuousScheduler(
            num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_blocks_per_req=max_blocks_per_req,
            enable_prefix_cache=enable_prefix_cache)
        self.pool = models.init_paged_cache(cfg, num_blocks, block_size,
                                            compute_dtype)
        if mesh is not None:
            # the pool replicates over every mesh axis (host-global block
            # tables may point any DP shard at any block); committing it
            # here keeps the donated in/out shardings copy-free.
            from jax.sharding import NamedSharding, PartitionSpec as PS
            self.pool = jax.device_put(
                self.pool, jax.tree.map(
                    lambda _: NamedSharding(mesh, PS()), self.pool))
        self.pending = np.zeros((max_batch,), np.int32)   # next token to feed
        self._decode_steps: Dict[str, object] = {}
        self._prefills: Dict[int, object] = {}     # per_request: cap -> fn
        self._chunk_steps: Dict[int, object] = {}  # chunked: chunk size -> fn
        self._copy_block = jax.jit(cachelib.copy_block_paged,
                                   donate_argnums=(0,))
        self._last_scheme: Optional[str] = None
        self.stats = EngineStats()

    # ------------------------------------------------------------ build ---

    def _decode_step(self, scheme: str):
        if scheme not in self._decode_steps:
            self._decode_steps[scheme] = make_paged_serve_step(
                self.cfg, self.mesh, compute_dtype=self.compute_dtype,
                impl=self.impl, scheme=scheme, policy=self.shard_policy)
        return self._decode_steps[scheme]

    def _prefill(self, cap: int):
        if cap not in self._prefills:
            # prefill attention runs in "MHA mode"; the scheme only matters
            # at decode, so one prefill serves every scheme.
            self._prefills[cap] = make_prefill_step(
                self.cfg, None, batch=1, capacity=cap,
                compute_dtype=self.compute_dtype, impl=self.impl)
        return self._prefills[cap]

    def _chunk_step(self, chunk: int):
        if chunk not in self._chunk_steps:
            impl = {"gather": "ref", "pallas": "kernel",
                    None: self.impl}[self.prefill_impl]
            # a FIXED engine scheme prefills with the same absorption
            # ordering (all schemes compute the same function); 'auto'
            # pins prefill to 'seq' so the per-step decode dispatch does
            # not multiply compiled chunk shapes, and 'naive' has no
            # latent chunk path.
            scheme = self.scheme if self.scheme in ("seq", "rc", "ru") \
                else "seq"
            self._chunk_steps[chunk] = make_chunked_prefill_step(
                self.cfg, self.mesh, compute_dtype=self.compute_dtype,
                impl=impl, scheme=scheme, policy=self.shard_policy)
        return self._chunk_steps[chunk]

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill step shapes built so far: bounded by the number
        of chunk sizes (chunked mode) instead of prompt-length buckets."""
        return len(self._chunk_steps) + len(self._prefills)

    def _pick_scheme(self) -> str:
        if self.scheme != "auto":
            self._last_scheme = self.scheme
            return self.scheme
        active = self.sched.active_slots
        cache_len = int(self.sched.lengths[active].max()) + 1 if active else 1
        s = auto_dispatch(self.mla, self.platform, cache_len=cache_len,
                          batch=max(len(active), 1),
                          paged_block=self.block_size,
                          dp_shards=self._dp)
        if self._last_scheme is not None and s != self._last_scheme:
            self.stats.scheme_switches += 1
        self._last_scheme = s
        return s

    # -------------------------------------------------------- sampling ----

    def _sample_tokens(self, rows, slots) -> Dict[int, int]:
        """Sample one token per slot; ``rows`` (len(slots), V) carries the
        logits row of each listed slot (still occupied by its request).

        temperature <= 0: one batched greedy argmax.  Otherwise
        temperature / top-k sampling, one batched device call: per-slot
        keys fold(fold(seed, rid), position), position = absolute index
        of the sampled token in the request's full sequence — invariant
        under recompute preemption (the folded prompt grows by exactly
        the generated tokens), so replay drains the same PRNG stream per
        request regardless of batch composition and reproduces the same
        output."""
        if self.temperature <= 0.0:
            arg = np.asarray(jnp.argmax(rows, axis=-1))
            return {s: int(arg[i]) for i, s in enumerate(slots)}
        if self.mesh is not None:
            # Gather the (few-KB) logits rows to the host and re-feed them
            # as a single-device array: under the pre-0.5 jax default
            # (threefry_partitionable=False) the SAME random op lowered
            # over a sharded operand draws DIFFERENT bits than unsharded,
            # so sampling straight from the vocab-sharded logits would
            # silently fork the PRNG stream from the single-host engine.
            # Host-side rows make the sampled stream topology-invariant.
            rows = jnp.asarray(np.asarray(rows))
        rids, poss = [], []
        for s in slots:
            req = self.sched.slots[s]
            rids.append(req.rid)
            poss.append(req.plen + len(req.tokens))
        toks = np.asarray(self._sample_fn(
            rows, jnp.asarray(rids, jnp.uint32),
            jnp.asarray(poss, jnp.uint32)))
        return {s: int(toks[i]) for i, s in enumerate(slots)}

    @functools.cached_property
    def _sample_fn(self):
        base, temp, top_k = self._sample_key, self.temperature, self.top_k

        def run(rows, rids, poss):
            keys = jax.vmap(lambda r, p: jax.random.fold_in(
                jax.random.fold_in(base, r), p))(rids, poss)
            rows = rows.astype(jnp.float32) / temp
            if top_k > 0:
                kth = jnp.sort(rows, axis=-1)[:, -top_k]
                rows = jnp.where(rows >= kth[:, None], rows, -jnp.inf)
            return jax.vmap(jax.random.categorical)(keys, rows)

        return jax.jit(run)

    # --------------------------------------------------------- prefill ----

    def _run_chunked_prefill(self, admitted, step_i: int) -> None:
        """Prefill every just-admitted request's UN-CACHED prompt suffix as
        a batch, ``prefill_chunk`` tokens per request per step, scattering
        latents straight into the pool.  Rows that exhaust their prompt in
        a chunk sample generated token #1 from that chunk's last-valid
        logits and register their blocks in the radix cache."""
        C = self.prefill_chunk
        step_fn = self._chunk_step(C)
        pending = dict(admitted)
        fill = {slot: req.n_cached for slot, req in admitted}
        while pending:
            tokens = np.zeros((self.sched.max_batch, C), np.int32)
            lens = np.zeros((self.sched.max_batch,), np.int32)
            nv = np.zeros((self.sched.max_batch,), np.int32)
            finishing = []
            for slot, req in list(pending.items()):
                start = fill[slot]
                take = min(req.plen - start, C)
                tokens[slot, :take] = req.prompt[start:start + take]
                lens[slot] = start
                nv[slot] = take
                fill[slot] = start + take
                if fill[slot] >= req.plen:
                    finishing.append((slot, req))
                    del pending[slot]
            logits, self.pool = step_fn(
                self.params, jnp.asarray(tokens), self.pool,
                jnp.asarray(self.sched.block_table), jnp.asarray(lens),
                jnp.asarray(nv))
            self.stats.prefill_tokens += int(nv.sum())
            self.stats.prefill_chunks += 1
            for slot, req in finishing:
                tok = self._sample_tokens(logits[slot][None], [slot])[slot]
                # register blocks only now — their latents are in the pool
                self.sched.commit_prefill(slot)
                if self.sched.record_prefill_sample(slot, tok, step_i) is None:
                    self.pending[slot] = tok

    def _run_per_request_prefill(self, admitted, step_i: int) -> None:
        """PR-1's path: contiguous per-request prefill (bucketed capacities
        to bound recompiles) + whole-block scatter into the pool.  Kept
        for A/B benchmarking; incompatible with prefix sharing."""
        for slot, req in admitted:
            cap = blocks_for(req.plen, self.block_size) * self.block_size
            logits, entries = self._prefill(cap)(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None])
            pages = jnp.asarray(self.sched.block_table[slot], jnp.int32)
            self.pool = scatter_prefill_to_paged(self.pool, entries, pages)
            self.stats.prefill_tokens += req.plen
            tok = self._sample_tokens(logits[0][None], [slot])[slot]
            self.sched.commit_prefill(slot)
            if self.sched.record_prefill_sample(slot, tok, step_i) is None:
                self.pending[slot] = tok

    # ------------------------------------------------------------- run ----

    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def step(self) -> None:
        """One scheduler tick: admit + batched prefill, then one batched
        decode step over all slots."""
        t0 = time.perf_counter()
        step_i = self.stats.steps
        was_decoding = self.sched.n_active > 0

        # grow running requests BEFORE admitting: otherwise a just-admitted
        # request could take the last blocks, get preempted immediately,
        # and throw away the prefill it just paid for.
        self.stats.preemptions += len(self.sched.ensure_step_capacity())
        for src, dst in self.sched.drain_cow():
            self.pool = self._copy_block(self.pool,
                                         jnp.asarray(src, jnp.int32),
                                         jnp.asarray(dst, jnp.int32))

        admitted = self.sched.try_admit(step_i)
        for _, req in admitted:
            self.stats.admissions += 1
            self.stats.prompt_tokens += req.plen
            if was_decoding:
                self.stats.mid_gen_admissions += 1
        if admitted:
            if self.prefill_mode == "chunked":
                self._run_chunked_prefill(admitted, step_i)
            else:
                self._run_per_request_prefill(admitted, step_i)

        active = self.sched.active_slots
        if active:
            scheme = self._pick_scheme()
            self.stats.schemes_used[scheme] = \
                self.stats.schemes_used.get(scheme, 0) + 1
            step_fn = self._decode_step(scheme)
            logits, self.pool = step_fn(
                self.params, jnp.asarray(self.pending),
                self.pool, jnp.asarray(self.sched.block_table),
                jnp.asarray(self.sched.lengths))
            picks = self._sample_tokens(logits[jnp.asarray(active)], active)
            self.sched.advance(picks, step_i)
            for s, t in picks.items():
                self.pending[s] = t
            self.stats.decode_tokens += len(active)

        u = self.sched.utilization()
        self.stats.util_valid_sum += u["valid_frac"]
        self.stats.util_pool_sum += u["pool_frac"]
        self.stats.util_samples += 1
        self.stats.steps += 1
        self.stats.wall += time.perf_counter() - t0

    def run(self, requests: List[Request], *, max_steps: int = 100_000,
            log_every: int = 0, log=print) -> Dict[str, float]:
        """Drive a request stream to completion.  ``req.arrival`` is the
        step index at which a request joins the waiting queue (Poisson
        arrivals in the example driver)."""
        todo = sorted(requests, key=lambda r: r.arrival)
        i = 0
        while not (i >= len(todo) and self.sched.all_done):
            while i < len(todo) and todo[i].arrival <= self.stats.steps:
                self.submit(todo[i])
                i += 1
            self.step()
            if log_every and self.stats.steps % log_every == 0:
                u = self.sched.utilization()
                log(f"[engine] step {self.stats.steps}: "
                    f"active={self.sched.n_active} "
                    f"waiting={len(self.sched.waiting)} "
                    f"done={len(self.sched.finished)} "
                    f"util={u['valid_frac']:.2f} "
                    f"pool={u['pool_frac']:.2f} "
                    f"scheme={self._last_scheme}")
            if self.stats.steps >= max_steps:
                raise RuntimeError(f"did not drain in {max_steps} steps")
        return self.summary()

    def summary(self) -> Dict[str, float]:
        """Engine stats + prefix-cache stats + allocator totals."""
        out = self.stats.summary()
        out.update(self.sched.prefix.summary())
        out["total_blocks_allocated"] = float(
            self.sched.allocator.total_allocs)
        out["prefill_compiles"] = float(self.prefill_compiles)
        return out
