from .steps import (TrainStepConfig, lm_loss, make_chunked_prefill_step,
                    make_paged_serve_step, make_prefill_step,
                    make_serve_step, make_train_step, cache_pspecs,
                    scatter_prefill_to_paged)
from .loop import LoopConfig, SimulatedFailure, TrainLoop
from .scheduler import (BlockAllocator, ContinuousScheduler, Request,
                        blocks_for)
from .prefix_cache import PrefixCache, PrefixCacheStats
from .engine import EngineStats, PagedMLAEngine
