from .steps import (TrainStepConfig, lm_loss, make_prefill_step,
                    make_serve_step, make_train_step, cache_pspecs)
from .loop import LoopConfig, SimulatedFailure, TrainLoop
