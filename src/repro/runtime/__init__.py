from .steps import (TrainStepConfig, lm_loss, make_chunked_prefill_step,
                    make_paged_sample_step, make_paged_serve_step,
                    make_prefill_step, make_serve_step, make_train_step,
                    make_verify_step, cache_pspecs, scatter_prefill_to_paged)
from .loop import LoopConfig, SimulatedFailure, TrainLoop, drive
from .scheduler import (BlockAllocator, ContinuousScheduler, Request,
                        blocks_for)
from .prefix_cache import PrefixCache, PrefixCacheStats
from .sampling import SamplingParams
from .spec import (accept_length, identity_draft, parse_draft_spec,
                   shallow_draft)
from .engine import AsyncPagedMLAEngine, EngineStats, PagedMLAEngine
