"""Per-request sampling / generation parameters — the request API.

``SamplingParams`` consolidates the knobs that used to be scattered
across ``Request`` fields (``max_new``, ``stop``), engine-constructor
defaults (``temperature`` / ``top_k`` / ``sample_seed``) and ad-hoc HTTP
body parsing in ``launch.server``:

    req = Request(rid=0, prompt=ids,
                  sampling=SamplingParams(max_tokens=64, n=4,
                                          stop=((13,),)))

``n > 1`` requests n-way PARALLEL SAMPLING: the prompt prefills once,
then the sequence forks n ways through ``BlockAllocator.fork`` +
copy-on-write on the partial tail block (runtime.scheduler.fork_group).
Each fork samples with its own fold(rid + i, position) key stream, so
the group is token-identical to n independent requests submitted with
consecutive rids — the caller reserves the rid range
``[rid, rid + n)``.

``temperature`` / ``top_k`` / ``seed`` default to None = inherit the
engine's configured values.  A non-None value must MATCH the engine
configuration: the async engine folds sampling into the compiled step
(temperature / top-k / seed are baked into the jitted program), so a
per-request override would mint a new compiled-step variant per value —
exactly what the hot-path auditor's unchanged-by-construction check
forbids.  The engine validates this at ``submit`` and raises a clear
``ValueError`` instead of silently retracing.

The legacy ``Request(prompt, max_new, stop=...)`` constructor keeps
working through a deprecation shim (scheduler.Request.__post_init__
builds the equivalent SamplingParams and warns); it is pinned by
tests/test_multiturn_fork.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Immutable per-request generation spec.

    max_tokens   generation budget (>= 1)
    temperature  None = engine default; 0 = greedy; > 0 seeded sampling
    top_k        None = engine default; 0 = full vocab
    seed         None = engine default sampling seed
    stop         stop token-id sequences (generation ends when the output
                 suffix matches one; the match is hidden from the output)
    n            parallel samples: prefill once, fork the sequence n ways
                 (rids [rid, rid + n) are consumed by the group)
    """

    max_tokens: int = 16
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    seed: Optional[int] = None
    stop: Tuple[Tuple[int, ...], ...] = ()
    n: int = 1

    def __post_init__(self):
        # normalize stop to hashable nested tuples so params stay frozen
        # whether built from JSON lists or tuples
        object.__setattr__(
            self, "stop",
            tuple(tuple(int(t) for t in s) for s in self.stop))

    def validate(self) -> "SamplingParams":
        """Raise ValueError on out-of-range fields; returns self so the
        frontend can chain ``SamplingParams(...).validate()``."""
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")
        if self.max_tokens < 1:
            raise ValueError(
                f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.temperature is not None and self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.top_k is not None and self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        for s in self.stop:
            if not s:
                raise ValueError("empty stop sequence")
        return self

    @classmethod
    def from_legacy(cls, max_new: int,
                    stop: Optional[Sequence[Sequence[int]]] = None
                    ) -> "SamplingParams":
        """The deprecation shim target for ``Request(prompt, max_new,
        stop=...)`` call sites."""
        return cls(max_tokens=int(max_new),
                   stop=tuple(tuple(int(t) for t in s)
                              for s in (stop or ())))
