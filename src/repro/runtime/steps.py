"""Jitted step builders: train_step / prefill_step / serve_step.

These are the three entry points the dry-run lowers for every (arch x
shape) cell.  All builders are mesh-aware: given (mesh, rules) they attach
NamedShardings for params, optimizer state, inputs and decode caches, and
jit with donation so cache/opt-state updates are in-place on device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from .. import models
from ..models.common import ModelConfig
from ..nn import sharding as shd
from ..optim import AdamWConfig, adamw_update


# ------------------------------------------------------------------ loss ---


def _ce_terms(logits, labels):
    """Σ masked CE and Σ mask over a (B, L, V) block (f32)."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * mask), jnp.sum(mask)


def lm_loss(params, cfg: ModelConfig, tokens, labels, *, embeds=None,
            compute_dtype=jnp.bfloat16, impl: str = "ref", mesh=None,
            scheme: str = "seq", loss_chunk: int = 0) -> Tuple[jax.Array, Dict]:
    """Causal-LM cross entropy (+ MoE aux losses). labels = next tokens,
    -100 entries are masked.  With a modality prefix (embeds: (B,P,D)),
    only the text positions are scored.

    ``loss_chunk > 0``: vocab-chunked CE — the (B, L, V) logits tensor is
    never materialized; the final hidden states are unembedded and scored
    ``loss_chunk`` positions at a time under a rematerialized scan (peak
    live logits = B x loss_chunk x V).  Required at gemma3 scale
    (V=262144) and a net memory win for every 4k+ train shape."""
    if loss_chunk:
        x, aux = models.forward(params, cfg, tokens, embeds=embeds,
                                compute_dtype=compute_dtype, impl=impl,
                                mesh=mesh, scheme=scheme, return_hidden=True)
        P = x.shape[1] - labels.shape[1]
        if P > 0:
            x = x[:, P:]
        B, L, D = x.shape
        c = min(loss_chunk, L)
        pad = -L % c
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        n = x.shape[1] // c
        xs = x.reshape(B, n, c, D).swapaxes(0, 1)
        ls = labels.reshape(B, n, c).swapaxes(0, 1)

        def body(carry, inp):
            xc, lc = inp
            from ..nn import layers as nl
            logits_c = nl.unembed(params_embed, xc)
            s, m = _ce_terms(logits_c, lc)
            return (carry[0] + s, carry[1] + m), ()

        params_embed = params["embed"]
        body = jax.checkpoint(body)
        (ce_sum, n_tok), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                          (xs, ls))
        ce = ce_sum / jnp.maximum(n_tok, 1.0)
    else:
        logits, aux = models.forward(params, cfg, tokens, embeds=embeds,
                                     compute_dtype=compute_dtype, impl=impl,
                                     mesh=mesh, scheme=scheme)
        P = logits.shape[1] - labels.shape[1]
        if P > 0:
            logits = logits[:, P:]
        ce_sum, n_tok = _ce_terms(logits, labels)
        ce = ce_sum / jnp.maximum(n_tok, 1.0)
    loss = ce
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux["balance"] + 1e-3 * aux["z_loss"]
    metrics = {"loss": loss, "ce": ce, **{k: jnp.asarray(v) for k, v in aux.items()}}
    return loss, metrics


# ------------------------------------------------------------ train step ---


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1           # grad accumulation
    compute_dtype: Any = jnp.bfloat16
    impl: str = "ref"
    scheme: str = "seq"
    loss_chunk: int = 0             # vocab-chunked CE (0 = dense logits)
    remat_policy: str = "default"


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], opt_cfg: AdamWConfig,
                    ts: TrainStepConfig = TrainStepConfig(),
                    policy: str = "train"):
    """Returns (step_fn, shardings) — step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics).  batch: {tokens, labels[, embeds]}.
    policy='dp' replicates weights (small models; see nn.sharding)."""
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg) if mesh is not None else None
    defs = models.model_defs(cfg)

    def grads_of(params, batch):
        fn = functools.partial(lm_loss, cfg=cfg,
                               compute_dtype=ts.compute_dtype, impl=ts.impl,
                               mesh=mesh, scheme=ts.scheme,
                               loss_chunk=ts.loss_chunk)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: fn(p, tokens=batch["tokens"], labels=batch["labels"],
                         embeds=batch.get("embeds")), has_aux=True)(params)
        return grads, metrics

    def step(params, opt_state, batch):
        if ts.microbatches > 1:
            mb = ts.microbatches
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

            def accum(carry, mbatch):
                g_sum, m_sum = carry
                g, m = grads_of(params, mbatch)
                return (jax.tree.map(jnp.add, g_sum, g),
                        jax.tree.map(jnp.add, m_sum, m)), ()

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"loss": 0.0, "ce": 0.0, "balance": 0.0, "z_loss": 0.0,
                       "dropped_frac": 0.0}
            zeros_m = jax.tree.map(jnp.float32, zeros_m)
            (g, m), _ = jax.lax.scan(accum, (zeros_g, zeros_m), split)
            grads = jax.tree.map(lambda x: x / mb, g)
            metrics = jax.tree.map(lambda x: x / mb, m)
        else:
            grads, metrics = grads_of(params, batch)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics}

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1)), None

    pspecs = shd.param_specs(defs, rules)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_shard = {"step": NamedSharding(mesh, PS()), "mu": p_shard, "nu": p_shard}
    dp = rules["batch"]
    batch_shard = {
        "tokens": NamedSharding(mesh, PS(dp, None)),
        "labels": NamedSharding(mesh, PS(dp, None)),
    }
    if cfg.family in ("vlm", "encdec"):    # stub modality prefix
        batch_shard["embeds"] = NamedSharding(mesh, PS(dp, None, None))
    step_fn = jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, batch_shard),
        out_shardings=(p_shard, opt_shard, None),
        donate_argnums=(0, 1),
    )
    return step_fn, {"params": p_shard, "opt": opt_shard, "batch": batch_shard}


# ------------------------------------------------------- serve/prefill -----


def cache_pspecs(cache_tree, rules, *, family: str = "dense",
                 batch_spec=None, seq_spec=None, seq_len: int = 0,
                 paged: bool = False):
    """PartitionSpec tree for a decode cache.

    Path-aware: leaves named 'kv'/'k'/'v' carry a sequence dim right after
    the batch dim; SSM/conv/xLSTM states do not.  Stacked (scan) caches
    ('period' subtree; all of whisper's) have a leading layer dim.

    batch_spec — mesh axes for the batch dim (None to replicate, e.g.
                 batch=1 long-decode).
    seq_spec   — mesh axis for the cache SEQ dim (distributed flash-decode:
                 each shard scores its cache span; GSPMD combines the
                 partial softmax with small all-reduces).  Applied only to
                 leaves whose seq dim equals ``seq_len`` (whisper's cross
                 cache keeps its n_frames dim whole).
    paged      — the tree is a PAGED latent block pool (init_paged_cache):
                 leaves are (num_blocks, block_size, D) — there is no batch
                 dim to shard.  The pool replicates over 'model' exactly
                 like the contiguous latent cache (the MQA structure of
                 absorbed MLA: head shards re-read the same compact pool)
                 AND over the DP axes, because per-request block tables map
                 any slot to any pool block — a DP shard of the batch may
                 read/write anywhere in the pool.  The compact latent
                 layout is what makes full replication affordable (the
                 paper's ~16x bytes/token saving); what DP buys is
                 per-device TRAFFIC, not capacity: each device only
                 streams the blocks its local batch rows reference (see
                 hwmodel.attention_costs.mla_decode_cost(dp_shards=)).
    """
    from jax.tree_util import DictKey, tree_map_with_path
    if paged:
        return jax.tree.map(lambda _: PS(), cache_tree)
    seq_leaves = {"kv", "k", "v", "ckv", "krope"}

    def spec_of(path, a):
        keys = [p.key for p in path if isinstance(p, DictKey)]
        stacked = (keys and keys[0] in ("period", "self", "cross")) \
            or family == "encdec"
        b_ax = 1 if stacked else 0
        nd = a.ndim
        axes = [None] * nd
        if nd > b_ax:
            axes[b_ax] = batch_spec
        if seq_spec and keys and keys[-1] in seq_leaves and nd > b_ax + 1 \
                and (not seq_len or a.shape[b_ax + 1] == seq_len):
            axes[b_ax + 1] = seq_spec
        return PS(*axes)

    return tree_map_with_path(spec_of, cache_tree)


def _dp_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ("pod", "data"):
        n *= sizes.get(a, 1)
    return n


def _batch_spec(mesh: Mesh, rules, batch: int):
    """DP spec for the batch dim, or None when not divisible (batch=1)."""
    return rules["batch"] if batch % _dp_size(mesh) == 0 else None


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh],
                      *, batch: int, capacity: int, compute_dtype=jnp.bfloat16,
                      impl: str = "ref", scheme: str = "seq",
                      policy: str = "serve", params_template=None):
    """Returns jitted fn(params, tokens[, embeds]) -> (last_logits, cache).

    ``params_template``: pass the ACTUAL params tree when it carries
    engine-attached ``w_absorb`` leaves (scheme 'ru'; see
    :func:`paged_param_shardings`) so the mesh in_shardings match it."""
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg) if mesh is not None else None

    def run(params, tokens, embeds=None):
        return models.prefill(params, cfg, tokens, embeds=embeds,
                              capacity=capacity, compute_dtype=compute_dtype,
                              impl=impl, mesh=mesh, scheme=scheme,
                              shard_mode=policy)

    if mesh is None:
        return jax.jit(run)
    defs = models.model_defs(cfg)
    p_shard = paged_param_shardings(params_template, cfg, mesh, rules) \
        if params_template is not None else \
        jax.tree.map(lambda s: NamedSharding(mesh, s),
                     shd.param_specs(defs, rules))
    dp = _batch_spec(mesh, rules, batch)
    in_sh = [p_shard, NamedSharding(mesh, PS(dp, None))]
    if cfg.family in ("vlm", "encdec"):
        in_sh.append(NamedSharding(mesh, PS(dp, None, None)))
    # cache out_shardings must match what make_serve_step expects, so the
    # prefill->decode handoff needs no resharding copy.
    cache_t = jax.eval_shape(
        lambda: models.init_cache(cfg, batch, capacity, compute_dtype))
    cspecs = cache_pspecs(cache_t, rules, family=cfg.family, batch_spec=dp,
                          seq_spec=rules.get("cache_seq"), seq_len=capacity)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
    return jax.jit(run, in_shardings=tuple(in_sh),
                   out_shardings=(None, c_shard))


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh],
                    *, compute_dtype=jnp.bfloat16, impl: str = "ref",
                    scheme: str = "seq", shard_cache_seq: bool = False,
                    policy: str = "serve", params_template=None):
    """One-token decode step:  fn(params, token, cache, index) ->
    (logits, cache).  Cache is donated (updated in place on device).

    With a mesh this returns ``jit_with_cache(cache_template, batch) ->
    step_fn`` (the cache pytree's shardings depend on its structure);
    ``params_template`` as in :func:`make_prefill_step`.

    policy='serve_2dtp' additionally shards the cache SEQ dim over 'model'
    (rules['cache_seq']) — distributed flash-decode; 'shard_cache_seq'
    forces seq sharding over 'data' for batch=1 long decode."""
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg) if mesh is not None else None

    def run(params, token, cache, index):
        return models.decode_step(params, cfg, token, cache, index,
                                  compute_dtype=compute_dtype, impl=impl,
                                  mesh=mesh, scheme=scheme, shard_mode=policy)

    if mesh is None:
        return jax.jit(run, donate_argnums=(2,))

    defs = models.model_defs(cfg)
    p_shard = paged_param_shardings(params_template, cfg, mesh, rules) \
        if params_template is not None else \
        jax.tree.map(lambda s: NamedSharding(mesh, s),
                     shd.param_specs(defs, rules))

    def jit_with_cache(cache_template, batch: int, seq_len: int = 0):
        dp = _batch_spec(mesh, rules, batch)
        seq_spec = rules.get("cache_seq")
        if shard_cache_seq and dp is None and seq_spec is None:
            seq_spec = "data"
        cspecs = cache_pspecs(cache_template, rules, family=cfg.family,
                              batch_spec=dp, seq_spec=seq_spec,
                              seq_len=seq_len)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
        return jax.jit(
            run,
            in_shardings=(p_shard, NamedSharding(mesh, PS(dp)), c_shard,
                          NamedSharding(mesh, PS())),
            out_shardings=(None, c_shard),
            donate_argnums=(2,),
        )

    return jit_with_cache


# ------------------------------------------------------- paged serving -----


def commit_params(params, cfg: ModelConfig, mesh: Mesh,
                  policy: str = "serve"):
    """Commit a (possibly absorb-carrying) param tree to ``policy``'s
    layout once, so jitted steps that leave the params slot unspecified
    inherit the placement with no per-call resharding.  The single source
    of truth for the engine and the serve CLI."""
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg)
    return jax.device_put(params,
                          paged_param_shardings(params, cfg, mesh, rules))


def commit_draft_params(draft_params, draft_cfg: ModelConfig, mesh: Mesh,
                        policy: str = "serve", *, target_host=None,
                        target_committed=None):
    """Commit a DRAFT model's param tree to the mesh, reusing the
    target's already-committed device buffers for every leaf the draft
    shares (by object identity) with the target's HOST tree.

    Shallow self-speculation drafts (runtime.spec.shallow_draft) alias
    the target's embed / final norm / first-N layer dicts by reference;
    committing them independently would duplicate those weights on every
    device — the (vocab x d_model) embedding twice per replica.  Reuse is
    sharding-sound because identically-named weights take identical rule
    specs under the same (mesh, policy).  Leaves the draft owns privately
    (the re-stacked scan periods) are device_put under the draft's own
    rules like :func:`commit_params` would."""
    rules = shd.make_rules(mesh, mode=policy, cfg=draft_cfg)
    shardings = paged_param_shardings(draft_params, draft_cfg, mesh, rules)
    reuse = {}
    if target_host is not None and target_committed is not None:
        from jax.tree_util import tree_flatten_with_path
        for path, leaf in tree_flatten_with_path(target_host)[0]:
            node = target_committed
            try:
                for key in path:
                    node = node[key.key]
            except (KeyError, TypeError, AttributeError):
                continue        # structure diverged: just re-commit
            reuse[id(leaf)] = node

    def commit(leaf, sh):
        return reuse[id(leaf)] if id(leaf) in reuse \
            else jax.device_put(leaf, sh)

    return jax.tree.map(commit, draft_params, shardings)


def paged_param_shardings(params, cfg: ModelConfig, mesh: Mesh, rules):
    """NamedSharding tree matching ``params``' ACTUAL structure.

    The engine attaches precomputed ``w_absorb`` leaves (core.mla
    .attach_absorbed_tree) that model_defs does not know about, so the
    defs-driven spec tree cannot be handed to device_put directly.  Walk
    the params tree: defs-declared weights take their rule spec, absorbed
    leaves shard over heads ('model') like the factors they absorb."""
    specs = shd.param_specs(models.model_defs(cfg), rules)
    heads = rules.get("heads")

    def graft(spec_node, param_node):
        if isinstance(param_node, dict):
            out = {}
            for k, v in param_node.items():
                if k == "w_absorb":
                    # (H, Q, K) or stacked (layers, H, Q, K)
                    lead = (None,) * (v.ndim - 3)
                    out[k] = PS(*lead, heads, None, None)
                else:
                    out[k] = graft(spec_node[k], v)
            return out
        return spec_node

    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        graft(specs, params))


def _paged_pool_shardings(cfg: ModelConfig, mesh: Mesh, rules,
                          compute_dtype, cache_dtype=None):
    """Replicated NamedSharding tree for the paged latent pool.  Only the
    tree STRUCTURE matters (every leaf is PS()), so a dummy-sized
    eval_shape stands in for the real pool.  ``cache_dtype`` must match
    the engine's pool (quantized pools carry extra scale leaves)."""
    pool_t = jax.eval_shape(
        lambda: models.init_paged_cache(cfg, 2, 1, compute_dtype,
                                        cache_dtype=cache_dtype))
    cspecs = cache_pspecs(pool_t, rules, family=cfg.family, paged=True)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)


def _tag_obs(fn, *, kind: str, scheme: str, impl: str):
    """Annotate a jitted step with its dispatch identity (step kind,
    attention scheme, attention impl) so telemetry and debugging tools
    can label spans / drift rows from the function object itself instead
    of threading extra arguments.  Plain setattr: jitted callables carry
    attributes fine and ``.lower()`` (the hot-path auditor's entry) is
    unaffected."""
    fn.obs_kind = kind
    fn.obs_scheme = scheme
    fn.obs_impl = impl
    return fn


def make_paged_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                          *, compute_dtype=jnp.bfloat16, impl: str = "ref",
                          scheme: str = "seq", policy: str = "serve",
                          cache_dtype: Optional[str] = None):
    """Continuous-batching decode step over the paged latent pool:

        fn(params, token (B,), pool_tree, block_tables (B, nb),
           lengths (B,)) -> (logits (B, V), pool_tree)

    ``lengths`` is ragged per request; inactive slots carry length 0 and a
    null block table (their logits are garbage the scheduler discards).
    The pool is donated — in-place scatter of the B new latent entries.

    With a mesh the batch dim — token, block tables, lengths — shards over
    the DP axes (``rules['batch']``; B must be a DP multiple: the engine
    pads ``max_batch`` up, which is free because inactive rows carry
    length 0 and null tables) while the pool replicates over EVERY mesh
    axis (see :func:`cache_pspecs` ``paged=``): block tables are
    host-global, so any DP shard may address any pool block, and the
    compact latent layout keeps n_model x n_dp replicas affordable —
    per-device cache TRAFFIC still shrinks by the DP factor because each
    device only streams the blocks its local rows reference.
    ``impl='kernel'``/'pallas' routes through the shard_map kernel path
    (kernels.ops.mla_decode_paged_attention: batch over DP, heads over
    'model', pool replicated); 'ref' lets GSPMD partition the gather
    reference.  ``policy`` picks the weight-sharding rules
    (nn.sharding.make_rules mode; params should be device_put with
    :func:`paged_param_shardings` for these same rules).
    """
    if cfg.attn_kind != "mla":
        raise NotImplementedError("paged serving requires attn_kind='mla'")

    def run(params, token, pool, block_tables, lengths):
        return models.decode_step(params, cfg, token, pool, None,
                                  compute_dtype=compute_dtype, impl=impl,
                                  mesh=mesh, scheme=scheme,
                                  shard_mode=policy,
                                  block_tables=block_tables,
                                  lengths=lengths)

    if mesh is None:
        return _tag_obs(jax.jit(run, donate_argnums=(2,)),
                        kind="decode", scheme=scheme, impl=impl)
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg)
    dp = rules["batch"]
    pool_shard = _paged_pool_shardings(cfg, mesh, rules, compute_dtype,
                                       cache_dtype)
    return _tag_obs(jax.jit(
        run,
        # params slot is UNSPECIFIED: committed shardings (device_put via
        # paged_param_shardings) propagate, and the same jitted step
        # serves trees with or without attached w_absorb leaves.
        in_shardings=(None, NamedSharding(mesh, PS(dp)), pool_shard,
                      NamedSharding(mesh, PS(dp, None)),
                      NamedSharding(mesh, PS(dp))),
        out_shardings=(None, pool_shard),
        donate_argnums=(2,),
    ), kind="decode", scheme=scheme, impl=impl)


def make_paged_sample_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                           *, compute_dtype=jnp.bfloat16, impl: str = "ref",
                           scheme: str = "seq", policy: str = "serve",
                           cache_dtype: Optional[str] = None,
                           temperature: float = 0.0, top_k: int = 0,
                           sample_seed: int = 0):
    """Decode step with sampling FOLDED INTO the compiled program:

        fn(params, token (B,), pool_tree, block_tables (B, nb),
           lengths (B,), rids (B,) u32, poss (B,) u32)
          -> (next_token (B,) int32, pool_tree)

    The double-buffered engine's step: only the (B,) sampled tokens ever
    sync back to the host — the (B, V) logits stay on device — so the
    host can prepare tick N+1 while the device still runs tick N and the
    eventual host read is one small transfer, not a vocab-wide one.

    Sampling matches the host path (``PagedMLAEngine._sample_fn``)
    bit-for-bit: greedy argmax at ``temperature <= 0``, else temperature /
    top-k categorical under fold(fold(seed, rid), position) keys — rows
    are independent, so sampling every slot (inactive rows draw garbage
    the scheduler discards) emits the same token per live row as the host
    path's gathered subset.  Under a mesh the logits (and the rid /
    position rows) are constrained to full replication before any random
    op: under the pre-0.5 jax default (threefry_partitionable=False) a
    random op lowered over a sharded operand draws different bits than
    unsharded, and replication keeps the stream topology-invariant —
    the same reason the host path gathers rows before sampling.
    """
    if cfg.attn_kind != "mla":
        raise NotImplementedError("paged serving requires attn_kind='mla'")
    base = jax.random.PRNGKey(sample_seed)

    def sample(logits, rids, poss):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if mesh is not None:
            repl = lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, PS()))
            logits, rids, poss = repl(logits), repl(rids), repl(poss)
        keys = jax.vmap(lambda r, p: jax.random.fold_in(
            jax.random.fold_in(base, r), p))(rids, poss)
        rows = logits.astype(jnp.float32) / temperature
        if top_k > 0:
            kth = jnp.sort(rows, axis=-1)[:, -top_k]
            rows = jnp.where(rows >= kth[:, None], rows, -jnp.inf)
        return jax.vmap(jax.random.categorical)(keys, rows).astype(jnp.int32)

    def run(params, token, pool, block_tables, lengths, rids, poss):
        logits, pool = models.decode_step(params, cfg, token, pool, None,
                                          compute_dtype=compute_dtype,
                                          impl=impl, mesh=mesh, scheme=scheme,
                                          shard_mode=policy,
                                          block_tables=block_tables,
                                          lengths=lengths)
        return sample(logits, rids, poss), pool

    if mesh is None:
        return _tag_obs(jax.jit(run, donate_argnums=(2,)),
                        kind="decode", scheme=scheme, impl=impl)
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg)
    dp = rules["batch"]
    pool_shard = _paged_pool_shardings(cfg, mesh, rules, compute_dtype,
                                       cache_dtype)
    return _tag_obs(jax.jit(
        run,
        in_shardings=(None, NamedSharding(mesh, PS(dp)), pool_shard,
                      NamedSharding(mesh, PS(dp, None)),
                      NamedSharding(mesh, PS(dp)),
                      NamedSharding(mesh, PS(dp)),
                      NamedSharding(mesh, PS(dp))),
        # tokens replicate (the host reads all B of them); pool stays put
        out_shardings=(NamedSharding(mesh, PS()), pool_shard),
        donate_argnums=(2,),
    ), kind="decode", scheme=scheme, impl=impl)


def make_chunked_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                              *, compute_dtype=jnp.bfloat16,
                              impl: str = "ref", scheme: str = "seq",
                              policy: str = "serve",
                              cache_dtype: Optional[str] = None):
    """Batched chunked prefill straight into the paged pool:

        fn(params, tokens (B, C), pool_tree, block_tables (B, nb),
           lengths (B,), n_valid (B,)) -> (last_valid_logits (B, V),
                                           pool_tree)

    Row b prefills its request's next ``n_valid[b]`` prompt tokens at
    absolute positions lengths[b].., attending the already-resident
    prefix (prefix-cache hits + earlier chunks) THROUGH the block table;
    idle rows carry n_valid 0.  The pool is donated (in-place scatter).

    ``impl`` selects the chunk-attention path: 'ref' runs the gather
    reference (materializes the (B, S) block-table view each chunk);
    'kernel' / 'pallas' runs the fused paged Pallas prefill kernel
    (kernels.mla_prefill) which walks the block table in place.
    ``scheme`` picks the query-absorption ordering (seq/rc/ru — all
    compute the same function; 'naive' falls back to the gather view).

    With a mesh the batch dim — tokens, block tables, lengths, n_valid —
    shards over the DP axes and the pool replicates over every axis,
    exactly like :func:`make_paged_serve_step` (idle rows make the DP
    padding free); ``impl='kernel'``/'pallas' routes through the
    shard_map prefill-kernel path in kernels.ops.

    This replaces the per-request contiguous prefill + scatter detour:
    one compiled step shape per (batch, chunk) pair — NOT one retrace per
    prompt length — and every admitted request prefills as a batch.
    """
    if cfg.attn_kind != "mla":
        raise NotImplementedError("paged serving requires attn_kind='mla'")

    def run(params, tokens, pool, block_tables, lengths, n_valid):
        return models.prefill_chunk_paged(params, cfg, tokens, pool,
                                          block_tables, lengths, n_valid,
                                          compute_dtype=compute_dtype,
                                          impl=impl, mesh=mesh,
                                          scheme=scheme, shard_mode=policy)

    if mesh is None:
        return _tag_obs(jax.jit(run, donate_argnums=(2,)),
                        kind="prefill", scheme=scheme, impl=impl)
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg)
    dp = rules["batch"]
    pool_shard = _paged_pool_shardings(cfg, mesh, rules, compute_dtype,
                                       cache_dtype)
    return _tag_obs(jax.jit(
        run,
        in_shardings=(None, NamedSharding(mesh, PS(dp, None)), pool_shard,
                      NamedSharding(mesh, PS(dp, None)),
                      NamedSharding(mesh, PS(dp)),
                      NamedSharding(mesh, PS(dp))),
        out_shardings=(None, pool_shard),
        donate_argnums=(2,),
    ), kind="prefill", scheme=scheme, impl=impl)


def make_verify_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                     *, compute_dtype=jnp.bfloat16, impl: str = "ref",
                     scheme: str = "seq", policy: str = "serve",
                     cache_dtype: Optional[str] = None):
    """Speculative-decode verify step over the paged latent pool:

        fn(params, tokens (B, C), pool_tree, block_tables (B, nb),
           lengths (B,), n_valid (B,)) -> (logits (B, C, V), pool_tree)

    The multi-token sibling of :func:`make_paged_serve_step` built on the
    chunked-prefill machinery with C = k + 1: row b scores its last
    sampled token plus ``n_valid[b] - 1`` draft tokens against its
    resident latent prefix in ONE batched forward — the prefix streams
    from HBM once for all k + 1 query positions instead of once per token
    (the amortization hwmodel.attention_costs.mla_verify_cost prices).
    Unlike the prefill step it returns logits for EVERY position, so the
    engine can sample the target's token at each verify position and
    accept/reject drafts host-side.  ``impl``/``scheme``/``mesh`` behave
    exactly as in :func:`make_chunked_prefill_step` (same shardings:
    batch rows over DP, heads over 'model', pool replicated + donated);
    the (B, C, V) logits are left unspecified for GSPMD — the engine
    host-gathers the few rows it samples anyway.
    """
    if cfg.attn_kind != "mla":
        raise NotImplementedError("paged serving requires attn_kind='mla'")

    def run(params, tokens, pool, block_tables, lengths, n_valid):
        return models.verify_chunk_paged(params, cfg, tokens, pool,
                                         block_tables, lengths, n_valid,
                                         compute_dtype=compute_dtype,
                                         impl=impl, mesh=mesh,
                                         scheme=scheme, shard_mode=policy)

    if mesh is None:
        return _tag_obs(jax.jit(run, donate_argnums=(2,)),
                        kind="verify", scheme=scheme, impl=impl)
    rules = shd.make_rules(mesh, mode=policy, cfg=cfg)
    dp = rules["batch"]
    pool_shard = _paged_pool_shardings(cfg, mesh, rules, compute_dtype,
                                       cache_dtype)
    return _tag_obs(jax.jit(
        run,
        in_shardings=(None, NamedSharding(mesh, PS(dp, None)), pool_shard,
                      NamedSharding(mesh, PS(dp, None)),
                      NamedSharding(mesh, PS(dp)),
                      NamedSharding(mesh, PS(dp))),
        out_shardings=(None, pool_shard),
        donate_argnums=(2,),
    ), kind="verify", scheme=scheme, impl=impl)


def _scatter_entries(pool_leaf, contig_leaf, pages, block_size: int):
    """One cache leaf of the prefill->paged handoff.  contig_leaf:
    (1, cap, D) or stacked (layers, 1, cap, D); pages: (n_pg,) pool block
    ids (null-padded — garbage written to block 0 is never read)."""
    from ..core import cache as cachelib
    cap = contig_leaf.shape[-2]
    n_pg = -(-cap // block_size)
    pad = n_pg * block_size - cap
    squeezed = contig_leaf[:, 0] if contig_leaf.ndim == 4 else contig_leaf[0]
    if pad:
        width = [(0, 0)] * squeezed.ndim
        width[-2] = (0, pad)
        squeezed = jnp.pad(squeezed, width)
    D = squeezed.shape[-1]
    if squeezed.ndim == 3:      # stacked (layers, cap_pad, D)
        vals = squeezed.reshape(squeezed.shape[0], n_pg, block_size, D)
    else:
        vals = squeezed.reshape(n_pg, block_size, D)
    return cachelib.write_blocks_paged(pool_leaf, pages[:n_pg], vals)


def _tree_has_quantized_pool(tree) -> bool:
    if isinstance(tree, dict):
        return "ckv_scale" in tree \
            or any(_tree_has_quantized_pool(v) for v in tree.values())
    return False


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_prefill_to_paged(pool_tree, entries_tree, pages):
    """Scatter one request's contiguous prefill cache (batch dim 1) into
    the paged pool at its allocated ``pages`` ((max_blocks,) int32, padded
    with the null block).  Whole blocks are written; the tail garbage
    inside the last block is masked at attention time.

    Quantized pools are not supported on this legacy per-request path (the
    contiguous prefill cache carries no scales) — use chunked prefill,
    whose scatter quantizes on write."""
    if _tree_has_quantized_pool(pool_tree):
        raise NotImplementedError(
            "scatter_prefill_to_paged does not support quantized pools; "
            "use prefill_mode='chunked'")
    pages = jnp.asarray(pages, jnp.int32)

    def leaf(pool_leaf, contig_leaf):
        bs = pool_leaf.shape[-2]
        return _scatter_entries(pool_leaf, contig_leaf, pages, bs)

    return jax.tree.map(leaf, pool_tree, entries_tree)
