"""Host-side continuous-batching scheduler over the paged latent-KV pool.

The device side (core.cache paged layout, kernels.mla_decode paged kernel)
is pure and shape-static; everything ragged and dynamic lives here, in
numpy, between jitted steps:

  * ``BlockAllocator`` — a free list over the global block pool.  Block 0
    is the reserved NULL block: unassigned block-table entries point at it
    so every block-table-driven gather/DMA stays in-bounds.
  * ``ContinuousScheduler`` — fixed ``max_batch`` decode slots.  Requests
    are admitted FCFS into free slots whenever the pool can cover their
    prompt (+1 for the first generated token); each decode step lazily
    allocates one more block for any request crossing a block boundary;
    finished requests free their blocks immediately, so capacity flows to
    the waiting queue mid-generation — the continuous-batching property.
  * Out-of-blocks mid-decode preempts the youngest running request
    (recompute-style: its prompt + generated tokens re-enter the waiting
    queue as a longer prompt), so the oldest requests always make
    progress.

The scheduler is deliberately model-agnostic: it hands out numpy block
tables / lengths; ``runtime.engine`` owns params, jitted steps and the
prefill -> pool scatter.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

NULL_BLOCK = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) int32
    max_new: int                  # generation budget
    arrival: int = 0              # driver step at which it becomes visible
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    n_preempted: int = 0
    orig_plen: int = -1           # preemption folds output into the prompt

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.orig_plen < 0:
            self.orig_plen = self.plen

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def output(self) -> List[int]:
        """All generated tokens, including any folded into the prompt by a
        preemption."""
        return list(self.prompt[self.orig_plen:]) + list(self.tokens)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size blocks; block 0
    (NULL) is never handed out."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._free_set = set(self._free)    # O(1) double-free detection

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks, or None (and no change) if the pool is short."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(got)
        return got

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if not (0 < b < self.num_blocks):
                raise ValueError(f"bad block id {b}")
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)
            self._free_set.add(b)


def blocks_for(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


class ContinuousScheduler:
    def __init__(self, *, num_blocks: int, block_size: int, max_batch: int,
                 max_blocks_per_req: Optional[int] = None):
        self.allocator = BlockAllocator(num_blocks)
        self.block_size = block_size
        self.max_batch = max_batch
        self.max_blocks = max_blocks_per_req or (num_blocks - 1)
        self.block_table = np.full((max_batch, self.max_blocks), NULL_BLOCK,
                                   np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.blocks_of: Dict[int, List[int]] = {}
        self.waiting: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self._admit_order: List[int] = []   # slots, oldest admission first

    # ------------------------------------------------------------ queue ---

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.max_batch) if self.slots[s] is not None]

    @property
    def n_active(self) -> int:
        return len(self.active_slots)

    @property
    def all_done(self) -> bool:
        return not self.waiting and not self.active_slots

    # -------------------------------------------------------- admission ---

    def try_admit(self, step: int = 0) -> List[Tuple[int, Request]]:
        """FCFS admission into free slots.  A request needs blocks for its
        whole prompt plus the first generated token; if the pool cannot
        cover the queue head, admission stops (no head-of-line skipping —
        keeps FCFS latency honest).  Returns [(slot, request)] admitted
        now; the engine prefills them and scatters into the pool."""
        admitted = []
        for slot in range(self.max_batch):
            if not self.waiting:
                break
            if self.slots[slot] is not None:
                continue
            req = self.waiting[0]
            need = blocks_for(req.plen + 1, self.block_size)
            if need > self.max_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt {req.plen} needs {need} "
                    f"blocks > max_blocks_per_req {self.max_blocks}")
            if need > self.allocator.num_blocks - 1:
                # can NEVER fit, even with an empty pool — fail fast
                # instead of refusing admission forever
                raise ValueError(
                    f"request {req.rid}: prompt {req.plen} needs {need} "
                    f"blocks > pool size {self.allocator.num_blocks - 1}")
            blocks = self.allocator.alloc(need)
            if blocks is None:          # out of blocks: admission refused
                break
            self.waiting.popleft()
            req.slot, req.admitted_step = slot, step
            self.slots[slot] = req
            self.blocks_of[slot] = blocks
            self.block_table[slot] = NULL_BLOCK
            self.block_table[slot, :need] = blocks
            self.lengths[slot] = req.plen
            self._admit_order.append(slot)
            admitted.append((slot, req))
        return admitted

    # ----------------------------------------------------- decode cycle ---

    def ensure_step_capacity(self) -> List[Request]:
        """Grow each active request's allocation so the next decode token
        (written at position lengths[slot]) has a block.  Oldest admissions
        grow first; on pool exhaustion the YOUNGEST running request is
        preempted (recompute-style) so the oldest always make progress.
        Returns the preempted requests."""
        preempted: List[Request] = []
        for slot in list(self._admit_order):          # oldest first
            if self.slots[slot] is None:              # already preempted
                continue
            need = blocks_for(int(self.lengths[slot]) + 1, self.block_size)
            if need > self.max_blocks:
                raise ValueError(f"request in slot {slot} exceeds "
                                 f"max_blocks_per_req {self.max_blocks}")
            while need > len(self.blocks_of[slot]):
                got = self.allocator.alloc(1)
                if got is None:
                    if self.n_active <= 1:
                        raise RuntimeError(
                            "pool exhausted with a single running request; "
                            "increase num_blocks or max cache length")
                    victim, vslot = self._preempt_youngest()
                    preempted.append(victim)
                    if vslot == slot:     # preempted ourselves: stop growing
                        break
                    continue
                self.blocks_of[slot].extend(got)
                self.block_table[slot, len(self.blocks_of[slot]) - 1] = got[0]
        return preempted

    def _preempt_youngest(self) -> Tuple[Request, int]:
        slot = self._admit_order[-1]
        req = self.slots[slot]
        # recompute-style: prompt + generated so far re-enter the queue as
        # a longer prompt (greedy decoding makes the replay identical)
        req.prompt = np.concatenate(
            [req.prompt, np.asarray(req.tokens, np.int32)])
        req.max_new -= len(req.tokens)
        req.tokens = []
        req.n_preempted += 1
        self._release_slot(slot)
        self.waiting.appendleft(req)
        return req, slot

    def record_prefill_sample(self, slot: int, tok: int,
                              step: int = 0) -> Optional[Request]:
        """Account the token sampled from the PREFILL logits (generated
        token #1 — sampled but not yet written to the cache).  Returns the
        request if that already exhausts its budget (max_new == 1)."""
        req = self.slots[slot]
        req.tokens.append(int(tok))
        if req.done:
            req.finished_step = step
            self._release_slot(slot)
            self.finished.append(req)
            return req
        return None

    def advance(self, sampled: Dict[int, int], step: int = 0) -> List[Request]:
        """Account one decode step: ``sampled[slot]`` is the token the step
        just produced for that slot; the token fed INTO the step is now in
        the cache (lengths += 1).  Finished requests are evicted and their
        blocks freed.  Returns the requests finished this step."""
        done: List[Request] = []
        for slot, tok in sampled.items():
            req = self.slots[slot]
            if req is None:
                continue
            self.lengths[slot] += 1
            req.tokens.append(int(tok))
            if req.done:
                req.finished_step = step
                self._release_slot(slot)
                self.finished.append(req)
                done.append(req)
        return done

    def _release_slot(self, slot: int) -> None:
        self.allocator.free(self.blocks_of.pop(slot))
        req = self.slots[slot]
        req.slot = -1
        self.slots[slot] = None
        self.block_table[slot] = NULL_BLOCK
        self.lengths[slot] = 0
        self._admit_order.remove(slot)

    # ------------------------------------------------------------- stats ---

    def utilization(self) -> Dict[str, float]:
        """valid_frac: valid tokens / allocated slots (internal
        fragmentation); pool_frac: allocated blocks / pool size."""
        alloc_blocks = sum(len(v) for v in self.blocks_of.values())
        valid = int(self.lengths[self.active_slots].sum()) \
            if self.active_slots else 0
        return {
            "valid_frac": valid / (alloc_blocks * self.block_size)
            if alloc_blocks else 0.0,
            "pool_frac": alloc_blocks / (self.allocator.num_blocks - 1),
            "valid_tokens": float(valid),
            "allocated_blocks": float(alloc_blocks),
        }
