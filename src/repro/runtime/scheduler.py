"""Host-side continuous-batching scheduler over the paged latent-KV pool.

The device side (core.cache paged layout, kernels.mla_decode paged kernel)
is pure and shape-static; everything ragged and dynamic lives here, in
numpy, between jitted steps:

  * ``BlockAllocator`` — a REF-COUNTED free list over the global block
    pool.  Block 0 is the reserved NULL block: unassigned block-table
    entries point at it so every block-table-driven gather/DMA stays
    in-bounds.  ``fork`` (refcount += 1) and ``release`` (refcount -= 1)
    replace raw ``free`` throughout the scheduler — prefix-shared blocks
    are mapped by several requests at once (runtime.prefix_cache).
  * ``ContinuousScheduler`` — fixed ``max_batch`` decode slots.  Requests
    are admitted FCFS into free slots whenever the pool can cover their
    prompt (+1 for the first generated token); ``try_admit`` first matches
    the longest cached prefix in the radix ``PrefixCache`` and maps the
    request's leading block-table entries onto the shared pool blocks, so
    only the un-cached suffix needs prefilling (``Request.n_cached``).
    Each decode step lazily allocates one more block for any request
    crossing a block boundary; finished requests release their blocks —
    trie-registered ones stay resident as LRU-evictable prefix cache,
    the rest return to the free list immediately.
  * Out-of-blocks mid-decode first evicts LRU refcount-zero cached
    blocks, then preempts the youngest running request (recompute-style:
    its prompt + generated tokens re-enter the waiting queue as a longer
    prompt — whose prefix usually re-hits the cache), so the oldest
    requests always make progress.

The scheduler is deliberately model-agnostic: it hands out numpy block
tables / lengths / copy-on-write block pairs; ``runtime.engine`` owns
params, jitted steps, the chunked prefill -> pool scatter, and the device
side of every CoW copy (``cow_pending``).

It is also topology-agnostic: under sharded serving (PR 4) these host
structures stay GLOBAL — one block table / length array covering every
slot, addressing one logical pool — and only their device placement
changes (runtime.steps shards the row dim over DP and replicates the
pool; the engine pads ``max_batch`` to a DP multiple before constructing
the scheduler, which just sees a few more ordinary slots).
"""
from __future__ import annotations

import collections
import dataclasses
from time import perf_counter
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .prefix_cache import PrefixCache

NULL_BLOCK = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) int32
    max_new: int                  # generation budget
    arrival: int = 0              # driver step at which it becomes visible
    tokens: List[int] = dataclasses.field(default_factory=list)
    # per-request termination (PR 9): generation also stops when the
    # output's suffix matches any of these token-id sequences ("stop"),
    # or when the frontend cancels the request mid-flight ("cancelled").
    # The matched stop tokens are excluded from ``output`` (n_trunc hides
    # them — they may span a preemption fold, so the prompt isn't edited).
    stop: List[List[int]] = dataclasses.field(default_factory=list)
    finish_reason: str = ""       # "" while running; length|stop|cancelled
    n_trunc: int = 0              # trailing output tokens hidden by a stop
    slot: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    n_preempted: int = 0
    orig_plen: int = -1           # preemption folds output into the prompt
    n_cached: int = 0             # prompt tokens served by the prefix cache
    # lifecycle wall clock (perf_counter seconds, -1 = not reached):
    # stamped by the scheduler at each transition so telemetry can build
    # queued/prefill/decode spans and TTFT/TPOT retrospectively.  admit_t
    # and first_tok_t keep their FIRST value across preemptions (TTFT is
    # time to the first token the user ever saw); preempt_ts logs each
    # preemption instant.
    submit_t: float = -1.0
    admit_t: float = -1.0
    first_tok_t: float = -1.0
    finish_t: float = -1.0
    preempt_ts: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.orig_plen < 0:
            self.orig_plen = self.plen

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def output(self) -> List[int]:
        """All generated tokens, including any folded into the prompt by a
        preemption, minus any trailing matched stop sequence."""
        out = list(self.prompt[self.orig_plen:]) + list(self.tokens)
        return out[:len(out) - self.n_trunc] if self.n_trunc else out

    @property
    def done(self) -> bool:
        return bool(self.finish_reason) or len(self.tokens) >= self.max_new


class BlockAllocator:
    """Ref-counted free-list allocator over ``num_blocks`` fixed-size
    blocks; block 0 (NULL) is never handed out.

    ``alloc`` hands out blocks at refcount 1; ``fork`` adds a reference
    (prefix sharing); ``release`` drops one and REPORTS blocks reaching
    zero without freeing them — the caller (PrefixCache) decides whether
    a zero block stays cached (LRU-evictable) or is ``free``d."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._free_set = set(self._free)    # O(1) double-free detection
        self.refcount: Dict[int, int] = {}  # allocated block -> references
        self.total_allocs = 0               # cumulative blocks handed out

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def _check_id(self, b: int) -> None:
        if not (0 < b < self.num_blocks):
            raise ValueError(f"bad block id {b}")

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks at refcount 1, or None (and no change) if the
        pool is short."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(got)
        for b in got:
            self.refcount[b] = 1
        self.total_allocs += n
        return got

    def fork(self, blocks: List[int]) -> None:
        """Add one reference per block (prefix-cache hit).  Reviving a
        cached refcount-0 block is legal; forking a free block is not."""
        for b in blocks:
            self._check_id(b)
            if b in self._free_set or b not in self.refcount:
                raise ValueError(f"fork of unallocated block {b}")
            self.refcount[b] += 1

    def release(self, blocks: List[int]) -> List[int]:
        """Drop one reference per block; returns the blocks that reached
        refcount 0 (still allocated — route them to ``free`` or keep them
        cached)."""
        zeroed = []
        for b in blocks:
            self._check_id(b)
            rc = self.refcount.get(b)
            if rc is None or b in self._free_set:
                raise ValueError(f"release of unallocated block {b}")
            if rc <= 0:
                raise ValueError(f"release of refcount-0 block {b}")
            self.refcount[b] = rc - 1
            if rc == 1:
                zeroed.append(b)
        return zeroed

    def free(self, blocks: List[int]) -> None:
        """Return blocks to the free list.  Only unshared blocks
        (refcount <= 1) may be freed; shared blocks must be ``release``d
        by each holder."""
        for b in blocks:
            self._check_id(b)
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            if self.refcount.get(b, 0) > 1:
                raise ValueError(f"free of shared block {b} "
                                 f"(refcount {self.refcount[b]})")
            self.refcount.pop(b, None)
            self._free.append(b)
            self._free_set.add(b)


def blocks_for(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


class ContinuousScheduler:
    """``decode_window`` is the number of cache positions one decode tick
    may WRITE per request: 1 for plain decode, ``spec_k + 1`` for
    speculative decoding (the verify step scatters the last sampled token
    plus up to k drafts).  Admission and per-step block growth reserve the
    window (clipped to each request's remaining budget), so a verify
    scatter can never hit the silent table-clamp overwrite that
    :meth:`_require_table_room` guards."""

    def __init__(self, *, num_blocks: int, block_size: int, max_batch: int,
                 max_blocks_per_req: Optional[int] = None,
                 enable_prefix_cache: bool = True,
                 decode_window: int = 1):
        if decode_window < 1:
            raise ValueError(f"decode_window must be >= 1, {decode_window}")
        self.allocator = BlockAllocator(num_blocks)
        self.block_size = block_size
        self.decode_window = decode_window
        self.prefix = PrefixCache(self.allocator, block_size,
                                  enabled=enable_prefix_cache)
        self.max_batch = max_batch
        self.max_blocks = max_blocks_per_req or (num_blocks - 1)
        self.block_table = np.full((max_batch, self.max_blocks), NULL_BLOCK,
                                   np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.blocks_of: Dict[int, List[int]] = {}
        self.waiting: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self._admit_order: List[int] = []   # slots, oldest admission first
        # (src, dst) device copies the engine must run before the next
        # pool write (copy-on-write breaks of shared write targets)
        self.cow_pending: List[Tuple[int, int]] = []

    # ------------------------------------------------------------ queue ---

    def submit(self, req: Request) -> None:
        if req.submit_t < 0:
            req.submit_t = perf_counter()
        self.waiting.append(req)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.max_batch) if self.slots[s] is not None]

    @property
    def n_active(self) -> int:
        return len(self.active_slots)

    @property
    def all_done(self) -> bool:
        return not self.waiting and not self.active_slots

    # -------------------------------------------------------- admission ---

    def try_admit(self, step: int = 0) -> List[Tuple[int, Request]]:
        """FCFS admission into free slots.  The radix cache is consulted
        first: the longest cached prefix is ``fork``ed onto the request's
        leading block-table entries (``req.n_cached`` tokens need no
        prefill); fresh blocks cover the rest of the prompt plus the
        first generated token.  If the pool cannot cover the queue head
        even after LRU eviction, admission stops (no head-of-line
        skipping — keeps FCFS latency honest).  Returns [(slot, request)]
        admitted now; the engine prefills the un-cached suffixes as a
        batch and then calls ``commit_prefill`` per request."""
        admitted = []
        for slot in range(self.max_batch):
            if not self.waiting:
                break
            if self.slots[slot] is not None:
                continue
            req = self.waiting[0]
            need = blocks_for(req.plen + self._window(req), self.block_size)
            if need > self.max_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt {req.plen} needs {need} "
                    f"blocks > max_blocks_per_req {self.max_blocks}")
            if need > self.allocator.num_blocks - 1:
                # can NEVER fit, even with an empty pool — fail fast
                # instead of refusing admission forever
                raise ValueError(
                    f"request {req.rid}: prompt {req.plen} needs {need} "
                    f"blocks > pool size {self.allocator.num_blocks - 1}")
            shared = self.prefix.match(req.prompt)
            fresh = self.prefix.alloc(need - len(shared))
            if fresh is None:               # out of blocks: admission refused
                self.prefix.cancel_match(req.prompt, shared)
                break
            blocks = shared + fresh
            self.waiting.popleft()
            req.slot, req.admitted_step = slot, step
            if req.admit_t < 0:
                req.admit_t = perf_counter()
            req.n_cached = len(shared) * self.block_size
            self.slots[slot] = req
            self.blocks_of[slot] = blocks
            self.block_table[slot] = NULL_BLOCK
            self.block_table[slot, :need] = blocks
            self.lengths[slot] = req.plen
            self._admit_order.append(slot)
            admitted.append((slot, req))
        return admitted

    def commit_prefill(self, slot: int) -> int:
        """Register the request's full prompt blocks in the radix cache.
        MUST be called only after the engine's prefill has scattered the
        corresponding latents into the pool — matches hand out pool
        contents, not promises.  Returns the number of blocks newly
        registered."""
        req = self.slots[slot]
        n_full = req.plen // self.block_size
        return self.prefix.insert(req.prompt, self.blocks_of[slot][:n_full])

    # ----------------------------------------------------- decode cycle ---

    def _window(self, req: Request) -> int:
        """Write window of the next decode tick for ``req``: the verify
        window clipped to the remaining generation budget (a request about
        to finish never writes — or needs blocks for — the full k + 1)."""
        return max(1, min(self.decode_window,
                          req.max_new - len(req.tokens)))

    def _require_table_room(self, slot: int, n_tokens: int) -> None:
        """Raise if ``n_tokens`` total tokens would overflow slot's block
        table.  ``core.cache.update_latent_paged`` cannot detect this —
        JAX clamps the out-of-range page index onto the request's LAST
        block and silently overwrites it — so the host must refuse first."""
        if blocks_for(n_tokens, self.block_size) > self.max_blocks:
            req = self.slots[slot]
            raise RuntimeError(
                f"block table full: request {req.rid if req else '?'} in "
                f"slot {slot} needs {n_tokens} token slots but the table "
                f"caps at {self.max_blocks} blocks x {self.block_size} = "
                f"{self.max_blocks * self.block_size} tokens; a device "
                f"write would clamp onto the last block and silently "
                f"overwrite it (raise max_blocks_per_req or max_new)")

    def ensure_step_capacity(self) -> List[Request]:
        """Grow each active request's allocation so the next decode tick's
        write window (positions lengths[slot] .. lengths[slot] + window-1;
        window = ``decode_window`` budget-clipped) has blocks.  Oldest
        admissions grow first; on pool exhaustion the cache is
        LRU-evicted, then the YOUNGEST running request is preempted
        (recompute-style) so the oldest always make progress.  If a
        write-target block turns out shared (prefix-forked or
        trie-registered), the share is broken copy-on-write: a private
        block is allocated and the (src, dst) device copy is queued on
        ``cow_pending`` for the engine.  Returns the preempted requests."""
        preempted: List[Request] = []
        for slot in list(self._admit_order):          # oldest first
            if self.slots[slot] is None:              # already preempted
                continue
            window = self._window(self.slots[slot])
            self._require_table_room(slot, int(self.lengths[slot]) + window)
            need = blocks_for(int(self.lengths[slot]) + window,
                              self.block_size)
            while need > len(self.blocks_of[slot]):
                got = self.prefix.alloc(1)
                if got is None:
                    if self.n_active <= 1:
                        raise RuntimeError(
                            "pool exhausted with a single running request; "
                            "increase num_blocks or max cache length")
                    victim, vslot = self._preempt_youngest()
                    preempted.append(victim)
                    if vslot == slot:     # preempted ourselves: stop growing
                        break
                    continue
                self.blocks_of[slot].extend(got)
                self.block_table[slot, len(self.blocks_of[slot]) - 1] = got[0]
            if self.slots[slot] is not None:
                self._cow_write_target(slot, window)
        return preempted

    def _cow_write_target(self, slot: int, window: int = 1) -> None:
        """Copy-on-write: if any block about to receive one of this slot's
        next ``window`` tokens is shared, swap in a private copy.
        Structurally this does not arise from prefix sharing alone (shared
        blocks cover only full prompt prefixes, writes land strictly after
        the prompt) — it guards external forks and future decode-block
        registration."""
        lo = int(self.lengths[slot]) // self.block_size
        hi = (int(self.lengths[slot]) + window - 1) // self.block_size
        for widx in range(lo, min(hi, len(self.blocks_of[slot]) - 1) + 1):
            old = self.blocks_of[slot][widx]
            if not self.prefix.is_write_shared(old):
                continue
            got = self.prefix.alloc(1)
            if got is None:
                raise RuntimeError(
                    f"pool exhausted breaking a copy-on-write share of "
                    f"block {old} (slot {slot}); increase num_blocks")
            self.blocks_of[slot][widx] = got[0]
            self.block_table[slot, widx] = got[0]
            self.prefix.release([old])
            self.prefix.count_cow()
            self.cow_pending.append((old, got[0]))

    def drain_cow(self) -> List[Tuple[int, int]]:
        """Hand the queued (src, dst) copy-on-write block copies to the
        engine (which owns the device pool) and clear the queue."""
        out, self.cow_pending = self.cow_pending, []
        return out

    def _preempt_youngest(self) -> Tuple[Request, int]:
        slot = self._admit_order[-1]
        req = self.slots[slot]
        # recompute-style: prompt + generated so far re-enter the queue as
        # a longer prompt (per-position-keyed sampling makes the replay
        # identical — see engine._sample)
        req.prompt = np.concatenate(
            [req.prompt, np.asarray(req.tokens, np.int32)])
        req.max_new -= len(req.tokens)
        req.tokens = []
        req.n_preempted += 1
        req.preempt_ts.append(perf_counter())
        self._release_slot(slot)
        self.waiting.appendleft(req)
        return req, slot

    def record_prefill_sample(self, slot: int, tok: int,
                              step: int = 0) -> Optional[Request]:
        """Account the token sampled from the PREFILL logits (generated
        token #1 — sampled but not yet written to the cache).  Returns the
        request if that already finishes it (max_new == 1, or a
        single-token stop sequence)."""
        req = self.slots[slot]
        req.tokens.append(int(tok))
        if req.first_tok_t < 0:
            req.first_tok_t = perf_counter()
        self._check_stop(req)
        if req.done:
            return self._finish(slot, step)
        return None

    def advance(self, sampled: Dict[int, int], step: int = 0) -> List[Request]:
        """Account one decode step: ``sampled[slot]`` is the token the step
        just produced for that slot; the token fed INTO the step is now in
        the cache (lengths += 1).  Finished requests are evicted and their
        blocks released (trie-registered ones stay LRU-evictable).
        Returns the requests finished this step."""
        return self.advance_multi({s: [t] for s, t in sampled.items()}, step)

    def advance_multi(self, emitted: Dict[int, List[int]],
                      step: int = 0) -> List[Request]:
        """Account one SPECULATIVE round: ``emitted[slot]`` is the ordered
        list of tokens the verify step produced for that slot (accepted
        drafts + one bonus/correction token, at most the slot's write
        window).  The cache gained the fed token plus every accepted draft
        — lengths += len(emitted); the LAST emitted token is the new
        pending token (not yet written; rejected drafts' latents sit past
        ``lengths`` and are overwritten before they can ever be attended).
        Len-1 lists degrade to plain :meth:`advance`.  Returns the
        requests finished this round."""
        done: List[Request] = []
        for slot, toks in emitted.items():
            req = self.slots[slot]
            if req is None or not toks:
                continue
            if len(toks) > self._window(req):
                raise ValueError(
                    f"slot {slot}: {len(toks)} emitted tokens exceed the "
                    f"write window {self._window(req)}")
            # token-at-a-time so a stop hit is token-exact: tokens after
            # the match (later accepted drafts in a spec round) are
            # discarded, never accounted.
            for t in toks:
                self.lengths[slot] += 1
                req.tokens.append(int(t))
                if self._check_stop(req) or req.done:
                    break
            if req.done:
                done.append(self._finish(slot, step))
        return done

    def _check_stop(self, req: Request) -> bool:
        """True if the output's suffix now matches one of the request's
        stop sequences; marks it finished ("stop") and hides the matched
        tokens from ``output``.  Matched against ``output`` (not just
        ``tokens``) so a sequence spanning a preemption fold still hits."""
        if not req.stop or req.finish_reason:
            return bool(req.finish_reason)
        out = list(req.prompt[req.orig_plen:]) + list(req.tokens)
        for seq in req.stop:
            n = len(seq)
            if n and n <= len(out) and out[-n:] == [int(t) for t in seq]:
                req.finish_reason = "stop"
                req.n_trunc = n
                return True
        return False

    def _finish(self, slot: int, step: int) -> Request:
        """Evict a finished request from its slot and account it."""
        req = self.slots[slot]
        if not req.finish_reason:
            req.finish_reason = "length"
        req.finished_step = step
        req.finish_t = perf_counter()
        self._release_slot(slot)
        self.finished.append(req)
        return req

    def cancel(self, rid: int, step: int = 0) -> Optional[Request]:
        """Abort a request wherever it is.  Waiting requests leave the
        queue; running requests release their slot and blocks (trie-
        registered prefix blocks stay cached and unpoisoned — the pool
        contents they index are still valid prompt latents).  Unknown or
        already-finished rids are a no-op.  Returns the cancelled request
        (``finish_reason == "cancelled"``) or None."""
        for req in self.waiting:
            if req.rid == rid:
                self.waiting.remove(req)
                req.finish_reason = "cancelled"
                req.finished_step = step
                req.finish_t = perf_counter()
                self.finished.append(req)
                return req
        for slot in self.active_slots:
            req = self.slots[slot]
            if req.rid == rid:
                req.finish_reason = "cancelled"
                return self._finish(slot, step)
        return None

    def _release_slot(self, slot: int) -> None:
        self.prefix.release(self.blocks_of.pop(slot))
        req = self.slots[slot]
        req.slot = -1
        self.slots[slot] = None
        self.block_table[slot] = NULL_BLOCK
        self.lengths[slot] = 0
        self._admit_order.remove(slot)

    # ------------------------------------------------------------- stats ---

    def utilization(self) -> Dict[str, float]:
        """valid_frac: valid tokens / allocated slots (internal
        fragmentation); pool_frac: allocated blocks / pool size (cached
        refcount-0 blocks are counted separately as cached_blocks)."""
        alloc_blocks = sum(len(v) for v in self.blocks_of.values())
        valid = int(self.lengths[self.active_slots].sum()) \
            if self.active_slots else 0
        return {
            "valid_frac": valid / (alloc_blocks * self.block_size)
            if alloc_blocks else 0.0,
            "pool_frac": alloc_blocks / (self.allocator.num_blocks - 1),
            "valid_tokens": float(valid),
            "allocated_blocks": float(alloc_blocks),
            "cached_blocks": float(self.prefix.num_evictable),
        }
