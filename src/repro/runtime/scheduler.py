"""Host-side continuous-batching scheduler over the paged latent-KV pool.

The device side (core.cache paged layout, kernels.mla_decode paged kernel)
is pure and shape-static; everything ragged and dynamic lives here, in
numpy, between jitted steps:

  * ``BlockAllocator`` — a REF-COUNTED free list over the global block
    pool.  Block 0 is the reserved NULL block: unassigned block-table
    entries point at it so every block-table-driven gather/DMA stays
    in-bounds.  ``fork`` (refcount += 1) and ``release`` (refcount -= 1)
    replace raw ``free`` throughout the scheduler — prefix-shared blocks
    are mapped by several requests at once (runtime.prefix_cache).
  * ``ContinuousScheduler`` — fixed ``max_batch`` decode slots.  Requests
    are admitted into free slots whenever the pool can cover their
    prompt (+1 for the first generated token); ``try_admit`` first matches
    the longest cached prefix in the radix ``PrefixCache`` — token-
    granular: a hit may end mid-block, materialized by a queued
    copy-on-write of the partial source block — and maps the request's
    leading block-table entries onto the shared pool blocks, so only the
    un-cached suffix needs prefilling (``Request.n_cached``).  Admission
    order is ``admission='fcfs'`` (strict) or ``'cache_aware'``
    (longest-cached-prefix first with an ``admission_age_bound``
    starvation bound).  Each decode step lazily allocates one more block
    for any request crossing a block boundary — and registers the block
    just completed in the trie (``decode_block_reuse``), so a follow-up
    conversation turn re-hits its own generation; finished requests
    release their blocks — trie-registered ones stay resident as
    LRU-evictable prefix cache, the rest return to the free list
    immediately.
  * n-way PARALLEL SAMPLING (``SamplingParams.n > 1``): the prompt
    prefills once, then ``fork_group`` maps every pre-admitted fork
    child onto the parent's full prompt blocks (``BlockAllocator.fork``)
    with a copy-on-write tail, and each fork decodes as an ordinary
    independent request (own stop/cancel/preemption, consecutive rids,
    own sampling-key stream).
  * Out-of-blocks mid-decode first evicts LRU refcount-zero cached
    blocks, then preempts the youngest running request (recompute-style:
    its prompt + generated tokens re-enter the waiting queue as a longer
    prompt — whose prefix usually re-hits the cache), so the oldest
    requests always make progress.

The scheduler is deliberately model-agnostic: it hands out numpy block
tables / lengths / copy-on-write block pairs; ``runtime.engine`` owns
params, jitted steps, the chunked prefill -> pool scatter, and the device
side of every CoW copy (``cow_pending``).

It is also topology-agnostic: under sharded serving (PR 4) these host
structures stay GLOBAL — one block table / length array covering every
slot, addressing one logical pool — and only their device placement
changes (runtime.steps shards the row dim over DP and replicates the
pool; the engine pads ``max_batch`` to a DP multiple before constructing
the scheduler, which just sees a few more ordinary slots).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import warnings
from time import perf_counter
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .prefix_cache import PrefixCache
from .sampling import SamplingParams

NULL_BLOCK = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (plen,) int32
    # generation budget — the scheduler's MUTABLE working copy
    # (preemption shrinks it as output folds into the prompt).  None
    # defers to ``sampling.max_tokens``; passing it directly is the
    # legacy pre-SamplingParams constructor, kept via a deprecation shim.
    max_new: Optional[int] = None
    arrival: int = 0              # driver step at which it becomes visible
    tokens: List[int] = dataclasses.field(default_factory=list)
    # per-request termination (PR 9): generation also stops when the
    # output's suffix matches any of these token-id sequences ("stop"),
    # or when the frontend cancels the request mid-flight ("cancelled").
    # The matched stop tokens are excluded from ``output`` (n_trunc hides
    # them — they may span a preemption fold, so the prompt isn't edited).
    stop: List[List[int]] = dataclasses.field(default_factory=list)
    finish_reason: str = ""       # "" while running; length|stop|cancelled
    n_trunc: int = 0              # trailing output tokens hidden by a stop
    slot: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    n_preempted: int = 0
    orig_plen: int = -1           # preemption folds output into the prompt
    n_cached: int = 0             # prompt tokens served by the prefix cache
    # lifecycle wall clock (perf_counter seconds, -1 = not reached):
    # stamped by the scheduler at each transition so telemetry can build
    # queued/prefill/decode spans and TTFT/TPOT retrospectively.  admit_t
    # and first_tok_t keep their FIRST value across preemptions (TTFT is
    # time to the first token the user ever saw); preempt_ts logs each
    # preemption instant.
    submit_t: float = -1.0
    admit_t: float = -1.0
    first_tok_t: float = -1.0
    finish_t: float = -1.0
    preempt_ts: List[float] = dataclasses.field(default_factory=list)
    # -- request API (PR 10): consolidated per-request knobs.  max_new /
    # stop above remain the scheduler's mutable working copies,
    # initialized from here.
    sampling: Optional[SamplingParams] = None
    # n-way parallel sampling: ``sampling.n - 1`` fork children ride on
    # the parent through the queue (rids rid+1 .. rid+n-1, sampling
    # n=1); ``fork_group`` maps them onto the parent's prompt blocks
    # right after its prefill, after which each is an ordinary
    # independent request.  ``forked`` stays True across preemption so a
    # replayed parent never re-forks.
    fork_children: List["Request"] = dataclasses.field(default_factory=list)
    forked: bool = False
    n_skipped: int = 0            # times bypassed by cache-aware admission

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.sampling is None:
            if self.max_new is None:
                raise ValueError(
                    "Request needs sampling=SamplingParams(...) (or the "
                    "legacy max_new=)")
            warnings.warn(
                "Request(prompt, max_new, stop=...) is deprecated; pass "
                "sampling=SamplingParams(max_tokens=..., stop=..., ...)",
                DeprecationWarning, stacklevel=3)
            self.sampling = SamplingParams.from_legacy(self.max_new,
                                                       self.stop)
        else:
            self.sampling = self.sampling.validate()
            if self.max_new is None:
                self.max_new = self.sampling.max_tokens
            if not self.stop:
                self.stop = [list(s) for s in self.sampling.stop]
        if self.orig_plen < 0:
            self.orig_plen = self.plen

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def output(self) -> List[int]:
        """All generated tokens, including any folded into the prompt by a
        preemption, minus any trailing matched stop sequence."""
        out = list(self.prompt[self.orig_plen:]) + list(self.tokens)
        return out[:len(out) - self.n_trunc] if self.n_trunc else out

    @property
    def done(self) -> bool:
        return bool(self.finish_reason) or len(self.tokens) >= self.max_new


class BlockAllocator:
    """Ref-counted free-list allocator over ``num_blocks`` fixed-size
    blocks; block 0 (NULL) is never handed out.

    ``alloc`` hands out blocks at refcount 1; ``fork`` adds a reference
    (prefix sharing); ``release`` drops one and REPORTS blocks reaching
    zero without freeing them — the caller (PrefixCache) decides whether
    a zero block stays cached (LRU-evictable) or is ``free``d."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._free_set = set(self._free)    # O(1) double-free detection
        self.refcount: Dict[int, int] = {}  # allocated block -> references
        self.total_allocs = 0               # cumulative blocks handed out

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def _check_id(self, b: int) -> None:
        if not (0 < b < self.num_blocks):
            raise ValueError(f"bad block id {b}")

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks at refcount 1, or None (and no change) if the
        pool is short."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(got)
        for b in got:
            self.refcount[b] = 1
        self.total_allocs += n
        return got

    def fork(self, blocks: List[int]) -> None:
        """Add one reference per block (prefix-cache hit).  Reviving a
        cached refcount-0 block is legal; forking a free block is not."""
        for b in blocks:
            self._check_id(b)
            if b in self._free_set or b not in self.refcount:
                raise ValueError(f"fork of unallocated block {b}")
            self.refcount[b] += 1

    def release(self, blocks: List[int]) -> List[int]:
        """Drop one reference per block; returns the blocks that reached
        refcount 0 (still allocated — route them to ``free`` or keep them
        cached)."""
        zeroed = []
        for b in blocks:
            self._check_id(b)
            rc = self.refcount.get(b)
            if rc is None or b in self._free_set:
                raise ValueError(f"release of unallocated block {b}")
            if rc <= 0:
                raise ValueError(f"release of refcount-0 block {b}")
            self.refcount[b] = rc - 1
            if rc == 1:
                zeroed.append(b)
        return zeroed

    def free(self, blocks: List[int]) -> None:
        """Return blocks to the free list.  Only unshared blocks
        (refcount <= 1) may be freed; shared blocks must be ``release``d
        by each holder."""
        for b in blocks:
            self._check_id(b)
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            if self.refcount.get(b, 0) > 1:
                raise ValueError(f"free of shared block {b} "
                                 f"(refcount {self.refcount[b]})")
            self.refcount.pop(b, None)
            self._free.append(b)
            self._free_set.add(b)


def blocks_for(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


class ContinuousScheduler:
    """``decode_window`` is the number of cache positions one decode tick
    may WRITE per request: 1 for plain decode, ``spec_k + 1`` for
    speculative decoding (the verify step scatters the last sampled token
    plus up to k drafts).  Admission and per-step block growth reserve the
    window (clipped to each request's remaining budget), so a verify
    scatter can never hit the silent table-clamp overwrite that
    :meth:`_require_table_room` guards."""

    def __init__(self, *, num_blocks: int, block_size: int, max_batch: int,
                 max_blocks_per_req: Optional[int] = None,
                 enable_prefix_cache: bool = True,
                 decode_window: int = 1,
                 admission: str = "fcfs",
                 admission_age_bound: int = 64,
                 decode_block_reuse: bool = True,
                 partial_match: bool = True):
        if decode_window < 1:
            raise ValueError(f"decode_window must be >= 1, {decode_window}")
        if admission not in ("fcfs", "cache_aware"):
            raise ValueError(f"unknown admission policy {admission!r} "
                             "(expected 'fcfs' or 'cache_aware')")
        if admission_age_bound < 1:
            raise ValueError("admission_age_bound must be >= 1")
        self.allocator = BlockAllocator(num_blocks)
        self.block_size = block_size
        self.decode_window = decode_window
        self.admission = admission
        self.admission_age_bound = admission_age_bound
        self.decode_block_reuse = decode_block_reuse
        self.prefix = PrefixCache(self.allocator, block_size,
                                  enabled=enable_prefix_cache,
                                  partial=partial_match)
        self.max_batch = max_batch
        self.max_blocks = max_blocks_per_req or (num_blocks - 1)
        self.block_table = np.full((max_batch, self.max_blocks), NULL_BLOCK,
                                   np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.blocks_of: Dict[int, List[int]] = {}
        self.waiting: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self._admit_order: List[int] = []   # slots, oldest admission first
        # (src, dst) device copies the engine must run before the next
        # pool write (copy-on-write breaks of shared write targets,
        # partial-match tails, fork-group tails)
        self.cow_pending: List[Tuple[int, int]] = []
        self.fork_groups = 0        # parallel-sampling groups forked
        self.forked_children = 0    # fork children spawned across groups

    # ------------------------------------------------------------ queue ---

    def submit(self, req: Request) -> None:
        if req.submit_t < 0:
            req.submit_t = perf_counter()
        if req.sampling.n > 1 and not req.forked and not req.fork_children:
            # materialize the fork children now so cancellation and group
            # accounting have real Request objects; they ride on the
            # parent (NOT the queue) until fork_group seats them.  The
            # caller owns rid uniqueness for [rid, rid + n).
            one = dataclasses.replace(req.sampling, n=1)
            for i in range(1, req.sampling.n):
                child = Request(rid=req.rid + i, prompt=req.prompt,
                                arrival=req.arrival, sampling=one)
                child.submit_t = req.submit_t
                req.fork_children.append(child)
        self.waiting.append(req)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.max_batch) if self.slots[s] is not None]

    @property
    def n_active(self) -> int:
        return len(self.active_slots)

    @property
    def all_done(self) -> bool:
        return not self.waiting and not self.active_slots

    # -------------------------------------------------------- admission ---

    def _pick_waiting(self) -> Request:
        """The waiting request admission should try next.  'fcfs': the
        queue head.  'cache_aware': the request with the longest
        currently-cached prefix (probed with ``PrefixCache.lookup_len`` —
        no forks, no stats), arrival order breaking ties — warm
        conversation turns jump cold prompts, multiplying the hit rate
        the decode-block registrations create.  Starvation bound: any
        request already bypassed ``admission_age_bound`` times is served
        first regardless of its cache affinity."""
        if self.admission == "fcfs" or len(self.waiting) <= 1:
            return self.waiting[0]
        for req in self.waiting:
            if req.n_skipped >= self.admission_age_bound:
                return req
        best, best_len = None, -1
        for req in self.waiting:
            n = self.prefix.lookup_len(req.prompt)
            if n > best_len:
                best, best_len = req, n
        return best

    def _dequeue(self, req: Request) -> None:
        """Remove ``req`` from the waiting queue; under cache-aware
        admission every request it jumped over ages by one (the
        starvation counter ``_pick_waiting`` honors)."""
        idx = self.waiting.index(req)
        self.waiting.remove(req)
        for jumped in itertools.islice(self.waiting, idx):
            jumped.n_skipped += 1

    def try_admit(self, step: int = 0) -> List[Tuple[int, Request]]:
        """Admission into free slots (``admission`` picks the order; see
        ``_pick_waiting``).  The radix cache is consulted first: the
        longest cached prefix is ``fork``ed onto the request's leading
        block-table entries (``req.n_cached`` tokens need no prefill);
        fresh blocks cover the rest of the prompt plus the first
        generated token.  A token-granular match ending MID-BLOCK is
        materialized copy-on-write: the first fresh block becomes a
        private copy of the cached partial source via a queued device
        copy the engine runs before prefill.

        An n-way parallel-sampling parent admits as a GROUP, atomically:
        one slot per fork plus each fork's private tail blocks are
        reserved now, so ``fork_group`` (which runs later the same tick,
        right after the parent's prefill) can never fail mid-flight.

        If the pool cannot cover the picked request even after LRU
        eviction, admission stops.  Returns [(slot, request)] admitted
        now — parents only, fork children never prefill; the engine
        prefills the un-cached suffixes as a batch and then calls
        ``commit_prefill`` (+ ``fork_group``) per request."""
        admitted = []
        free = collections.deque(
            s for s in range(self.max_batch) if self.slots[s] is None)
        while self.waiting and free:
            req = self._pick_waiting()
            group = [] if req.forked else req.fork_children
            if 1 + len(group) > len(free):
                break
            need = blocks_for(req.plen + self._window(req), self.block_size)
            n_shared_full = req.plen // self.block_size
            child_needs = [blocks_for(c.plen + self._window(c),
                                      self.block_size) - n_shared_full
                           for c in group]
            if need > self.max_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt {req.plen} needs {need} "
                    f"blocks > max_blocks_per_req {self.max_blocks}")
            if need + sum(child_needs) > self.allocator.num_blocks - 1:
                # can NEVER fit, even with an empty pool — fail fast
                # instead of refusing admission forever
                raise ValueError(
                    f"request {req.rid}: prompt {req.plen} (x{1 + len(group)}"
                    f" parallel samples) needs {need + sum(child_needs)} "
                    f"blocks > pool size {self.allocator.num_blocks - 1}")
            shared = self.prefix.match(req.prompt)
            fresh = self.prefix.alloc(need - len(shared))
            if fresh is None:               # out of blocks: admission refused
                self.prefix.cancel_match(req.prompt, shared)
                break
            reserved: List[List[int]] = []
            for cn in child_needs:
                got = self.prefix.alloc(cn)
                if got is None:
                    break
                reserved.append(got)
            if len(reserved) < len(group):  # group doesn't fit atomically
                for got in reserved:
                    self.prefix.release(got)
                self.prefix.release(fresh)
                self.prefix.cancel_match(req.prompt, shared)
                break
            self._dequeue(req)
            slot = free.popleft()
            blocks = list(shared) + fresh
            req.slot, req.admitted_step = slot, step
            if req.admit_t < 0:
                req.admit_t = perf_counter()
            req.n_cached = shared.n_tokens(self.block_size)
            self.slots[slot] = req
            self.blocks_of[slot] = blocks
            self.block_table[slot] = NULL_BLOCK
            self.block_table[slot, :need] = blocks
            self.lengths[slot] = req.plen
            self._admit_order.append(slot)
            if shared.partial_len:
                # Materialize the mid-block tail: fresh[0] (block index
                # len(shared), where the partial tokens live) becomes a
                # private copy of the cached source.  Releasing the
                # source fork immediately is safe: the engine drains
                # cow_pending between admission and prefill, so the copy
                # is enqueued ahead of every later pool write in stream
                # order — even if eviction recycles the source block
                # this very tick, its latents are still intact when the
                # copy executes.
                self.cow_pending.append((shared.partial_src, fresh[0]))
                self.prefix.count_cow()
                self.prefix.release([shared.partial_src])
            for child, got in zip(group, reserved):
                # seat the fork child now (slot + private tail blocks);
                # its shared prompt mapping and lengths arrive at
                # fork_group, after the parent's prefill this tick.
                cslot = free.popleft()
                child.slot, child.admitted_step = cslot, step
                if child.admit_t < 0:
                    child.admit_t = perf_counter()
                self.slots[cslot] = child
                self.blocks_of[cslot] = list(got)
                self.block_table[cslot] = NULL_BLOCK
                for i, b in enumerate(got):
                    self.block_table[cslot, n_shared_full + i] = b
                self.lengths[cslot] = 0
                self._admit_order.append(cslot)
            admitted.append((slot, req))
        return admitted

    def fork_group(self, slot: int) -> List[Tuple[int, Request]]:
        """Fork the just-prefilled parent in ``slot`` n ways (parallel
        sampling): each pre-admitted fork child maps the parent's FULL
        prompt blocks read-only (``BlockAllocator.fork``, refcount += 1)
        ahead of the private tail blocks reserved at admission; a
        mid-block prompt tail is materialized by queueing a parent-tail
        -> child-tail device copy on ``cow_pending`` (the engine drains
        it before the next decode dispatch, so the copy is ordered ahead
        of both forks' future writes).  Called by the engine right after
        ``commit_prefill``; the parent's last-position logits then seed
        every child's first token, each sampled on its own
        fold(child rid, position) key stream.  Idempotent across
        preemption replay (``forked``).  Returns [(child_slot, child)].
        """
        parent = self.slots[slot]
        if parent is None or parent.forked or not parent.fork_children:
            return []
        parent.forked = True
        n_full = parent.plen // self.block_size
        shared = self.blocks_of[slot][:n_full]
        tail = parent.plen % self.block_size
        out = []
        for child in parent.fork_children:
            cslot = child.slot
            self.allocator.fork(shared)
            self.blocks_of[cslot] = list(shared) + self.blocks_of[cslot]
            self.block_table[cslot, :n_full] = shared
            self.lengths[cslot] = parent.plen
            child.n_cached = parent.plen    # served by the fork, not prefill
            if tail:
                self.cow_pending.append((self.blocks_of[slot][n_full],
                                         self.blocks_of[cslot][n_full]))
                self.prefix.count_cow()
            out.append((cslot, child))
        self.fork_groups += 1
        self.forked_children += len(out)
        tel = self.prefix.tel
        if tel is not None:
            tel.tracer.instant("fork_group", args={"rid": parent.rid,
                                                   "n": 1 + len(out)})
        return out

    def commit_prefill(self, slot: int) -> int:
        """Register the request's full prompt blocks in the radix cache.
        MUST be called only after the engine's prefill has scattered the
        corresponding latents into the pool — matches hand out pool
        contents, not promises.  Returns the number of blocks newly
        registered."""
        req = self.slots[slot]
        n_full = req.plen // self.block_size
        return self.prefix.insert(req.prompt, self.blocks_of[slot][:n_full])

    def register_decode_blocks(self, slot: int) -> int:
        """Register the slot's completed blocks — prompt AND generated
        tokens — in the radix trie, so a later request whose prompt
        embeds this generation (the follow-up turn of a conversation,
        an agent replaying a transcript) re-hits it instead of
        re-prefilling.  Called as ``lengths`` crosses each block
        boundary; idempotent — trie paths already present are only
        LRU-refreshed, and a block registered once is never offered
        again (``PrefixCache.insert``).  Safe against speculative
        rewind: only blocks fully below ``lengths`` are offered, and
        lengths advances over ACCEPTED tokens only, so stale
        rejected-draft latents always sit past the registered range."""
        if not self.decode_block_reuse or not self.prefix.enabled:
            return 0
        req = self.slots[slot]
        n_full = int(self.lengths[slot]) // self.block_size
        if n_full <= req.plen // self.block_size:
            return 0    # nothing decode-filled completes a new block yet
        seq = np.concatenate(
            [req.prompt, np.asarray(req.tokens, np.int32)])
        return self.prefix.insert(seq[:n_full * self.block_size],
                                  self.blocks_of[slot][:n_full], decode=True)

    # ----------------------------------------------------- decode cycle ---

    def _window(self, req: Request) -> int:
        """Write window of the next decode tick for ``req``: the verify
        window clipped to the remaining generation budget (a request about
        to finish never writes — or needs blocks for — the full k + 1)."""
        return max(1, min(self.decode_window,
                          req.max_new - len(req.tokens)))

    def _require_table_room(self, slot: int, n_tokens: int) -> None:
        """Raise if ``n_tokens`` total tokens would overflow slot's block
        table.  ``core.cache.update_latent_paged`` cannot detect this —
        JAX clamps the out-of-range page index onto the request's LAST
        block and silently overwrites it — so the host must refuse first."""
        if blocks_for(n_tokens, self.block_size) > self.max_blocks:
            req = self.slots[slot]
            raise RuntimeError(
                f"block table full: request {req.rid if req else '?'} in "
                f"slot {slot} needs {n_tokens} token slots but the table "
                f"caps at {self.max_blocks} blocks x {self.block_size} = "
                f"{self.max_blocks * self.block_size} tokens; a device "
                f"write would clamp onto the last block and silently "
                f"overwrite it (raise max_blocks_per_req or max_new)")

    def ensure_step_capacity(self) -> List[Request]:
        """Grow each active request's allocation so the next decode tick's
        write window (positions lengths[slot] .. lengths[slot] + window-1;
        window = ``decode_window`` budget-clipped) has blocks.  Oldest
        admissions grow first; on pool exhaustion the cache is
        LRU-evicted, then the YOUNGEST running request is preempted
        (recompute-style) so the oldest always make progress.  If a
        write-target block turns out shared (prefix-forked or
        trie-registered), the share is broken copy-on-write: a private
        block is allocated and the (src, dst) device copy is queued on
        ``cow_pending`` for the engine.  Returns the preempted requests."""
        preempted: List[Request] = []
        for slot in list(self._admit_order):          # oldest first
            if self.slots[slot] is None:              # already preempted
                continue
            window = self._window(self.slots[slot])
            self._require_table_room(slot, int(self.lengths[slot]) + window)
            need = blocks_for(int(self.lengths[slot]) + window,
                              self.block_size)
            while need > len(self.blocks_of[slot]):
                got = self.prefix.alloc(1)
                if got is None:
                    if self.n_active <= 1:
                        raise RuntimeError(
                            "pool exhausted with a single running request; "
                            "increase num_blocks or max cache length")
                    victim, vslot = self._preempt_youngest()
                    preempted.append(victim)
                    if vslot == slot:     # preempted ourselves: stop growing
                        break
                    continue
                self.blocks_of[slot].extend(got)
                self.block_table[slot, len(self.blocks_of[slot]) - 1] = got[0]
            if self.slots[slot] is not None:
                self._cow_write_target(slot, window)
        return preempted

    def _cow_write_target(self, slot: int, window: int = 1) -> None:
        """Copy-on-write: if any block about to receive one of this slot's
        next ``window`` tokens is shared, swap in a private copy.
        Structurally this does not arise from prefix sharing, fork
        groups or decode-block registration alone (shared / registered
        blocks are always FULL — writes land strictly past them), but a
        preempted request replaying through a partial cache hit, or an
        external fork, can put a shared block under the write cursor."""
        lo = int(self.lengths[slot]) // self.block_size
        hi = (int(self.lengths[slot]) + window - 1) // self.block_size
        for widx in range(lo, min(hi, len(self.blocks_of[slot]) - 1) + 1):
            old = self.blocks_of[slot][widx]
            if not self.prefix.is_write_shared(old):
                continue
            got = self.prefix.alloc(1)
            if got is None:
                raise RuntimeError(
                    f"pool exhausted breaking a copy-on-write share of "
                    f"block {old} (slot {slot}); increase num_blocks")
            self.blocks_of[slot][widx] = got[0]
            self.block_table[slot, widx] = got[0]
            self.prefix.release([old])
            self.prefix.count_cow()
            self.cow_pending.append((old, got[0]))

    def drain_cow(self) -> List[Tuple[int, int]]:
        """Hand the queued (src, dst) copy-on-write block copies to the
        engine (which owns the device pool) and clear the queue."""
        out, self.cow_pending = self.cow_pending, []
        return out

    def _preempt_youngest(self) -> Tuple[Request, int]:
        slot = self._admit_order[-1]
        req = self.slots[slot]
        # recompute-style: prompt + generated so far re-enter the queue as
        # a longer prompt (per-position-keyed sampling makes the replay
        # identical — see engine._sample)
        req.prompt = np.concatenate(
            [req.prompt, np.asarray(req.tokens, np.int32)])
        req.max_new -= len(req.tokens)
        req.tokens = []
        req.n_preempted += 1
        req.preempt_ts.append(perf_counter())
        self._release_slot(slot)
        self.waiting.appendleft(req)
        return req, slot

    def record_prefill_sample(self, slot: int, tok: int,
                              step: int = 0) -> Optional[Request]:
        """Account the token sampled from the PREFILL logits (generated
        token #1 — sampled but not yet written to the cache).  Returns the
        request if that already finishes it (max_new == 1, or a
        single-token stop sequence)."""
        req = self.slots[slot]
        req.tokens.append(int(tok))
        if req.first_tok_t < 0:
            req.first_tok_t = perf_counter()
        self._check_stop(req)
        if req.done:
            return self._finish(slot, step)
        return None

    def advance(self, sampled: Dict[int, int], step: int = 0) -> List[Request]:
        """Account one decode step: ``sampled[slot]`` is the token the step
        just produced for that slot; the token fed INTO the step is now in
        the cache (lengths += 1).  Finished requests are evicted and their
        blocks released (trie-registered ones stay LRU-evictable).
        Returns the requests finished this step."""
        return self.advance_multi({s: [t] for s, t in sampled.items()}, step)

    def advance_multi(self, emitted: Dict[int, List[int]],
                      step: int = 0) -> List[Request]:
        """Account one SPECULATIVE round: ``emitted[slot]`` is the ordered
        list of tokens the verify step produced for that slot (accepted
        drafts + one bonus/correction token, at most the slot's write
        window).  The cache gained the fed token plus every accepted draft
        — lengths += len(emitted); the LAST emitted token is the new
        pending token (not yet written; rejected drafts' latents sit past
        ``lengths`` and are overwritten before they can ever be attended).
        Len-1 lists degrade to plain :meth:`advance`.  Returns the
        requests finished this round."""
        done: List[Request] = []
        for slot, toks in emitted.items():
            req = self.slots[slot]
            if req is None or not toks:
                continue
            if len(toks) > self._window(req):
                raise ValueError(
                    f"slot {slot}: {len(toks)} emitted tokens exceed the "
                    f"write window {self._window(req)}")
            # token-at-a-time so a stop hit is token-exact: tokens after
            # the match (later accepted drafts in a spec round) are
            # discarded, never accounted.
            for t in toks:
                self.lengths[slot] += 1
                req.tokens.append(int(t))
                if int(self.lengths[slot]) % self.block_size == 0:
                    # a block of generated latents just completed — make
                    # it matchable (multi-turn decode-block reuse)
                    self.register_decode_blocks(slot)
                if self._check_stop(req) or req.done:
                    break
            if req.done:
                done.append(self._finish(slot, step))
        return done

    def _check_stop(self, req: Request) -> bool:
        """True if the output's suffix now matches one of the request's
        stop sequences; marks it finished ("stop") and hides the matched
        tokens from ``output``.  Matched against ``output`` (not just
        ``tokens``) so a sequence spanning a preemption fold still hits."""
        if not req.stop or req.finish_reason:
            return bool(req.finish_reason)
        out = list(req.prompt[req.orig_plen:]) + list(req.tokens)
        for seq in req.stop:
            n = len(seq)
            if n and n <= len(out) and out[-n:] == [int(t) for t in seq]:
                req.finish_reason = "stop"
                req.n_trunc = n
                return True
        return False

    def _finish(self, slot: int, step: int) -> Request:
        """Evict a finished request from its slot and account it."""
        req = self.slots[slot]
        if not req.finish_reason:
            req.finish_reason = "length"
        req.finished_step = step
        req.finish_t = perf_counter()
        self._release_slot(slot)
        self.finished.append(req)
        return req

    def cancel(self, rid: int, step: int = 0) -> Optional[Request]:
        """Abort a request wherever it is.  Waiting requests leave the
        queue; running requests release their slot and blocks (trie-
        registered prefix blocks stay cached and unpoisoned — the pool
        contents they index are still valid prompt latents).  Fork
        groups: cancelling a not-yet-forked waiting parent takes its
        children with it; cancelling a single not-yet-forked child just
        shrinks the group; post-fork, every member is an ordinary
        independent request and cancels alone.  Unknown or already-
        finished rids are a no-op.  Returns the cancelled request
        (``finish_reason == "cancelled"``) or None."""
        def retire(r: Request) -> Request:
            r.finish_reason = "cancelled"
            r.finished_step = step
            r.finish_t = perf_counter()
            self.finished.append(r)
            return r

        for req in self.waiting:
            if req.rid == rid:
                self.waiting.remove(req)
                if not req.forked:
                    # pre-admission children exist only as attachments
                    for child in req.fork_children:
                        retire(child)
                    req.fork_children = []
                return retire(req)
            if not req.forked:
                for child in req.fork_children:
                    if child.rid == rid:
                        req.fork_children.remove(child)
                        return retire(child)
        for slot in self.active_slots:
            req = self.slots[slot]
            if req.rid == rid:
                req.finish_reason = "cancelled"
                return self._finish(slot, step)
        return None

    def _release_slot(self, slot: int) -> None:
        self.prefix.release(self.blocks_of.pop(slot))
        req = self.slots[slot]
        req.slot = -1
        self.slots[slot] = None
        self.block_table[slot] = NULL_BLOCK
        self.lengths[slot] = 0
        self._admit_order.remove(slot)

    # ------------------------------------------------------------- stats ---

    def utilization(self) -> Dict[str, float]:
        """valid_frac: valid tokens / allocated slots (internal
        fragmentation); pool_frac: allocated blocks / pool size (cached
        refcount-0 blocks are counted separately as cached_blocks)."""
        alloc_blocks = sum(len(v) for v in self.blocks_of.values())
        valid = int(self.lengths[self.active_slots].sum()) \
            if self.active_slots else 0
        return {
            "valid_frac": valid / (alloc_blocks * self.block_size)
            if alloc_blocks else 0.0,
            "pool_frac": alloc_blocks / (self.allocator.num_blocks - 1),
            "valid_tokens": float(valid),
            "allocated_blocks": float(alloc_blocks),
            "cached_blocks": float(self.prefix.num_evictable),
        }
