from . import ops, ref
from .flash_attention import flash_attention
from .mla_decode import mla_decode_kernel
from .mla_prefill import mla_prefill_paged_kernel
from .ops import attention, mla_decode_attention, mla_prefill_paged_attention

__all__ = ["ops", "ref", "flash_attention", "mla_decode_kernel",
           "mla_prefill_paged_kernel", "attention", "mla_decode_attention",
           "mla_prefill_paged_attention"]
