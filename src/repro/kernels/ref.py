"""Pure-jnp oracles for the Pallas kernels (tests assert allclose vs these)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, q_offset: int = 0,
                        softmax_scale: Optional[float] = None):
    """q: (B, H, Lq, Dqk); k, v: (B, Hkv, Lk, D*). Returns (B, H, Lq, Dv).

    Dense reference with fp32 softmax. ``q_offset``: absolute position of
    q[,:,0] (for chunked prefill); causal mask uses absolute positions.
    """
    B, H, Lq, Dqk = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dqk ** -0.5
    qg = q.reshape(B, Hkv, G, Lq, Dqk).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Lq)
    k_pos = jnp.arange(Lk)
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Lq, v.shape[-1]).astype(q.dtype)


def mla_decode_ref(q_full, ckv, krope, index, *,
                   softmax_scale: Optional[float] = None):
    """Absorbed-MLA decode oracle (MQA-style attention in latent space).

    q_full : (B, H, Dl+Dr)  = [q_latent ; q_rope]
    ckv    : (B, S, Dl); krope: (B, S, Dr)  — split latent cache
    index  : scalar — position of the newest token (attend to pos <= index)
    Returns (B, H, Dl): attention-weighted latent values.
    """
    B, H, D = q_full.shape
    S, Dl = ckv.shape[1], ckv.shape[2]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache = jnp.concatenate([ckv, krope], axis=-1)
    s = jnp.einsum("bhd,bsd->bhs", q_full.astype(jnp.float32),
                   cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S) <= index
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bsk->bhk", p, ckv.astype(jnp.float32))
    return o.astype(q_full.dtype)


def _dequant_gathered(pages, scales, bt, B, flat):
    """Gather pool pages through the block table and dequantize the
    gathered view in f32 (per-token-slot scales, shape (N, bs, 1))."""
    x = pages[bt].reshape(B, flat, pages.shape[-1])
    if scales is None:
        return x
    return x.astype(jnp.float32) * scales[bt].reshape(B, flat, 1)


def mla_prefill_paged_ref(q_full, ckv_pages, krope_pages, block_tables,
                          lengths, n_valid, *,
                          softmax_scale: Optional[float] = None,
                          ckv_scales=None, krope_scales=None):
    """Paged chunked-prefill oracle (multi-query sibling of
    :func:`mla_decode_paged_ref`).

    q_full      : (B, C, H, Dl+Dr) — chunk queries in the joint latent
                  space ([q_eff ; q_rope], any absorption scheme)
    ckv_pages   : (N, bs, Dl); krope_pages: (N, bs, Dr) — global pool
                  (the chunk's own latents are already scattered in)
    block_tables: (B, nb) int32; lengths: (B,) int32 — absolute position
                  of each row's first chunk token; n_valid: (B,) int32 —
                  real tokens per row (rows past it, and idle rows with
                  n_valid 0, yield EXACT ZEROS, matching the kernel).
    Returns (B, C, H, Dl).

    Gathers each request's pages into a contiguous view and reduces with
    a causal mask over absolute positions (chunk token c attends pool
    positions <= lengths[b] + c).  The Pallas kernel reads the pool in
    place instead (no gather) — this is the numerics oracle, not the
    deployment path.
    """
    B, C, H, D = q_full.shape
    bt = jnp.asarray(block_tables, jnp.int32)
    nb, bs = bt.shape[1], ckv_pages.shape[1]
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    ckv = _dequant_gathered(ckv_pages, ckv_scales, bt, B, nb * bs)
    krope = _dequant_gathered(krope_pages, krope_scales, bt, B, nb * bs)
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache = jnp.concatenate([ckv, krope], axis=-1)
    s = jnp.einsum("bchd,bsd->bchs", q_full.astype(jnp.float32),
                   cache.astype(jnp.float32)) * scale
    q_pos = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    k_pos = jnp.arange(nb * bs, dtype=jnp.int32)
    valid = (k_pos[None, None, :] <= q_pos[:, :, None]) \
        & (jnp.arange(C, dtype=jnp.int32)[None, :, None] < n_valid[:, None, None])
    s = jnp.where(valid[:, :, None, :], s, NEG_INF)
    p = jnp.where(valid[:, :, None, :], jax.nn.softmax(s, axis=-1), 0.0)
    o = jnp.einsum("bchs,bsk->bchk", p, ckv.astype(jnp.float32))
    return o.astype(q_full.dtype)


def mla_decode_paged_ref(q_full, ckv_pages, krope_pages, block_tables,
                         indices, *, softmax_scale: Optional[float] = None,
                         ckv_scales=None, krope_scales=None):
    """Paged absorbed-MLA decode oracle.

    q_full      : (B, H, Dl+Dr)
    ckv_pages   : (N, bs, Dl); krope_pages: (N, bs, Dr) — global block pool
    block_tables: (B, nb) int32 — request-local block j -> pool block
    indices     : (B,) int32 — newest valid position per request (attend to
                  pos <= indices[b]; a negative index yields a zero row).
    Returns (B, H, Dl).

    Gathers each request's pages into a contiguous view and reduces exactly
    like :func:`mla_decode_ref` with a per-request mask.  The Pallas kernel
    reads the pool in place instead (no gather) — this is the numerics
    oracle, not the deployment path.
    """
    B, H, D = q_full.shape
    bt = jnp.asarray(block_tables, jnp.int32)
    nb, bs = bt.shape[1], ckv_pages.shape[1]
    idx = jnp.asarray(indices, jnp.int32)
    ckv = _dequant_gathered(ckv_pages, ckv_scales, bt, B, nb * bs)
    krope = _dequant_gathered(krope_pages, krope_scales, bt, B, nb * bs)
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    cache = jnp.concatenate([ckv, krope], axis=-1)
    s = jnp.einsum("bhd,bsd->bhs", q_full.astype(jnp.float32),
                   cache.astype(jnp.float32)) * scale
    valid = jnp.arange(nb * bs)[None, :] <= idx[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jnp.where(valid[:, None, :], jax.nn.softmax(s, axis=-1), 0.0)
    o = jnp.einsum("bhs,bsk->bhk", p, ckv.astype(jnp.float32))
    return o.astype(q_full.dtype)
