"""Pallas TPU kernel for paged chunked-prefill MLA attention: the
multi-query sibling of ``kernels/mla_decode.mla_decode_paged_kernel``.

One CHUNK of batched prefill feeds C query tokens per request, already
mapped into the joint latent space (q_full = [q_eff(D_kvl) ; q_rope(D_r)]
— any of the seq/rc/ru absorption schemes; they differ only in how q_eff
was produced).  K = V = the shared paged latent pool.  The kernel walks
each request's block table via scalar prefetch and runs fused
score/online-softmax/PV per pool block, so the resident prefix streams
HBM->VMEM exactly once per chunk and NO contiguous (B, S) gather of the
block-table view is ever materialized in HBM — the reference gather path
(core.mla gather branch) writes + re-reads that view every chunk, which
is exactly the bandwidth the paper's roofline says the compute-bound
prefill phase cannot afford (see hwmodel.attention_costs
.mla_prefill_chunk_cost for the closed-form delta).

TPU mapping:
  grid (B, nq, nb) — kv-blocks innermost (sequential), query tiles of
  ``block_q`` chunk rows next, batch outermost.  Online-softmax state
  lives in VMEM scratch shaped (block_q*H, D_kvl): per-instance VMEM at
  H=128, C=32(bq=16), D=576, bs=128: q 16*128x576x4 = 4.5 MB, pool block
  128x576x4 = 288 KB, scores 2048x128x4 = 1 MB, acc 2048x512x4 = 4 MB
  => ~10 MB (tighten block_q for bigger chunks).

Ragged semantics (shared with core.cache / runtime.scheduler):
  * ``lengths[b]`` — absolute position of row b's FIRST chunk token
    (tokens already resident: prefix-cache hits + earlier chunks).
  * ``n_valid[b]`` — real tokens in row b's chunk; rows past it are
    padding and produce EXACT ZEROS (their l stays 0), as do idle batch
    rows (n_valid == 0) — the engine discards them either way, but zeros
    keep kernel/oracle parity assertable everywhere.
  * causal over absolute positions: chunk token c attends pool positions
    <= lengths[b] + c.  The chunk's own latents are scattered into the
    pool BEFORE the kernel runs (update_latent_paged_chunk), so the
    in-chunk causal triangle rides the same block-table walk.
  * unassigned block-table entries point at the null block 0; blocks
    fully beyond the last valid position skip their compute via pl.when
    (the DMA'd null/stale block is never read by the math).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mla_decode import softmax_tile_update

NEG_INF = -2.0 ** 30


def _prefill_kernel(bt_ref, len_ref, nv_ref, q_ref, ckv_ref, krope_ref,
                    *rest, scale, v_dim, bq, H, bs, nb, rescale, quantized):
    if quantized:
        ckv_s_ref, krope_s_ref, o_ref, acc, m_sc, l_sc = rest
    else:
        o_ref, acc, m_sc, l_sc = rest
    b = pl.program_id(0)
    iq = pl.program_id(1)
    j = pl.program_id(2)
    start = len_ref[b]                  # absolute position of chunk row 0
    nv = nv_ref[b]                      # valid rows in this request's chunk

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    # newest position any valid row of THIS query tile may attend; blocks
    # past it (and tiles wholly past n_valid) skip their compute.
    last_q = start + jnp.minimum(nv, (iq + 1) * bq) - 1

    @pl.when((iq * bq < nv) & (j * bs <= last_q))
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(bq * H, -1)  # (bq*H, Dl+Dr)
        ckv = ckv_ref[0].astype(jnp.float32)                  # (bs, Dl)
        krope = krope_ref[0].astype(jnp.float32)              # (bs, Dr)
        if quantized:
            # dequant in-register: per-token-slot f32 scales DMA'd through
            # the same block-table index_map as the data block
            ckv = ckv * ckv_s_ref[0]                          # (bs, 1)
            krope = krope * krope_s_ref[0]
        # two-term scores on the split pool (no fused [ckv|krope] copy)
        s = (jax.lax.dot_general(q[:, :v_dim], ckv, (((1,), (1,)), ((), ())))
             + jax.lax.dot_general(q[:, v_dim:], krope,
                                   (((1,), (1,)), ((), ())))) * scale
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        c = iq * bq + row // H          # chunk-row index of each score row
        k_pos = j * bs + col            # absolute pool position
        mask = (k_pos <= start + c) & (c < nv)
        s = jnp.where(mask, s, NEG_INF)
        softmax_tile_update(s, mask, ckv, acc, m_sc, l_sc, rescale=rescale)

    @pl.when(j == nb - 1)
    def _done():
        l = l_sc[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc[...] / l_safe).reshape(bq, H, v_dim).astype(o_ref.dtype)


def mla_prefill_paged_kernel(q_full, ckv_pages, krope_pages, block_tables,
                             lengths, n_valid, *,
                             softmax_scale: Optional[float] = None,
                             block_q: int = 0,
                             ckv_scales=None, krope_scales=None,
                             rescale: str = "exp_add",
                             interpret: Optional[bool] = None):
    """Paged chunked-prefill flash attention over the latent block pool.

    q_full (B, C, H, Dl+Dr); ckv_pages (N, bs, Dl); krope_pages
    (N, bs, Dr); block_tables (B, nb) int32; lengths (B,) int32 —
    absolute position of each row's first chunk token; n_valid (B,)
    int32 — real tokens per row (0 = idle slot -> zero output rows).
    ``block_q``: query-tile rows (0 = whole chunk; C is padded up to a
    tile multiple, pad rows return zeros).  Returns (B, C, H, Dl).

    Block tables, lengths and n_valid all ride the scalar-prefetch
    operand: the BlockSpec index_map dereferences ``block_tables[b, j]``
    so each grid step DMAs exactly one pool block HBM->VMEM — the
    single-stream property of the paged decode kernel, generalized to C
    causal query positions.

    For a QUANTIZED pool pass ``ckv_scales``/``krope_scales`` (N, bs, 1)
    f32 — dequant happens in-register per pool block.  ``rescale``
    selects the online-softmax correction: 'exp_add' (AMLA exponent
    addition, default) or 'mul' (classic FlashAttention).
    """
    B, C, H, D = q_full.shape
    v_dim, dr = ckv_pages.shape[-1], krope_pages.shape[-1]
    bs = ckv_pages.shape[1]
    nb = block_tables.shape[1]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    quantized = ckv_scales is not None
    if quantized != (krope_scales is not None):
        raise ValueError("pass both ckv_scales and krope_scales or neither")
    bq = C if block_q <= 0 else min(block_q, C)
    pad = -C % bq
    if pad:
        q_full = jnp.pad(q_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q_full.shape[1] // bq
    kernel = functools.partial(_prefill_kernel, scale=scale, v_dim=v_dim,
                               bq=bq, H=H, bs=bs, nb=nb, rescale=rescale,
                               quantized=quantized)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    in_specs = [
        pl.BlockSpec((1, bq, H, D),
                     lambda b, iq, j, bt, ln, nv: (b, iq, 0, 0)),
        pl.BlockSpec((1, bs, v_dim),
                     lambda b, iq, j, bt, ln, nv: (bt[b, j], 0, 0)),
        pl.BlockSpec((1, bs, dr),
                     lambda b, iq, j, bt, ln, nv: (bt[b, j], 0, 0)),
    ]
    operands = [block_tables, lengths, n_valid, q_full, ckv_pages,
                krope_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1),
                         lambda b, iq, j, bt, ln, nv: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, iq, j, bt, ln, nv: (bt[b, j], 0, 0)),
        ]
        operands += [ckv_scales, krope_scales]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, nq, nb),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, bq, H, v_dim),
                lambda b, iq, j, bt, ln, nv: (b, iq, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq * H, v_dim), jnp.float32),
                pltpu.VMEM((bq * H, 1), jnp.float32),
                pltpu.VMEM((bq * H, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, nq * bq, H, v_dim), q_full.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :C] if pad else out
