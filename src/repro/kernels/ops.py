"""Public jit'd wrappers for the Pallas kernels + shard_map builders.

Models call attention through these so the implementation is swappable:
  impl='ref'    pure-jnp dense reference (GSPMD partitions it freely)
  impl='kernel' Pallas kernel (interpret=True on CPU), wrapped in shard_map
                when a mesh is active so each device runs the kernel on its
                local shard (batch over DP axes, heads over 'model').
"""
from __future__ import annotations

import functools
from typing import Optional

from jax.sharding import Mesh, PartitionSpec as PS

from .. import compat
from . import ref
from .flash_attention import flash_attention
from .mla_decode import mla_decode_kernel, mla_decode_paged_kernel
from .mla_prefill import mla_prefill_paged_kernel


def attention(q, k, v, *, impl: str = "ref", causal: bool = True,
              window: Optional[int] = None, q_offset: int = 0,
              softmax_scale: Optional[float] = None,
              mesh: Optional[Mesh] = None, dp_axes=None):
    """q: (B, H, Lq, Dqk); k, v: (B, Hkv, Lk, D). Returns (B, H, Lq, Dv)."""
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset, softmax_scale=softmax_scale)
    fn = functools.partial(flash_attention, causal=causal, window=window,
                           q_offset=q_offset, softmax_scale=softmax_scale)
    if mesh is None:
        return fn(q, k, v)
    dp = dp_axes if dp_axes is not None else tuple(
        a for a in ("pod", "data") if a in mesh.axis_names)
    qs = PS(dp, "model", None, None)
    return compat.shard_map(fn, mesh=mesh, in_specs=(qs, qs, qs),
                            out_specs=qs, check_vma=False)(q, k, v)


def mla_decode_attention(q_full, ckv, krope, index, *, impl: str = "ref",
                         softmax_scale: Optional[float] = None,
                         mesh: Optional[Mesh] = None, dp_axes=None,
                         block_k: int = 512):
    """Absorbed-MLA decode: q_full (B,H,Dl+Dr), ckv (B,S,Dl), krope
    (B,S,Dr) -> (B,H,Dl).

    Under shard_map: batch over DP axes, heads over 'model'; the latent
    cache is head-shared so it is REPLICATED over 'model' (the MQA
    structure of absorbed MLA — each model shard re-reads the same cache,
    which is the paper's bandwidth win: the cache is ~16x smaller than a
    dense KV cache, so n_model re-reads still move less data)."""
    if impl == "ref":
        return ref.mla_decode_ref(q_full, ckv, krope, index,
                                  softmax_scale=softmax_scale)
    fn = functools.partial(mla_decode_kernel,
                           softmax_scale=softmax_scale, block_k=block_k)
    if mesh is None:
        return fn(q_full, ckv, krope, index)
    dp = dp_axes if dp_axes is not None else tuple(
        a for a in ("pod", "data") if a in mesh.axis_names)
    return compat.shard_map(
        lambda q, c, r, i: fn(q, c, r, i), mesh=mesh,
        in_specs=(PS(dp, "model", None), PS(dp, None, None),
                  PS(dp, None, None), PS()),
        out_specs=PS(dp, "model", None), check_vma=False,
    )(q_full, ckv, krope, index)


def mla_decode_paged_attention(q_full, ckv_pages, krope_pages, block_tables,
                               indices, *, impl: str = "ref",
                               softmax_scale: Optional[float] = None,
                               ckv_scales=None, krope_scales=None,
                               rescale: str = "exp_add",
                               mesh: Optional[Mesh] = None, dp_axes=None):
    """Paged absorbed-MLA decode: q_full (B,H,Dl+Dr), pool pages
    (N,bs,Dl)/(N,bs,Dr), block_tables (B,nb), per-request ``indices``
    (B,) -> (B,H,Dl).

    Quantized pools pass per-token-slot ``ckv_scales``/``krope_scales``
    (N,bs,1) f32: the kernel dequantizes in-register, the ref oracle on
    the gathered f32 view.  ``rescale`` picks the kernel's online-softmax
    correction (AMLA 'exp_add' or classic 'mul'); the oracle's exact
    softmax ignores it.

    Under shard_map the batch (and with it the block tables / indices)
    shards over the DP axes and heads over 'model'; the block POOL (data
    and scale leaves alike) is replicated over 'model' exactly like the
    contiguous latent cache (the MQA structure of absorbed MLA: head
    shards re-read the same compact pool)."""
    if impl == "ref":
        return ref.mla_decode_paged_ref(q_full, ckv_pages, krope_pages,
                                        block_tables, indices,
                                        softmax_scale=softmax_scale,
                                        ckv_scales=ckv_scales,
                                        krope_scales=krope_scales)
    quantized = ckv_scales is not None
    if mesh is None:
        return mla_decode_paged_kernel(
            q_full, ckv_pages, krope_pages, block_tables, indices,
            softmax_scale=softmax_scale, ckv_scales=ckv_scales,
            krope_scales=krope_scales, rescale=rescale)
    dp = dp_axes if dp_axes is not None else tuple(
        a for a in ("pod", "data") if a in mesh.axis_names)
    in_specs = [PS(dp, "model", None), PS(None, None, None),
                PS(None, None, None), PS(dp, None), PS(dp)]
    operands = [q_full, ckv_pages, krope_pages, block_tables, indices]
    if quantized:
        in_specs += [PS(None, None, None), PS(None, None, None)]
        operands += [ckv_scales, krope_scales]

        def fn(q, c, r, t, i, cs, rs):
            return mla_decode_paged_kernel(
                q, c, r, t, i, softmax_scale=softmax_scale,
                ckv_scales=cs, krope_scales=rs, rescale=rescale)
    else:
        def fn(q, c, r, t, i):
            return mla_decode_paged_kernel(
                q, c, r, t, i, softmax_scale=softmax_scale, rescale=rescale)
    return compat.shard_map(
        fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=PS(dp, "model", None), check_vma=False,
    )(*operands)


def mla_prefill_paged_attention(q_full, ckv_pages, krope_pages, block_tables,
                                lengths, n_valid, *, impl: str = "ref",
                                softmax_scale: Optional[float] = None,
                                ckv_scales=None, krope_scales=None,
                                rescale: str = "exp_add",
                                mesh: Optional[Mesh] = None, dp_axes=None,
                                block_q: int = 0):
    """Paged chunked-prefill MLA attention: q_full (B,C,H,Dl+Dr), pool
    pages (N,bs,Dl)/(N,bs,Dr), block_tables (B,nb), per-request
    ``lengths``/``n_valid`` (B,) -> (B,C,H,Dl).

    Quantized pools pass ``ckv_scales``/``krope_scales`` (N,bs,1) f32 and
    ``rescale`` picks the kernel's online-softmax correction — see
    :func:`mla_decode_paged_attention`.

    The multi-query sibling of :func:`mla_decode_paged_attention`: under
    shard_map the batch (and with it the block tables / lengths /
    n_valid) shards over the DP axes and heads over 'model'; the block
    POOL is replicated over 'model' (the MQA structure of absorbed MLA —
    head shards re-read the same compact pool, which is the paper's
    bandwidth win: the latent pool is ~16x smaller than dense KV)."""
    if impl == "ref":
        return ref.mla_prefill_paged_ref(q_full, ckv_pages, krope_pages,
                                         block_tables, lengths, n_valid,
                                         softmax_scale=softmax_scale,
                                         ckv_scales=ckv_scales,
                                         krope_scales=krope_scales)
    quantized = ckv_scales is not None
    kfn = functools.partial(mla_prefill_paged_kernel,
                            softmax_scale=softmax_scale, block_q=block_q,
                            rescale=rescale)
    if mesh is None:
        return kfn(q_full, ckv_pages, krope_pages, block_tables, lengths,
                   n_valid, ckv_scales=ckv_scales, krope_scales=krope_scales)
    dp = dp_axes if dp_axes is not None else tuple(
        a for a in ("pod", "data") if a in mesh.axis_names)
    in_specs = [PS(dp, None, "model", None), PS(None, None, None),
                PS(None, None, None), PS(dp, None), PS(dp), PS(dp)]
    operands = [q_full, ckv_pages, krope_pages, block_tables, lengths,
                n_valid]
    if quantized:
        in_specs += [PS(None, None, None), PS(None, None, None)]
        operands += [ckv_scales, krope_scales]

        def fn(q, c, r, t, ln, nv, cs, rs):
            return kfn(q, c, r, t, ln, nv, ckv_scales=cs, krope_scales=rs)
    else:
        def fn(q, c, r, t, ln, nv):
            return kfn(q, c, r, t, ln, nv)
    return compat.shard_map(
        fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=PS(dp, None, "model", None), check_vma=False,
    )(*operands)
