"""Pallas TPU flash attention (forward + backward), GQA + sliding window.

TPU mapping (see DESIGN.md §9):
  grid (B, H, nq, nk) — nk innermost; TPU executes the grid sequentially, so
  the online-softmax state (acc/m/l) lives in VMEM scratch across kv blocks.
  Block shapes are MXU-aligned (q/k/v blocks 128 x D); with D<=576 the
  per-instance VMEM footprint is ~1.2 MB, far under the ~128 MB/core budget.

Numerics: fp32 accumulation, finite -2^30 mask value + explicit p=0 on
masked lanes (avoids inf-inf NaNs for fully-masked rows).

Validated on CPU with ``interpret=True`` against ``ref.flash_attention_ref``
(tests/test_kernels.py sweeps shapes, dtypes, GQA groups, windows).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _mask(iq, ik, *, block_q, block_k, q_offset, lk_valid, causal, window):
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    m = k_pos < lk_valid
    if causal:
        m &= k_pos <= q_pos
    if window is not None:
        m &= k_pos > (q_pos - window)
    return m


# ------------------------------------------------------------- forward -----


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_sc, l_sc, *,
                scale, causal, window, q_offset, block_q, block_k, nk, lk_valid):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask(iq, ik, block_q=block_q, block_k=block_k, q_offset=q_offset,
                 lk_valid=lk_valid, causal=causal, window=window)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * corr + p @ v
    m_sc[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = l_sc[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_sc[...] + jnp.log(l_safe))[:, 0]


def _fwd(q, k, v, *, causal, window, q_offset, scale, block_q, block_k, interpret):
    B, H, Lq, Dqk = q.shape
    Hkv, Lk, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    bq, bk = min(block_q, Lq), min(block_k, Lk)
    lq_pad = -Lq % bq
    lk_pad = -Lk % bk
    if lq_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad), (0, 0)))
    if lk_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, lk_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, lk_pad), (0, 0)))
    nq, nk = q.shape[2] // bq, k.shape[2] // bk
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=bq, block_k=bk, nk=nk, lk_valid=Lk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dqk), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dqk), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, q.shape[2], Dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, q.shape[2]), jnp.float32),
        ],
        scratch_shapes=[pl_scratch((bq, Dv)), pl_scratch((bq, 1)), pl_scratch((bq, 1))],
        interpret=interpret,
    )(q, k, v)
    if lq_pad:
        out, lse = out[:, :, :Lq], lse[:, :, :Lq]
    return out, lse


def pl_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# ------------------------------------------------------------ backward -----


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref, dq_acc, *,
               scale, causal, window, q_offset, block_q, block_k, nk, lk_valid):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = dl_ref[0, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask(iq, ik, block_q=block_q, block_k=block_k, q_offset=q_offset,
                 lk_valid=lk_valid, causal=causal, window=window)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta) * scale
    dq_acc[...] += ds @ k

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dk_ref, dv_ref,
                dk_acc, dv_acc, *, scale, causal, window, q_offset, block_q,
                block_k, nq, G, lk_valid):
    ik, g, iq = pl.program_id(2), pl.program_id(3), pl.program_id(4)

    @pl.when((g == 0) & (iq == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = dl_ref[0, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask(iq, ik, block_q=block_q, block_k=block_k, q_offset=q_offset,
                 lk_valid=lk_valid, causal=causal, window=window)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta) * scale
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when((g == G - 1) & (iq == nq - 1))
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


# ----------------------------------------------------------- public API ----


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, softmax_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, H, Lq, Dqk); k, v: (B, Hkv, Lk, Dqk/Dv) -> (B, H, Lq, Dv)."""
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, softmax_scale,
                        block_q, block_k, interpret)
    return out


def _resolve(softmax_scale, Dqk, interpret):
    scale = softmax_scale if softmax_scale is not None else Dqk ** -0.5
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return scale, interpret


def _flash_fwd(q, k, v, causal, window, q_offset, softmax_scale, block_q,
               block_k, interpret):
    scale, interpret = _resolve(softmax_scale, q.shape[-1], interpret)
    out, lse = _fwd(q, k, v, causal=causal, window=window, q_offset=q_offset,
                    scale=scale, block_q=block_q, block_k=block_k,
                    interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, softmax_scale, block_q, block_k,
               interpret, res, dout):
    q, k, v, out, lse = res
    B, H, Lq, Dqk = q.shape
    Hkv, Lk, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    scale, interpret = _resolve(softmax_scale, Dqk, interpret)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    bq, bk = min(block_q, Lq), min(block_k, Lk)
    lq_pad, lk_pad = -Lq % bq, -Lk % bk
    pad4 = lambda x, n: jnp.pad(x, ((0, 0), (0, 0), (0, n), (0, 0))) if n else x
    pad3 = lambda x, n: jnp.pad(x, ((0, 0), (0, 0), (0, n))) if n else x
    qp, kp, vp = pad4(q, lq_pad), pad4(k, lk_pad), pad4(v, lk_pad)
    dop, lsep, dlp = pad4(dout, lq_pad), pad3(lse, lq_pad), pad3(delta, lq_pad)
    # padded lse rows are 0 -> p = exp(-2^30 - 0) = 0: padded q rows are inert
    nq, nk = qp.shape[2] // bq, kp.shape[2] // bk

    dq_kernel = functools.partial(
        _dq_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=bq, block_k=bk, nk=nk, lk_valid=Lk)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dqk), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dqk), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dqk), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[pl_scratch((bq, Dqk))],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dlp)

    dkv_kernel = functools.partial(
        _dkv_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=bq, block_k=bk, nq=nq, G=G, lk_valid=Lk)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(B, Hkv, nk, G, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dqk), lambda b, hk, j, g, i: (b, hk * G + g, i, 0)),
            pl.BlockSpec((1, 1, bk, Dqk), lambda b, hk, j, g, i: (b, hk, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, hk, j, g, i: (b, hk, j, 0)),
            pl.BlockSpec((1, 1, bq, Dv), lambda b, hk, j, g, i: (b, hk * G + g, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, hk, j, g, i: (b, hk * G + g, i)),
            pl.BlockSpec((1, 1, bq), lambda b, hk, j, g, i: (b, hk * G + g, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, Dqk), lambda b, hk, j, g, i: (b, hk, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, hk, j, g, i: (b, hk, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(kp.shape, k.dtype),
            jax.ShapeDtypeStruct(vp.shape, v.dtype),
        ],
        scratch_shapes=[pl_scratch((bk, Dqk)), pl_scratch((bk, Dv))],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dlp)

    if lq_pad:
        dq = dq[:, :, :Lq]
    if lk_pad:
        dk, dv = dk[:, :, :Lk], dv[:, :, :Lk]
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
