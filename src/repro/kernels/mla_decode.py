"""Pallas TPU kernel for absorbed-MLA decode attention (the paper's object
of study): MQA-style flash-decoding over the *latent* KV cache.

After weight absorption (any of the seq/rc/ru schemes), each head's query
lives in the joint latent space  q_full = [q_latent(D_kvl) ; q_rope(D_r)]
and K = V = the shared latent cache  [ckv ; k_rope]  — a single "KV head"
shared by all n_h query heads.  This kernel fuses score, online softmax and
value reduction so the cache streams HBM->VMEM exactly once and no
(B, H, S) score tensor ever exists in HBM — the fused execution the paper
assumes ("it is crucial that the resulting, larger weight matrix remains
on-chip"; here the analogous requirement is that scores/softmax state stay
in VMEM).

TPU mapping:
  grid (B, nk) — kv-blocks innermost (sequential), online-softmax state in
  VMEM scratch.  Per-instance VMEM at H=128, D=576, block_k=512:
  q 128x576x4 = 295 KB, cache block 512x576x4 = 1.2 MB, scores 128x512x4
  = 262 KB, acc 128x512x4 = 262 KB  => ~2 MB.
  The cache-length ``index`` is a runtime scalar (scalar-prefetch operand);
  kv-blocks entirely beyond ``index`` skip their compute via pl.when.

Two variants share the kernel structure:
  * ``mla_decode_kernel``       — contiguous (B, S, .) cache, one shared
    scalar ``index``.
  * ``mla_decode_paged_kernel`` — paged block pool + per-request block
    tables and ragged ``indices`` (continuous batching); the block table
    rides the scalar-prefetch operand so the BlockSpec index_map gathers
    pool blocks directly (vLLM-style paged attention).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
INV_LN2 = 1.4426950408889634        # log2(e): folds exp into exp2
RESCALES = ("exp_add", "mul")


def exp_add_rescale(x, d_i):
    """x * 2**d_i for f32 ``x`` and int32 ``d_i <= 0`` via IEEE-754 exponent
    ADDITION (AMLA, arxiv 2509.25224): bitcast to int32, add d_i into the
    exponent field, bitcast back.  Zero inputs and exponent underflow
    (biased exponent reaching 0) flush to 0.0; d_i <= 0 never touches the
    sign bit."""
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    exp_field = (bits >> 23) & 0xFF
    shifted = jax.lax.bitcast_convert_type(bits + (d_i << 23), jnp.float32)
    ok = (x != 0.0) & (exp_field + d_i > 0)
    return jnp.where(ok, shifted, 0.0)


def softmax_tile_update(s, mask, ckv, acc, m_sc, l_sc, *, rescale):
    """One online-softmax + PV tile update on VMEM scratch state, shared by
    the decode and prefill kernels.  ``s`` is the scaled score tile with
    masked lanes already at NEG_INF; ``ckv`` the (already dequantized) f32
    value tile.

    rescale='mul'     — classic FlashAttention: real-valued running max,
      state rescaled by corr = exp(m_prev - m_new) multiplies.
    rescale='exp_add' — AMLA-style: base-2 softmax with the running max
      quantized up to an integer, so the correction 2**d
      (d = m_prev - m_new, an integer <= 0) is applied by adding d to the
      exponent bits of the f32 state — the per-tile rescale multiplies on
      acc/l disappear from the inner loop.
    """
    m_prev = m_sc[...]
    if rescale == "mul":
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * corr + p @ ckv
    elif rescale == "exp_add":
        s2 = s * INV_LN2
        m_new = jnp.ceil(
            jnp.maximum(m_prev, jnp.max(s2, axis=1, keepdims=True)))
        p = jnp.where(mask, jnp.exp2(s2 - m_new), 0.0)
        # d <= 0 by construction; anything below -254 zeroes every f32
        # anyway, and the clip keeps d << 23 inside int32.
        d_i = jnp.clip(m_prev - m_new, -254.0, 0.0).astype(jnp.int32)
        l_sc[...] = (exp_add_rescale(l_sc[...], d_i)
                     + jnp.sum(p, axis=1, keepdims=True))
        acc[...] = exp_add_rescale(acc[...], d_i) + p @ ckv
    else:
        raise ValueError(f"unknown rescale {rescale!r}; expected {RESCALES}")
    m_sc[...] = m_new


def _kernel(idx_ref, q_ref, ckv_ref, krope_ref, o_ref, acc, m_sc, l_sc, *,
            scale, v_dim, block_k, nk, rescale):
    ik = pl.program_id(1)
    index = idx_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    @pl.when(ik * block_k <= index)  # skip blocks fully beyond the cache end
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (H, Dl+Dr)
        ckv = ckv_ref[0].astype(jnp.float32)        # (Bk, Dl)
        krope = krope_ref[0].astype(jnp.float32)    # (Bk, Dr)
        # two-term scores on the split cache (no fused [ckv|krope] copy)
        s = (jax.lax.dot_general(q[:, :v_dim], ckv, (((1,), (1,)), ((), ())))
             + jax.lax.dot_general(q[:, v_dim:], krope,
                                   (((1,), (1,)), ((), ())))) * scale
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_pos <= index
        s = jnp.where(mask, s, NEG_INF)
        softmax_tile_update(s, mask, ckv, acc, m_sc, l_sc, rescale=rescale)

    @pl.when(ik == nk - 1)
    def _done():
        l = l_sc[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc[...] / l_safe).astype(o_ref.dtype)


def _paged_kernel(bt_ref, idx_ref, q_ref, ckv_ref, krope_ref, *rest,
                  scale, v_dim, bs, nb, rescale, quantized):
    if quantized:
        ckv_s_ref, krope_s_ref, o_ref, acc, m_sc, l_sc = rest
    else:
        o_ref, acc, m_sc, l_sc = rest
    b = pl.program_id(0)
    j = pl.program_id(1)
    index = idx_ref[b]                      # newest valid position, or -1

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)

    @pl.when(j * bs <= index)   # skip request-local blocks beyond the end
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (H, Dl+Dr)
        ckv = ckv_ref[0].astype(jnp.float32)      # (bs, Dl) — pool block
        krope = krope_ref[0].astype(jnp.float32)  # (bs, Dr)
        if quantized:
            # dequant in-register: one f32 scale per token slot, the block's
            # scales DMA'd alongside it through the same block-table
            # index_map
            ckv = ckv * ckv_s_ref[0]              # (bs, 1) broadcast
            krope = krope * krope_s_ref[0]
        s = (jax.lax.dot_general(q[:, :v_dim], ckv, (((1,), (1,)), ((), ())))
             + jax.lax.dot_general(q[:, v_dim:], krope,
                                   (((1,), (1,)), ((), ())))) * scale
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos <= index
        s = jnp.where(mask, s, NEG_INF)
        softmax_tile_update(s, mask, ckv, acc, m_sc, l_sc, rescale=rescale)

    @pl.when(j == nb - 1)
    def _done():
        l = l_sc[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc[...] / l_safe).astype(o_ref.dtype)


def mla_decode_paged_kernel(q_full, ckv_pages, krope_pages, block_tables,
                            indices, *, softmax_scale: Optional[float] = None,
                            ckv_scales=None, krope_scales=None,
                            rescale: str = "exp_add",
                            interpret: Optional[bool] = None):
    """Paged flash-decode over the latent block pool.

    q_full (B, H, Dl+Dr); ckv_pages (N, bs, Dl); krope_pages (N, bs, Dr);
    block_tables (B, nb) int32; indices (B,) int32 — newest valid position
    per request (ragged; -1 = inactive slot -> zero output).
    Returns (B, H, Dl).

    Both the block table and the per-request indices ride the scalar-
    prefetch operand: the BlockSpec index_map dereferences
    ``block_tables[b, j]`` so each grid step DMAs exactly one pool block
    HBM->VMEM — the single-stream property of the contiguous kernel is
    preserved under paging, and blocks past ``indices[b]`` skip their
    compute (the DMA'd null/stale block is never read by the math).

    For a QUANTIZED pool pass ``ckv_scales``/``krope_scales`` (N, bs, 1)
    f32: each grid step DMAs the block's scales through the same
    block-table index_map and the kernel dequantizes in-register — the
    cache never exists at full precision in HBM.  ``rescale`` selects the
    online-softmax correction: 'exp_add' (AMLA exponent addition, default)
    or 'mul' (classic FlashAttention).
    """
    B, H, D = q_full.shape
    v_dim, dr = ckv_pages.shape[-1], krope_pages.shape[-1]
    bs = ckv_pages.shape[1]
    nb = block_tables.shape[1]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    quantized = ckv_scales is not None
    if quantized != (krope_scales is not None):
        raise ValueError("pass both ckv_scales and krope_scales or neither")
    kernel = functools.partial(_paged_kernel, scale=scale, v_dim=v_dim,
                               bs=bs, nb=nb, rescale=rescale,
                               quantized=quantized)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    indices = jnp.asarray(indices, jnp.int32)
    in_specs = [
        pl.BlockSpec((1, H, D), lambda b, j, bt, idx: (b, 0, 0)),
        pl.BlockSpec((1, bs, v_dim),
                     lambda b, j, bt, idx: (bt[b, j], 0, 0)),
        pl.BlockSpec((1, bs, dr),
                     lambda b, j, bt, idx: (bt[b, j], 0, 0)),
    ]
    operands = [block_tables, indices, q_full, ckv_pages, krope_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1), lambda b, j, bt, idx: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, j, bt, idx: (bt[b, j], 0, 0)),
        ]
        operands += [ckv_scales, krope_scales]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nb),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, H, v_dim),
                                   lambda b, j, bt, idx: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, v_dim), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, v_dim), q_full.dtype),
        interpret=interpret,
    )(*operands)
    return out


def mla_decode_kernel(q_full, ckv, krope, index, *,
                      softmax_scale: Optional[float] = None,
                      block_k: int = 512, rescale: str = "exp_add",
                      interpret: Optional[bool] = None):
    """q_full: (B, H, Dl+Dr) = [q_latent ; q_rope]; ckv: (B, S, Dl);
    krope: (B, S, Dr); index: scalar int32 (newest valid position).
    Returns (B, H, Dl) — attention-weighted latent values."""
    B, H, D = q_full.shape
    S, v_dim = ckv.shape[1], ckv.shape[2]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bk = min(block_k, S)
    pad = -S % bk
    if pad:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        krope = jnp.pad(krope, ((0, 0), (0, pad), (0, 0)))
    nk = ckv.shape[1] // bk
    dr = krope.shape[-1]
    kernel = functools.partial(_kernel, scale=scale, v_dim=v_dim,
                               block_k=bk, nk=nk, rescale=rescale)
    index = jnp.asarray(index, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, nk),
            in_specs=[
                pl.BlockSpec((1, H, D), lambda b, j, idx: (b, 0, 0)),
                pl.BlockSpec((1, bk, v_dim), lambda b, j, idx: (b, j, 0)),
                pl.BlockSpec((1, bk, dr), lambda b, j, idx: (b, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, v_dim), lambda b, j, idx: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, v_dim), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, v_dim), q_full.dtype),
        interpret=interpret,
    )(index, q_full, ckv, krope)
    return out
