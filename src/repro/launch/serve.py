"""Serving launcher: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-236b \
        --smoke --batch 4 --prompt-len 32 --gen 16 --scheme auto

``--scheme auto`` runs the paper's co-design insight end-to-end: the MLA
execution scheme (rc / ru / seq) is picked per deployment point from the
platform's compute-to-bandwidth ratio (core.schemes.auto_dispatch).

``--paged`` serves the same load through the continuous-batching runtime
instead (paged latent-KV pool + per-request block tables + mid-generation
admission; runtime.engine).  With ``--scheme auto`` the dispatch re-runs
EVERY step on the live (batch, max cache_len) point.

Paged-runtime knobs (PR 2):

  --no-prefix-cache   disable the radix prefix cache (runtime.prefix_cache):
                      by default requests sharing a prompt prefix fork the
                      same pool blocks (ref-counted, copy-on-write at the
                      first divergent/partial block) and only prefill the
                      un-cached suffix; released blocks stay LRU-evictable.
  --prefill-chunk N   chunk size of the batched paged prefill (one compiled
                      prefill shape per chunk size — NOT per prompt length);
                      0 falls back to PR-1's per-request prefill (which
                      also forces the prefix cache off).
  --temperature/--top-k
                      sampling beyond greedy argmax; the PRNG key is folded
                      with (request id, absolute token position) so
                      recompute-preemption replay stays deterministic.

Prefill impl switch (PR 3):

  --prefill-impl {auto,gather,pallas}
                      chunk-attention path of the batched paged prefill.
                      'gather' materializes the contiguous (B, S)
                      block-table view in HBM every chunk (the reference
                      path); 'pallas' runs the fused paged prefill kernel
                      (kernels.mla_prefill) that walks the block table in
                      place — same tokens, no gather ever written.  'auto'
                      (default) follows --impl: 'kernel' (or its alias
                      'pallas') uses the kernel, 'ref' the gather view.
                      Both paths are token-identical (tier-1-gated in
                      tests/test_prefill_kernel.py + tests/test_paged.py).

Sharded serving (PR 4) — composes with --paged:

  --mesh DPxMP        device mesh, e.g. '2x2' = (data=2, model=2).  The
                      contiguous path shards per make_prefill_step /
                      make_serve_step; the PAGED path shards the batch
                      (token / block-table / length rows) over 'data' and
                      heads over 'model' while the latent pool replicates
                      on every device (runtime.steps: the compact cache
                      is what makes replication affordable; per-device
                      cache traffic still drops by the DP factor).
                      Outputs are token-identical to single-host serving
                      (tests/test_mesh_paged.py).  Needs
                      jax.device_count() >= DP*MP: on CPU set
                      XLA_FLAGS=--xla_force_host_platform_device_count=N.
  --policy            weight-sharding rules for the mesh
                      (nn.sharding.make_rules mode; default 'serve').

Speculative decoding (PR 5) — composes with --paged and --mesh:

  --spec-k K          draft K tokens per tick and verify them in ONE
                      k+1-query target forward (the prefill-chunk
                      machinery at chunk K+1; runtime.steps
                      .make_verify_step).  Rejected drafts are a pure
                      host-side length rewind.  Outputs are
                      token-identical to plain paged decode under greedy
                      AND seeded sampling (tests/test_spec_decode.py);
                      draft quality only moves throughput.
  --draft SPEC        draft model: 'shallow:N' (self-speculation — the
                      target's own first N layers, weights shared by
                      reference; default shallow:2) or 'self' (identity
                      draft, the 100%-acceptance oracle).

Quantized latent pool (PR 8) — composes with every paged flag:

  --cache-dtype {bf16,int8,fp8}
                      storage dtype of the paged {ckv|krope} latent pool.
                      'int8' (and 'fp8' on jax builds with
                      float8_e4m3fn) stores 1-byte payloads with per-
                      token-row f32 scales riding the pool pytree;
                      quantize-on-write in the prefill/decode scatter
                      paths, dequantize in-register inside the Pallas
                      kernels (never a pool-sized f32 copy in HBM).
                      Cuts modeled cache bytes/token to ~0.3x bf16 at
                      DeepSeek shapes, shifting the rc/ru/seq crossovers
                      auto_dispatch sees (core.schemes.cache_width);
                      greedy decode stays token-parity with bf16 on the
                      smoke models, with per-dtype logit-error bounds
                      vs the fp32 oracle gated in
                      tests/test_quant_cache.py.  Requires
                      --prefill-chunk > 0 (the per-request scatter
                      carries no scales).  'bf16' (default) is the
                      unquantized pool at the compute dtype.

Async double-buffered engine + HTTP frontend (PR 9):

  --engine {sync,async}
                      which paged engine runs the load.  'async'
                      (AsyncPagedMLAEngine) dispatches the fused
                      decode+sample step and returns WITHOUT syncing:
                      the host prepares tick N+1 (admission, block
                      growth, CoW drain) while the device executes
                      tick N, and only the sampled token ids sync back
                      a tick later.  Token-identical to 'sync' under
                      greedy AND seeded sampling, preemption included
                      (tests/test_async_engine.py).
  --serve             instead of running the synthetic batch, start the
                      stdlib HTTP/SSE frontend (launch.server) on
                      --host:--port and serve live requests:
                      POST /v1/generate (SSE streaming or blocking
                      JSON; per-request max_tokens + stop sequences),
                      POST /v1/cancel, GET /v1/health, GET
                      /v1/metrics.  Requires --paged.  A client
                      disconnect mid-stream cancels the request and
                      frees its pool blocks.
  --host / --port     frontend bind address (default 127.0.0.1:8000).

Multi-turn & parallel sampling (PR 10):

  --n N               parallel samples per request (SamplingParams.n):
                      the prompt prefills ONCE, then the sequence forks
                      N ways through refcounted block sharing +
                      copy-on-write on the partial tail block
                      (runtime.scheduler.fork_group).  Each fork samples
                      its own fold(rid + i, position) key stream, so the
                      group is token-identical to N independent seeded
                      requests while allocating strictly fewer blocks.
                      Per-request knobs ride runtime.sampling
                      .SamplingParams; the legacy Request(prompt,
                      max_new, stop=...) constructor still works through
                      a deprecation shim.
  --admission {cache_aware,fcfs}
                      admission order of waiting requests.
                      'cache_aware' (default) admits the request with
                      the longest currently-cached prefix first (probed
                      fork-free via PrefixCache.lookup_len) so warm
                      conversation turns jump cold prompts; requests
                      bypassed --admission-age-bound times are served
                      regardless (starvation bound).  'fcfs' restores
                      strict arrival order.
  --admission-age-bound N
                      how many times cache-aware admission may bypass a
                      waiting request before it is served unconditionally
                      (default 64).

  Decode-filled blocks also register in the radix trie as generation
  crosses each block boundary, so a follow-up turn whose prompt embeds
  the previous turn's output re-hits its OWN generation, and prefix
  matches are token-granular (a hit may end mid-block; the tail is
  materialized copy-on-write).  Both behaviors are on by default with
  the prefix cache and off with --no-prefix-cache.

Common knobs: --arch picks the model family/config, --smoke shrinks it
to CI size, --platform names the hwmodel deployment point that
auto_dispatch prices schemes against, and --seed seeds weight init and
the sampling PRNG.

Telemetry (PR 7) — composes with every paged flag:

  --trace PATH        record per-request lifecycle spans (arrival ->
                      queued -> prefill -> decode -> finish/preempt) and
                      per-step phase spans (schedule / prefill chunks /
                      draft / verify / device_step / host_sample) and
                      write Chrome/Perfetto trace-event JSON to PATH
                      (load it at https://ui.perfetto.dev or
                      chrome://tracing).
  --metrics PATH      write the metrics-registry JSON (counters, gauges,
                      TTFT/TPOT/queue-delay/step-time histograms with
                      p50/p95/p99, plus the engine summary verbatim) to
                      PATH and print the human-readable table.  Either
                      flag also arms the roofline drift channel: every
                      step logs hwmodel-predicted vs measured time for
                      the scheme it dispatched (repro.obs.drift).
                      Off (the default) costs the hot path nothing
                      measurable — the no-op tracer short-circuits
                      before any formatting or allocation.

Serving-flags summary (the paged runtime; all compose):

  flag              default   effect
  --paged           off       continuous batching over the block pool
  --block-size      16        tokens per pool block
  --num-blocks      sized     pool capacity
  --no-prefix-cache off       disable radix block sharing
  --prefill-chunk   32        batched prefill chunk (0 = per-request)
  --prefill-impl    auto      'gather' view vs 'pallas' in-place kernel
  --impl            ref       decode attention: 'ref' | 'kernel'
  --cache-dtype     bf16      pool storage: 'bf16' | 'int8' | 'fp8'
  --temperature     0.0       0 = greedy; else seeded sampling
  --top-k           0         top-k filter when sampling
  --mesh            ''        'DPxMP' sharded serving
  --policy          serve     weight-sharding rules under --mesh
  --spec-k          0         speculative decoding draft window
  --draft           shallow:2 draft spec ('shallow:N' | 'self')
  --trace           ''        Perfetto trace-event JSON output path
  --metrics         ''        metrics-registry JSON output path
  --engine          sync      paged engine: 'sync' | 'async' (overlapped)
  --serve           off       HTTP/SSE frontend instead of batch mode
  --host            127.0.0.1 frontend bind host (with --serve)
  --port            8000      frontend bind port (with --serve)
  --n               1         parallel samples per request (fork + CoW)
  --admission       cache_aware  admission order: 'cache_aware' | 'fcfs'
  --admission-age-bound 64    starvation bound of cache-aware admission

Static audit (PR 6): every step factory this CLI dispatches to
(decode/prefill/verify x gather/pallas x scheme, single-device and
--mesh) is compiled — never run — by ``repro.analysis.audit`` and
checked for donation aliasing, pool-gather byte budgets, dtype
discipline, and roofline conformance against ``hwmodel``'s cost model
(``make audit`` / the CI ``audit`` job; tolerance bands live in
``analysis/audit.py:TOLERANCES``, suppressions in
``analysis/audit_allowlist.py``).  A serve-path change that drops a
donation or inflates pool traffic fails the gate before any benchmark
notices.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, models
from repro.core import mla as mlalib
from repro.core.schemes import auto_dispatch
from repro.hwmodel.platforms import PLATFORMS
from repro.nn import module as nnm
from repro.runtime.steps import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--scheme", default="auto",
                    help="auto | naive | seq | rc | ru")
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over the paged latent pool")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool blocks (0 = sized for the request load)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix prefix-cache block sharing")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="batched paged prefill chunk size "
                         "(0 = PR-1 per-request prefill)")
    ap.add_argument("--prefill-impl", default="auto",
                    choices=("auto", "gather", "pallas"),
                    help="chunked-prefill attention path: 'gather' "
                         "materializes the block-table view (reference), "
                         "'pallas' walks the block table in place via the "
                         "fused prefill kernel; 'auto' follows --impl")
    ap.add_argument("--cache-dtype", default="bf16",
                    choices=("bf16", "int8", "fp8"),
                    help="paged latent-pool storage dtype: int8/fp8 "
                         "quantize on write with per-token-row f32 scales "
                         "and dequantize in-register in the kernels "
                         "(~0.3x cache bytes/token vs bf16); requires "
                         "--paged and --prefill-chunk > 0")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples with a per-request PRNG "
                         "key folded with the absolute token position")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter when sampling (0 = full vocab)")
    ap.add_argument("--mesh", default="",
                    help="device mesh 'DPxMP' (e.g. '2x2' = data x model); "
                         "'' = single host.  Composes with --paged.  On "
                         "CPU, force devices first: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--policy", default="serve",
                    choices=("serve", "serve_2dtp", "dp", "tp"),
                    help="weight-sharding rules under --mesh "
                         "(nn.sharding.make_rules mode)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft K tokens per tick "
                         "and verify them in one K+1-query forward "
                         "(0 = off; requires --paged, composes with "
                         "--mesh; token-identical to plain decode)")
    ap.add_argument("--draft", default="shallow:2",
                    help="draft model under --spec-k: 'shallow:N' = the "
                         "target's own first N layers (self-speculation) "
                         "| 'self' = identity draft (acceptance oracle)")
    ap.add_argument("--trace", default="",
                    help="write Chrome/Perfetto trace-event JSON (request "
                         "lifecycle + step phase spans) to this path; "
                         "requires --paged")
    ap.add_argument("--metrics", default="",
                    help="write metrics-registry JSON (counters/gauges/"
                         "histograms + engine summary) to this path and "
                         "print the table; requires --paged")
    ap.add_argument("--engine", default="sync", choices=("sync", "async"),
                    help="paged engine: 'sync' steps the device and waits; "
                         "'async' double-buffers — host schedules tick N+1 "
                         "while the device runs tick N (token-identical)")
    ap.add_argument("--serve", action="store_true",
                    help="start the HTTP/SSE frontend (launch.server) on "
                         "--host:--port instead of running the synthetic "
                         "batch; requires --paged")
    ap.add_argument("--host", default="127.0.0.1",
                    help="frontend bind host (with --serve)")
    ap.add_argument("--port", type=int, default=8000,
                    help="frontend bind port (with --serve)")
    ap.add_argument("--n", type=int, default=1,
                    help="parallel samples per request: prefill once, "
                         "fork the sequence n ways copy-on-write "
                         "(SamplingParams.n); requires --paged")
    ap.add_argument("--admission", default="cache_aware",
                    choices=("cache_aware", "fcfs"),
                    help="admission order of waiting requests: "
                         "'cache_aware' admits the longest-cached-prefix "
                         "first (aging-bounded), 'fcfs' strict arrival "
                         "order; requires --paged")
    ap.add_argument("--admission-age-bound", type=int, default=64,
                    help="serve a waiting request unconditionally after "
                         "cache-aware admission bypassed it this many "
                         "times")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.full(args.arch)
    dtype = jnp.float32
    params = nnm.init_params(jax.random.PRNGKey(args.seed),
                             models.model_defs(cfg), dtype)
    mesh = _parse_mesh(args.mesh)

    if args.paged:
        return _serve_paged(args, cfg, params, dtype, mesh)
    if args.cache_dtype != "bf16":
        raise SystemExit("--cache-dtype requires --paged (only the paged "
                         "latent pool stores quantized)")
    if args.spec_k:
        raise SystemExit("--spec-k requires --paged (the draft/verify "
                         "phases run on the paged runtime)")
    if args.trace or args.metrics:
        raise SystemExit("--trace/--metrics require --paged (the "
                         "telemetry subsystem instruments the "
                         "continuous-batching engine)")
    if args.serve or args.engine != "sync":
        raise SystemExit("--serve/--engine require --paged (the frontend "
                         "and the async double-buffer run on the paged "
                         "runtime)")
    if args.n != 1:
        raise SystemExit("--n requires --paged (parallel sampling forks "
                         "the paged block pool copy-on-write)")

    scheme = args.scheme
    if scheme == "auto":
        if cfg.attn_kind == "mla":
            platform = PLATFORMS[args.platform]
            cap = args.prompt_len + args.gen
            scheme = auto_dispatch(cfg.mla_config(), platform, cache_len=cap,
                                   batch=args.batch)
            print(f"[serve] auto_dispatch({args.platform}, L={cap}, "
                  f"B={args.batch}) -> scheme '{scheme}'")
        else:
            scheme = "seq"

    if cfg.attn_kind == "mla":
        # engine build: attach precomputed absorbed weights for 'ru'
        # (BEFORE the step builders, so mesh in_shardings see the final
        # param tree — see steps.paged_param_shardings)
        params = _prepare_mla(params, cfg, scheme)

    capacity = args.prompt_len + args.gen + 1
    tmpl = params if mesh is not None else None
    prefill = make_prefill_step(cfg, mesh, batch=args.batch,
                                capacity=capacity, compute_dtype=dtype,
                                impl=args.impl, scheme=scheme,
                                policy=args.policy, params_template=tmpl)
    step = make_serve_step(cfg, mesh, compute_dtype=dtype, impl=args.impl,
                           scheme=scheme, policy=args.policy,
                           params_template=tmpl)
    if mesh is not None:
        # with a mesh the serve-step builder closes over the cache pytree
        # (shardings depend on its structure); commit the weights once
        from repro.runtime.steps import commit_params
        params = commit_params(params, cfg, mesh, args.policy)
        step = step(jax.eval_shape(
            lambda: models.init_cache(cfg, args.batch, capacity, dtype)),
            args.batch, capacity)

    # independent streams for tokens and embeds: reusing one key would
    # correlate the draws (jaxlint JL001, enforced by `make audit`)
    tok_key, emb_key = jax.random.split(jax.random.PRNGKey(args.seed + 1))
    toks = jax.random.randint(tok_key, (args.batch, args.prompt_len), 0,
                              cfg.vocab)
    kw = {}
    if cfg.family in ("vlm", "encdec"):
        P = cfg.n_patches if cfg.family == "vlm" else cfg.n_frames
        kw["embeds"] = jax.random.normal(emb_key, (args.batch, P, cfg.d_model),
                                         dtype) * 0.02

    t0 = time.time()
    logits, cache = prefill(params, toks, **kw)
    logits.block_until_ready()
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{time.time() - t0:.2f}s")

    out_tokens = [np.asarray(jnp.argmax(logits, -1))]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok = jnp.asarray(out_tokens[-1])
        logits, cache = step(params, tok, cache, args.prompt_len + i)
        out_tokens.append(np.asarray(jnp.argmax(logits, -1)))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s), "
          f"scheme={scheme}")
    print("[serve] sample:", np.stack(out_tokens, 1)[0][:16])


def _parse_mesh(spec: str):
    """'' -> None; 'DPxMP' (e.g. '2x2') -> Mesh((dp, mp), (data, model))."""
    if not spec:
        return None
    from repro.launch.mesh import make_mesh
    try:
        dp, mp = (int(x) for x in spec.lower().replace(",", "x").split("x"))
    except ValueError:
        raise SystemExit(f"--mesh expects 'DPxMP' (e.g. '2x2'), got {spec!r}")
    need = dp * mp
    if jax.device_count() < need:
        raise SystemExit(
            f"--mesh {spec}: needs {need} devices, found "
            f"{jax.device_count()}.  On CPU force virtual devices first: "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return make_mesh((dp, mp), ("data", "model"))


def _serve_paged(args, cfg, params, dtype, mesh=None):
    """Continuous-batching path: the fixed (batch x prompt x gen) load
    becomes a staggered request stream against the paged runtime.  With a
    mesh, batch rows shard over 'data', heads over 'model', and the pool
    replicates (runtime.steps) — same tokens as single-host serving."""
    from repro.runtime import (AsyncPagedMLAEngine, PagedMLAEngine, Request,
                               SamplingParams, blocks_for)

    engine_cls = AsyncPagedMLAEngine if args.engine == "async" \
        else PagedMLAEngine
    bs = args.block_size
    per_req = blocks_for(args.prompt_len + args.gen + 1, bs)
    # fork children share the prompt blocks; each needs its own tail run
    per_group = per_req + (args.n - 1) * blocks_for(args.gen + 1, bs)
    num_blocks = args.num_blocks or (1 + args.batch * per_group)
    draft_cfg = draft_params = None
    if args.spec_k:
        from repro.runtime.spec import parse_draft_spec
        draft_cfg, draft_params = parse_draft_spec(args.draft, cfg, params)
        print(f"[serve] speculative decoding: k={args.spec_k}, "
              f"draft={args.draft} ({draft_cfg.n_layers} layers)")
    tel = None
    if args.trace or args.metrics:
        from repro.obs import Telemetry
        tel = Telemetry.on(trace=bool(args.trace),
                           metrics=bool(args.metrics), drift=True)
    engine = engine_cls(
        cfg, params, num_blocks=num_blocks, block_size=bs,
        max_batch=max(args.batch, args.n), max_blocks_per_req=per_req,
        compute_dtype=dtype, impl=args.impl, scheme=args.scheme,
        platform=PLATFORMS[args.platform],
        enable_prefix_cache=not args.no_prefix_cache,
        prefill_mode="chunked" if args.prefill_chunk else "per_request",
        prefill_impl=args.prefill_impl,
        prefill_chunk=args.prefill_chunk or 32,
        temperature=args.temperature, top_k=args.top_k,
        sample_seed=args.seed, mesh=mesh, shard_policy=args.policy,
        spec_k=args.spec_k, draft_cfg=draft_cfg, draft_params=draft_params,
        cache_dtype=args.cache_dtype, telemetry=tel,
        admission=args.admission,
        admission_age_bound=args.admission_age_bound)
    if args.serve:
        from repro.launch.server import Frontend
        fe = Frontend(engine, host=args.host, port=args.port)
        print(f"[serve] HTTP/SSE frontend on http://{fe.host}:{fe.port} "
              f"(engine={args.engine}; POST /v1/generate, /v1/cancel; "
              f"GET /v1/health, /v1/metrics; Ctrl-C to stop)")
        return fe.serve_forever()
    rng = np.random.default_rng(args.seed + 1)
    # rids are spaced by n: a fork group's children claim rid+1..rid+n-1.
    reqs = [Request(rid=i * args.n,
                    prompt=rng.integers(0, cfg.vocab,
                                        (args.prompt_len,)).astype(np.int32),
                    arrival=2 * i,
                    sampling=SamplingParams(max_tokens=args.gen, n=args.n))
            for i in range(args.batch)]
    t0 = time.time()
    summary = engine.run(reqs, log_every=8)
    dt = time.time() - t0
    print(f"[serve] paged: {summary['decode_tokens']:.0f} decode tokens in "
          f"{dt:.2f}s ({summary['tokens_per_s']:.1f} tok/s), "
          f"{summary['mid_gen_admissions']:.0f} mid-generation admissions, "
          f"cache utilization {summary['cache_utilization']:.2f}, "
          f"schemes {summary['schemes_used']}")
    print(f"[serve] prefix cache: hit rate "
          f"{summary['prefix_hit_rate']:.2f} "
          f"({summary['prefix_hit_tokens']:.0f}/"
          f"{summary['prompt_tokens']:.0f} prompt tokens), "
          f"{summary['prefill_tokens']:.0f} prefilled in "
          f"{summary['prefill_chunks']:.0f} chunks, "
          f"{summary['prefill_compiles']:.0f} prefill compiles")
    if args.n > 1:
        print(f"[serve] parallel sampling: {summary['fork_groups']:.0f} "
              f"groups forked n={args.n} "
              f"({summary['fork_children']:.0f} children, one prefill per "
              f"group)")
    if args.spec_k:
        print(f"[serve] spec decode: {summary['spec_rounds']:.0f} rounds, "
              f"accept rate {summary['spec_accept_rate']:.2f} "
              f"({summary['spec_accepted']:.0f}/"
              f"{summary['spec_drafted']:.0f} drafts), "
              f"{summary['spec_mean_emitted']:.2f} tokens/round, "
              f"{summary['spec_compiles']:.0f} spec compiles")
    first = min(engine.sched.finished, key=lambda r: r.rid)
    print("[serve] sample:", np.asarray(first.output[:16]))
    if tel is not None:
        tel.finalize(engine)
        written = tel.export(trace_path=args.trace or None,
                             metrics_path=args.metrics or None)
        for channel, path in written.items():
            print(f"[serve] telemetry: {channel} -> {path}")
        if tel.metrics is not None:
            print(tel.metrics.render_table())
        if tel.drift is not None and tel.drift.rows:
            d = tel.drift.report()["summary"]
            print(f"[serve] roofline drift: time-ratio p50 "
                  f"{d['time_ratio_p50']:.3g}, spread {d['spread']:.2f}")


def _prepare_mla(params, cfg, scheme):
    """Attach absorbed weights on every MLA sublayer (stacked or not)."""
    if scheme != "ru":
        return params
    return mlalib.attach_absorbed_tree(params, cfg.mla_config())


if __name__ == "__main__":
    main()
