"""Serving launcher: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-236b \
        --smoke --batch 4 --prompt-len 32 --gen 16 --scheme auto

``--scheme auto`` runs the paper's co-design insight end-to-end: the MLA
execution scheme (rc / ru / seq) is picked per deployment point from the
platform's compute-to-bandwidth ratio (core.schemes.auto_dispatch).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, models
from repro.core import mla as mlalib
from repro.core.schemes import auto_dispatch
from repro.hwmodel.platforms import PLATFORMS
from repro.nn import module as nnm
from repro.runtime.steps import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--scheme", default="auto",
                    help="auto | naive | seq | rc | ru")
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.full(args.arch)
    dtype = jnp.float32
    params = nnm.init_params(jax.random.PRNGKey(args.seed),
                             models.model_defs(cfg), dtype)

    scheme = args.scheme
    if scheme == "auto":
        if cfg.attn_kind == "mla":
            platform = PLATFORMS[args.platform]
            cap = args.prompt_len + args.gen
            scheme = auto_dispatch(cfg.mla_config(), platform, cache_len=cap,
                                   batch=args.batch)
            print(f"[serve] auto_dispatch({args.platform}, L={cap}, "
                  f"B={args.batch}) -> scheme '{scheme}'")
        else:
            scheme = "seq"

    capacity = args.prompt_len + args.gen + 1
    prefill = make_prefill_step(cfg, None, batch=args.batch,
                                capacity=capacity, compute_dtype=dtype,
                                impl=args.impl, scheme=scheme)
    step = make_serve_step(cfg, None, compute_dtype=dtype, impl=args.impl,
                           scheme=scheme)

    key = jax.random.PRNGKey(args.seed + 1)
    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    kw = {}
    if cfg.family in ("vlm", "encdec"):
        P = cfg.n_patches if cfg.family == "vlm" else cfg.n_frames
        kw["embeds"] = jax.random.normal(key, (args.batch, P, cfg.d_model),
                                         dtype) * 0.02
    if cfg.attn_kind == "mla":
        # engine build: attach precomputed absorbed weights for 'ru'
        params = _prepare_mla(params, cfg, scheme)

    t0 = time.time()
    logits, cache = prefill(params, toks, **kw)
    logits.block_until_ready()
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{time.time() - t0:.2f}s")

    out_tokens = [np.asarray(jnp.argmax(logits, -1))]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok = jnp.asarray(out_tokens[-1])
        logits, cache = step(params, tok, cache, args.prompt_len + i)
        out_tokens.append(np.asarray(jnp.argmax(logits, -1)))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s), "
          f"scheme={scheme}")
    print("[serve] sample:", np.stack(out_tokens, 1)[0][:16])


def _prepare_mla(params, cfg, scheme):
    """Attach absorbed weights on every MLA sublayer (stacked or not)."""
    if scheme != "ru":
        return params

    def visit(node):
        if isinstance(node, dict):
            if "w_uq" in node and "w_uk" in node:
                w_uq = node["w_uq"]
                mcfg = cfg.mla_config()
                if w_uq.ndim == 4:   # stacked (layers, Q, H, d)
                    absorb = jax.vmap(
                        lambda q, k: mlalib.absorb_qk({"w_uq": q, "w_uk": k},
                                                      mcfg))(w_uq, node["w_uk"])
                else:
                    absorb = mlalib.absorb_qk(node, mcfg)
                return {**node, "w_absorb": absorb.astype(w_uq.dtype)}
            return {k: visit(v) for k, v in node.items()}
        return node

    return visit(params)


if __name__ == "__main__":
    main()
