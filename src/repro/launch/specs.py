"""Abstract input/state specs for the dry-run: ShapeDtypeStruct stand-ins
for every model input — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .. import models
from ..configs.shapes import SHAPES, Shape
from ..models.common import ModelConfig
from ..nn import module as nnm
from ..optim import AdamWConfig, adamw_init

I32 = jnp.int32


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def param_specs(cfg: ModelConfig, param_dtype=jnp.bfloat16):
    """Abstract param tree (no allocation)."""
    return nnm.shapes(models.model_defs(cfg), param_dtype)


def opt_specs(cfg: ModelConfig, opt_cfg: AdamWConfig, param_dtype=jnp.bfloat16):
    """Abstract optimizer state via eval_shape over adamw_init."""
    p = param_specs(cfg, param_dtype)
    return jax.eval_shape(lambda q: adamw_init(q, opt_cfg), p)


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM shapes: 1024 patch embeddings + (seq-1024) text tokens."""
    if cfg.family == "vlm":
        return seq_len - cfg.n_patches
    return seq_len


def batch_specs(cfg: ModelConfig, shape: Shape,
                compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Inputs of the step function lowered for this shape kind."""
    B, L = shape.global_batch, shape.seq_len
    Lt = _text_len(cfg, L)
    if shape.kind == "train":
        out = {"tokens": sds((B, Lt), I32), "labels": sds((B, Lt), I32)}
        if cfg.family == "vlm":
            out["embeds"] = sds((B, cfg.n_patches, cfg.d_model), compute_dtype)
        if cfg.family == "encdec":
            out = {"tokens": sds((B, L), I32), "labels": sds((B, L), I32),
                   "embeds": sds((B, cfg.n_frames, cfg.d_model), compute_dtype)}
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, Lt), I32)}
        if cfg.family == "vlm":
            out["embeds"] = sds((B, cfg.n_patches, cfg.d_model), compute_dtype)
        if cfg.family == "encdec":
            out = {"tokens": sds((B, L), I32),
                   "embeds": sds((B, cfg.n_frames, cfg.d_model), compute_dtype)}
        return out
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: models.init_cache(cfg, B, shape.seq_len, compute_dtype))
        return {"token": sds((B,), I32), "cache": cache,
                "index": sds((), I32)}
    raise ValueError(shape.kind)


def input_specs(arch_or_cfg, shape_name: str, compute_dtype=jnp.bfloat16):
    """input_specs('deepseek-v2-236b', 'decode_32k') — the dry-run entry."""
    if isinstance(arch_or_cfg, ModelConfig):
        cfg = arch_or_cfg
    else:
        from .. import configs
        cfg = configs.full(arch_or_cfg)
    return batch_specs(cfg, SHAPES[shape_name], compute_dtype)
