"""Production mesh builders.

Single-pod : (data=16, model=16)          = 256 chips (one v5e pod)
Multi-pod  : (pod=2, data=16, model=16)   = 512 chips (2 pods over DCN/ICI)

Functions (not module-level constants) so importing never touches jax
device state — the dry-run sets XLA_FLAGS before any jax import instead.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 2))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def chips(mesh) -> int:
    return mesh.devices.size
