"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, print memory/cost analysis, and emit the
roofline artifacts consumed by EXPERIMENTS.md and benchmarks/roofline.

MUST set the device-count override before ANY other import (jax locks the
device count on first init).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro import compat, configs, models
from repro.analysis import hlo as hloa
from repro.configs.shapes import SHAPES
from repro.hwmodel.platforms import (TPU_V5E_HBM_BW, TPU_V5E_ICI_BW,
                                     TPU_V5E_PEAK_FLOPS)
from repro.hwmodel.roofline import three_term
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamWConfig
from repro.runtime.steps import (TrainStepConfig, make_prefill_step,
                                 make_serve_step, make_train_step)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


def _opt_cfg(cfg) -> AdamWConfig:
    big = models.param_count(cfg) > 100e9
    return AdamWConfig(moment_dtype=jnp.bfloat16 if big else jnp.float32)


def lower_cell(arch: str, shape_name: str, mesh, *, scheme: str = "rc",
               impl: str = "chunked", loss_chunk: int = 256,
               shard_cache_seq: Optional[bool] = None,
               policy: Optional[str] = None,
               compute_dtype=jnp.bfloat16):
    """Returns (lowered, meta) for one cell.

    ``policy``: sharding policy override ('train'/'serve' baseline;
    'serve_2dtp' resident-weight serving TP; 'dp' replicated weights)."""
    cfg = configs.full(arch)
    shape = SHAPES[shape_name]
    if cfg.attn_kind != "mla":
        scheme = "seq"   # scheme only affects MLA archs
    batch = S.batch_specs(cfg, shape, compute_dtype)
    params = S.param_specs(cfg, compute_dtype)

    if shape.kind == "train":
        step_fn, _ = make_train_step(
            cfg, mesh, _opt_cfg(cfg),
            TrainStepConfig(compute_dtype=compute_dtype, impl=impl,
                            scheme=scheme, loss_chunk=loss_chunk),
            policy=policy or "train")
        opt = S.opt_specs(cfg, _opt_cfg(cfg), compute_dtype)
        lowered = step_fn.lower(params, opt, batch)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, mesh, batch=shape.global_batch,
                               capacity=shape.seq_len + 8,
                               compute_dtype=compute_dtype, impl=impl,
                               scheme=scheme, policy=policy or "serve")
        args = [params, batch["tokens"]]
        if "embeds" in batch:
            args.append(batch["embeds"])
        lowered = fn.lower(*args)
    else:  # decode
        if shard_cache_seq is None:
            shard_cache_seq = shape.global_batch == 1
        maker = make_serve_step(cfg, mesh, compute_dtype=compute_dtype,
                                impl=impl, scheme=scheme,
                                shard_cache_seq=shard_cache_seq,
                                policy=policy or "serve")
        fn = maker(batch["cache"], shape.global_batch, shape.seq_len)
        lowered = fn.lower(params, batch["token"], batch["cache"],
                           batch["index"])
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "scheme": scheme if cfg.attn_kind == "mla" else None,
            "impl": impl, "chips": int(mesh.devices.size),
            "policy": policy or ("train" if shape.kind == "train" else "serve"),
            "mesh": "x".join(map(str, mesh.devices.shape))}
    return lowered, meta


def analyze_compiled(lowered, compiled, chips: int) -> Dict[str, Any]:
    ca = compat.cost_analysis(compiled)
    mem = compiled.memory_analysis()
    hc = hloa.analyze(compiled.as_text(), num_partitions=chips)
    terms = three_term(
        hlo_flops=hc.flops * chips, hlo_bytes=hc.bytes * chips,
        coll_bytes=hc.collective_bytes, chips=chips,
        peak=TPU_V5E_PEAK_FLOPS, hbm_bw=TPU_V5E_HBM_BW, ici_bw=TPU_V5E_ICI_BW)
    out = {
        "xla_cost_analysis": {k: float(ca[k]) for k in
                              ("flops", "bytes accessed") if k in ca},
        "hlo_flops_per_chip": hc.flops,
        "hlo_bytes_per_chip": hc.bytes,
        "collective_bytes_per_chip": hc.collective_bytes,
        "collective_by_kind": hc.collective_by_kind,
        "while_trip_counts": len(hc.while_trip_counts),
        "hlo_warnings": hc.warnings[:5],
        "t_compute": terms.t_compute,
        "t_memory": terms.t_memory,
        "t_collective": terms.t_collective,
        "bound": terms.bound,
        "roofline_fraction": terms.roofline_fraction,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                out[f"mem_{attr}"] = int(v)
        # Steady-state HBM residency per chip: arguments + non-aliased
        # outputs (aliased outputs are donated in-place updates).  The CPU
        # lowering's temp size additionally contains bf16->f32 float-
        # normalization phantoms that do not exist on TPU (see
        # EXPERIMENTS.md §Methodology), so it is reported but not gating.
        args = getattr(mem, "argument_size_in_bytes", 0)
        outb = getattr(mem, "output_size_in_bytes", 0)
        alias = getattr(mem, "alias_size_in_bytes", 0)
        out["hbm_residency_gib"] = round((args + outb - alias) / 2 ** 30, 2)
    return out


def run_cell(arch: str, shape_name: str, mesh, *, save: bool = True,
             verbose: bool = True, **opts) -> Dict[str, Any]:
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, **opts)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    result = {**meta, "lower_s": round(t_lower, 1),
              "compile_s": round(t_compile, 1),
              **analyze_compiled(lowered, compiled, meta["chips"])}
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} ({meta['mesh']}): "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"bound={result['bound']} "
              f"t=(C {result['t_compute']:.3e}, M {result['t_memory']:.3e}, "
              f"X {result['t_collective']:.3e})s")
        mem = compiled.memory_analysis()
        if mem is not None:
            print(f"         mem: temp={getattr(mem, 'temp_size_in_bytes', 0)/2**30:.2f} GiB "
                  f"args={getattr(mem, 'argument_size_in_bytes', 0)/2**30:.2f} GiB "
                  f"out={getattr(mem, 'output_size_in_bytes', 0)/2**30:.2f} GiB")
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        tag = f"{meta['mesh']}_{arch}_{shape_name}"
        if opts.get("scheme") and configs.full(arch).attn_kind == "mla":
            tag += f"_{opts['scheme']}"
        if opts.get("policy"):
            tag += f"_{opts['policy']}"
        with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--scheme", default="rc",
                    help="MLA execution scheme (naive|seq|rc|ru)")
    ap.add_argument("--impl", default="chunked")
    ap.add_argument("--include-skipped", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    archs = [args.arch] if args.arch else configs.ARCHS
    failures = []
    for arch in archs:
        skips = configs.skip_shapes(arch)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for sh in shapes:
            if sh in skips and not args.include_skipped:
                print(f"[dryrun] {arch} x {sh}: SKIP ({skips[sh]})")
                continue
            try:
                run_cell(arch, sh, mesh, scheme=args.scheme, impl=args.impl)
            except Exception as e:  # noqa: BLE001 — report, continue
                failures.append((arch, sh, repr(e)))
                print(f"[dryrun] {arch} x {sh}: FAIL {e}")
                traceback.print_exc()
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        raise SystemExit(1)
    print("\n[dryrun] ALL CELLS PASSED")


if __name__ == "__main__":
    main()
