"""Stdlib HTTP/SSE frontend for the paged MLA engines.

One ``EngineWorker`` thread owns the engine (the engines are NOT
thread-safe; only ``request_cancel`` may be called from other threads)
and runs the tick loop: drain client submissions into ``engine.submit``,
``engine.step`` while work is pending, then publish newly visible tokens
into per-request stream queues that the HTTP handler threads block on.
With the async engine the worker's host work for tick N+1 overlaps the
device executing tick N — the frontend code is identical either way.

Endpoints (JSON in / JSON or SSE out; stdlib ``http.server`` only):

  POST /v1/generate   {"prompt": [ids], "max_tokens": N,
                       "stop": [[ids], ...], "stream": bool,
                       "temperature": f, "top_k": N, "seed": N, "n": N}
                      The body maps onto runtime.sampling.SamplingParams.
                      Without "n": stream=false returns the single-
                      completion body {"rid", "finish_reason", "output"}
                      (byte-compatible with the PR-9 wire format, pinned
                      by a golden test); stream=true emits one ``event:
                      token`` per generated token and a final ``event:
                      done``.  WITH "n" (parallel sampling — one prefill,
                      the sequence forks n ways copy-on-write): blocking
                      responses carry ``choices`` = [{"index", "tokens",
                      "finish_reason"}, ...]; SSE token events carry
                      their ``choice`` index and ``done`` carries the
                      full choices array.  The group occupies rids
                      [rid, rid + n); each choice c cancels
                      independently via rid + c.
                      Validation errors (empty prompt, n < 1, negative
                      temperature, engine-config mismatch) return
                      structured JSON {"error": {"message", "type"}}
                      with status 400; unknown routes 404.
  POST /v1/cancel     {"rid": N} — thread-safe cancel; mid-decode the
                      request frees its slot/blocks at the next tick and
                      finishes with finish_reason="cancelled".  For a
                      parallel-sampling group, rid + c cancels choice c
                      alone (sibling forks keep decoding).
  GET  /v1/health     liveness + engine step/queue counters.
  GET  /v1/metrics    metrics-registry snapshot (when telemetry is on)
                      plus the engine summary.

Streaming holds back ``max(len(stop_seq)) - 1`` tokens so a stop
sequence completing across several ticks never leaks its own prefix to
the client; the held tokens flush with ``event: done``.  A client
disconnect mid-stream (BrokenPipeError on write) cancels the request —
every fork of it, for a group — so its blocks return to the pool
instead of decoding to max_tokens.
"""
from __future__ import annotations

import itertools
import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..runtime.sampling import SamplingParams
from ..runtime.scheduler import Request


class _Stream:
    """Per-request token channel between the worker and a handler.

    For a parallel-sampling group (``n > 1``) one stream serves the
    whole group: the parent request is attached at submit, the fork
    children after ``engine.submit`` materializes them, and the queue
    carries ("token", choice, index, token) items plus one final
    ("done", choices) once EVERY member finished.  ``n == 1`` keeps the
    PR-9 item shapes ("token", token) / ("done", reason, output) —
    direct queue consumers (tests, embedding users) see no change."""

    __slots__ = ("rid", "reqs", "n", "q", "emitted", "hold")

    def __init__(self, rid: int, req: Request, n: int = 1):
        self.rid = rid
        self.reqs = [req]               # parent first; children attach later
        self.n = n
        self.q: "queue.Queue[Tuple]" = queue.Queue()
        self.emitted = [0]
        # stop sequences can complete across ticks; never emit a token
        # that a later match could retro-truncate.
        self.hold = max((len(s) for s in req.stop), default=1) - 1

    @property
    def req(self) -> Request:
        return self.reqs[0]

    def attach_children(self, children: List[Request]) -> None:
        self.reqs.extend(children)
        self.emitted.extend(0 for _ in children)


class EngineWorker(threading.Thread):
    """Single thread that owns the engine and ticks it.

    Submissions arrive via ``submit`` (any thread), cancellation via
    ``cancel`` (delegates to the engine's thread-safe flag).  The loop
    sleeps on a condition variable while the engine is idle and no
    submissions are pending, so an unused server costs nothing.
    """

    def __init__(self, engine, *, idle_wait_s: float = 0.05):
        super().__init__(daemon=True, name="engine-worker")
        self.engine = engine
        self._idle_wait_s = idle_wait_s
        self._cv = threading.Condition()
        self._pending: List[Tuple[Request, _Stream]] = []
        self._streams: Dict[int, _Stream] = {}
        self._rids = itertools.count()
        self._stopping = False

    # ------------------------------------------------------- client API ----
    def submit(self, prompt, max_tokens: Optional[int] = None,
               stop: Optional[List[List[int]]] = None, *,
               sampling: Optional[SamplingParams] = None) -> _Stream:
        """Queue a generation.  Either pass ``sampling`` (the request
        API) or the legacy ``(max_tokens, stop)`` pair, which builds the
        equivalent single-sample params.  A group submission (n > 1)
        consumes rids [rid, rid + n) — choice c of the response is
        rid + c, cancellable on its own."""
        if sampling is None:
            sampling = SamplingParams.from_legacy(
                16 if max_tokens is None else max_tokens, stop)
        with self._cv:
            rid = next(self._rids)
            for _ in range(sampling.n - 1):   # children own rid+1..rid+n-1
                next(self._rids)
            req = Request(rid=rid,
                          prompt=np.asarray(prompt, dtype=np.int32),
                          sampling=sampling)
            st = _Stream(rid, req, n=sampling.n)
            self._pending.append((req, st))
            self._streams[rid] = st
            self._cv.notify()
        return st

    def cancel(self, rid: int) -> None:
        self.engine.request_cancel(rid)   # thread-safe by contract
        with self._cv:
            self._cv.notify()             # wake the loop to process it

    def stop(self) -> None:
        with self._cv:
            self._stopping = True
            self._cv.notify()
        self.join(timeout=30)

    # ------------------------------------------------------ worker loop ----
    def run(self) -> None:
        while True:
            with self._cv:
                while (not self._stopping and not self._pending
                       and self.engine.idle and not self.engine._cancels):
                    self._cv.wait(timeout=self._idle_wait_s)
                if self._stopping:
                    return
                pending, self._pending = self._pending, []
            for req, st in pending:
                req.arrival = self.engine.stats.steps
                self.engine.submit(req)
                if st.n > 1:
                    # scheduler.submit materialized the fork children —
                    # wire them into the group stream for publishing
                    st.attach_children(req.fork_children)
            if not self.engine.idle or self.engine._cancels:
                self.engine.step()
            self._publish()

    def _publish(self) -> None:
        done = []
        for rid, st in self._streams.items():
            if st.n == 1:
                out = st.req.output
                safe = len(out) if st.req.done \
                    else max(0, len(out) - st.hold)
                while st.emitted[0] < safe:
                    st.q.put(("token", int(out[st.emitted[0]])))
                    st.emitted[0] += 1
                if st.req.done:
                    st.q.put(("done", st.req.finish_reason or "length",
                              [int(t) for t in out]))
                    done.append(rid)
                continue
            for c, req in enumerate(st.reqs):
                out = req.output
                safe = len(out) if req.done else max(0, len(out) - st.hold)
                while st.emitted[c] < safe:
                    st.q.put(("token", c, st.emitted[c],
                              int(out[st.emitted[c]])))
                    st.emitted[c] += 1
            if len(st.reqs) == st.n and all(r.done for r in st.reqs):
                st.q.put(("done", [
                    {"index": c, "tokens": [int(t) for t in r.output],
                     "finish_reason": r.finish_reason or "length"}
                    for c, r in enumerate(st.reqs)]))
                done.append(rid)
        for rid in done:
            del self._streams[rid]


def _parse_sampling(body: dict) -> SamplingParams:
    """Map a /v1/generate JSON body onto validated SamplingParams."""
    return SamplingParams(
        max_tokens=int(body.get("max_tokens", 16)),
        temperature=body.get("temperature"),
        top_k=None if body.get("top_k") is None else int(body["top_k"]),
        seed=None if body.get("seed") is None else int(body["seed"]),
        stop=tuple(tuple(int(t) for t in s)
                   for s in (body.get("stop") or ())),
        n=int(body.get("n", 1))).validate()


def _make_handler(worker: EngineWorker):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.0: one response per connection, no chunked framing
        # needed for the SSE stream — the close delimits it.
        protocol_version = "HTTP/1.0"

        def log_message(self, *a):      # silence per-request stderr spam
            pass

        # ------------------------------------------------------ helpers ----
        def _json(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _error(self, code: int, message: str,
                   etype: str = "invalid_request") -> None:
            self._json(code, {"error": {"message": message, "type": etype}})

        def _body(self) -> dict:
            n = int(self.headers.get("Content-Length", 0))
            return json.loads(self.rfile.read(n) or b"{}")

        # ------------------------------------------------------- routes ----
        def do_GET(self):
            eng = worker.engine
            if self.path == "/v1/health":
                return self._json(200, {
                    "ok": True, "steps": eng.stats.steps,
                    "active": eng.sched.n_active,
                    "waiting": len(eng.sched.waiting),
                    "finished": len(eng.sched.finished)})
            if self.path == "/v1/metrics":
                payload = {"summary": eng.summary()}
                if eng.tel.metrics is not None:
                    payload["metrics"] = eng.tel.metrics.to_dict()
                return self._json(200, payload)
            self._error(404, f"no route {self.path}", "not_found")

        def do_POST(self):
            if self.path == "/v1/cancel":
                try:
                    body = self._body()
                except json.JSONDecodeError as e:
                    return self._error(400, f"invalid JSON: {e}")
                worker.cancel(int(body.get("rid", -1)))
                return self._json(200, {"ok": True})
            if self.path != "/v1/generate":
                return self._error(404, f"no route {self.path}",
                                   "not_found")
            try:
                body = self._body()
                prompt = body["prompt"]
                if not prompt:
                    raise ValueError("empty prompt")
                sp = _parse_sampling(body)
                # engine-config match (temperature/top_k/seed are baked
                # into the compiled step) fails HERE, on the handler
                # thread, as a 400 — never inside the worker loop
                worker.engine.validate_sampling(sp)
            except KeyError as e:
                return self._error(400, f"missing field: {e}")
            except json.JSONDecodeError as e:
                return self._error(400, f"invalid JSON: {e}")
            except (TypeError, ValueError) as e:
                return self._error(400, str(e))
            has_n = "n" in body
            st = worker.submit(prompt, sampling=sp)
            if body.get("stream"):
                return self._stream(st, has_n)
            if not has_n:                  # PR-9 byte-compatible response
                while True:
                    item = st.q.get()
                    if item[0] == "done":
                        return self._json(200, {
                            "rid": st.rid, "finish_reason": item[1],
                            "output": item[2]})
            while True:
                item = st.q.get()
                if item[0] == "done":
                    # an explicit n=1 group still flows through the
                    # single-stream queue shape — wrap it as choice 0
                    choices = item[1] if st.n > 1 else [
                        {"index": 0, "tokens": item[2],
                         "finish_reason": item[1]}]
                    return self._json(200, {"rid": st.rid,
                                            "choices": choices})

        def _cancel_group(self, st: _Stream) -> None:
            for rid in range(st.rid, st.rid + st.n):
                worker.cancel(rid)

        def _stream(self, st: _Stream, has_n: bool) -> None:
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            self.end_headers()
            try:
                # rid first so the client can POST /v1/cancel mid-stream
                if has_n:
                    self._event("start", {"rid": st.rid, "n": st.n})
                else:
                    self._event("start", {"rid": st.rid})
            except (BrokenPipeError, ConnectionResetError):
                self._cancel_group(st)
                return
            i = 0
            while True:
                item = st.q.get()
                try:
                    if item[0] == "done":
                        if has_n:
                            choices = item[1] if st.n > 1 else [
                                {"index": 0, "tokens": item[2],
                                 "finish_reason": item[1]}]
                            self._event("done", {"rid": st.rid,
                                                 "choices": choices})
                        else:
                            self._event("done", {"rid": st.rid,
                                                 "finish_reason": item[1],
                                                 "output": item[2]})
                        return
                    if st.n > 1:
                        _, choice, idx, tok = item
                    else:
                        choice, idx, tok = 0, i, item[1]
                        i += 1
                    if has_n:
                        self._event("token", {"token": tok, "index": idx,
                                              "choice": choice})
                    else:
                        self._event("token", {"token": tok, "index": idx})
                except (BrokenPipeError, ConnectionResetError):
                    # client went away: free every fork's blocks
                    self._cancel_group(st)
                    return

        def _event(self, event: str, payload: dict) -> None:
            self.wfile.write(f"event: {event}\n"
                             f"data: {json.dumps(payload)}\n\n".encode())
            self.wfile.flush()

    return Handler


class Frontend:
    """HTTP server + engine worker pair.  ``port=0`` binds ephemeral
    (read the chosen one back from ``.port``) — used by the tests."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 8000):
        self.worker = EngineWorker(engine)
        self.httpd = ThreadingHTTPServer((host, port), _make_handler(self.worker))
        self.host, self.port = self.httpd.server_address[:2]
        self._serve_thread: Optional[threading.Thread] = None

    def start(self) -> "Frontend":
        self.worker.start()
        self._serve_thread = threading.Thread(
            target=self.httpd.serve_forever, daemon=True, name="http-serve")
        self._serve_thread.start()
        return self

    def serve_forever(self) -> None:
        """Blocking variant for the CLI (Ctrl-C to stop)."""
        self.worker.start()
        try:
            self.httpd.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            self.stop()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        self.worker.stop()
