"""Stdlib HTTP/SSE frontend for the paged MLA engines.

One ``EngineWorker`` thread owns the engine (the engines are NOT
thread-safe; only ``request_cancel`` may be called from other threads)
and runs the tick loop: drain client submissions into ``engine.submit``,
``engine.step`` while work is pending, then publish newly visible tokens
into per-request stream queues that the HTTP handler threads block on.
With the async engine the worker's host work for tick N+1 overlaps the
device executing tick N — the frontend code is identical either way.

Endpoints (JSON in / JSON or SSE out; stdlib ``http.server`` only):

  POST /v1/generate   {"prompt": [ids], "max_tokens": N,
                       "stop": [[ids], ...], "stream": bool}
                      stream=true: ``text/event-stream`` with one
                      ``event: token`` per generated token and a final
                      ``event: done`` carrying finish_reason + the full
                      (stop-truncated) output.  stream=false: a single
                      JSON body after completion.
  POST /v1/cancel     {"rid": N} — thread-safe cancel; mid-decode the
                      request frees its slot/blocks at the next tick and
                      finishes with finish_reason="cancelled".
  GET  /v1/health     liveness + engine step/queue counters.
  GET  /v1/metrics    metrics-registry snapshot (when telemetry is on)
                      plus the engine summary.

Streaming holds back ``max(len(stop_seq)) - 1`` tokens so a stop
sequence completing across several ticks never leaks its own prefix to
the client; the held tokens flush with ``event: done``.  A client
disconnect mid-stream (BrokenPipeError on write) cancels the request so
its blocks return to the pool instead of decoding to max_tokens.
"""
from __future__ import annotations

import itertools
import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..runtime.scheduler import Request


class _Stream:
    """Per-request token channel between the worker and a handler."""

    __slots__ = ("rid", "req", "q", "emitted", "hold")

    def __init__(self, rid: int, req: Request):
        self.rid = rid
        self.req = req
        self.q: "queue.Queue[Tuple]" = queue.Queue()
        self.emitted = 0
        # stop sequences can complete across ticks; never emit a token
        # that a later match could retro-truncate.
        self.hold = max((len(s) for s in req.stop), default=1) - 1


class EngineWorker(threading.Thread):
    """Single thread that owns the engine and ticks it.

    Submissions arrive via ``submit`` (any thread), cancellation via
    ``cancel`` (delegates to the engine's thread-safe flag).  The loop
    sleeps on a condition variable while the engine is idle and no
    submissions are pending, so an unused server costs nothing.
    """

    def __init__(self, engine, *, idle_wait_s: float = 0.05):
        super().__init__(daemon=True, name="engine-worker")
        self.engine = engine
        self._idle_wait_s = idle_wait_s
        self._cv = threading.Condition()
        self._pending: List[Tuple[Request, _Stream]] = []
        self._streams: Dict[int, _Stream] = {}
        self._rids = itertools.count()
        self._stopping = False

    # ------------------------------------------------------- client API ----
    def submit(self, prompt, max_tokens: int,
               stop: Optional[List[List[int]]] = None) -> _Stream:
        req = Request(rid=next(self._rids),
                      prompt=np.asarray(prompt, dtype=np.int32),
                      max_new=int(max_tokens),
                      stop=[list(map(int, s)) for s in (stop or [])])
        st = _Stream(req.rid, req)
        with self._cv:
            self._pending.append((req, st))
            self._streams[req.rid] = st
            self._cv.notify()
        return st

    def cancel(self, rid: int) -> None:
        self.engine.request_cancel(rid)   # thread-safe by contract
        with self._cv:
            self._cv.notify()             # wake the loop to process it

    def stop(self) -> None:
        with self._cv:
            self._stopping = True
            self._cv.notify()
        self.join(timeout=30)

    # ------------------------------------------------------ worker loop ----
    def run(self) -> None:
        while True:
            with self._cv:
                while (not self._stopping and not self._pending
                       and self.engine.idle and not self.engine._cancels):
                    self._cv.wait(timeout=self._idle_wait_s)
                if self._stopping:
                    return
                pending, self._pending = self._pending, []
            for req, _ in pending:
                req.arrival = self.engine.stats.steps
                self.engine.submit(req)
            if not self.engine.idle or self.engine._cancels:
                self.engine.step()
            self._publish()

    def _publish(self) -> None:
        done = []
        for rid, st in self._streams.items():
            out = st.req.output
            safe = len(out) if st.req.done else max(0, len(out) - st.hold)
            while st.emitted < safe:
                st.q.put(("token", int(out[st.emitted])))
                st.emitted += 1
            if st.req.done:
                st.q.put(("done", st.req.finish_reason or "length",
                          [int(t) for t in out]))
                done.append(rid)
        for rid in done:
            del self._streams[rid]


def _make_handler(worker: EngineWorker):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.0: one response per connection, no chunked framing
        # needed for the SSE stream — the close delimits it.
        protocol_version = "HTTP/1.0"

        def log_message(self, *a):      # silence per-request stderr spam
            pass

        # ------------------------------------------------------ helpers ----
        def _json(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _body(self) -> dict:
            n = int(self.headers.get("Content-Length", 0))
            return json.loads(self.rfile.read(n) or b"{}")

        # ------------------------------------------------------- routes ----
        def do_GET(self):
            eng = worker.engine
            if self.path == "/v1/health":
                return self._json(200, {
                    "ok": True, "steps": eng.stats.steps,
                    "active": eng.sched.n_active,
                    "waiting": len(eng.sched.waiting),
                    "finished": len(eng.sched.finished)})
            if self.path == "/v1/metrics":
                payload = {"summary": eng.summary()}
                if eng.tel.metrics is not None:
                    payload["metrics"] = eng.tel.metrics.to_dict()
                return self._json(200, payload)
            self._json(404, {"error": f"no route {self.path}"})

        def do_POST(self):
            if self.path == "/v1/cancel":
                body = self._body()
                worker.cancel(int(body.get("rid", -1)))
                return self._json(200, {"ok": True})
            if self.path != "/v1/generate":
                return self._json(404, {"error": f"no route {self.path}"})
            try:
                body = self._body()
                prompt = body["prompt"]
                if not prompt:
                    raise ValueError("empty prompt")
            except (ValueError, KeyError, json.JSONDecodeError) as e:
                return self._json(400, {"error": str(e)})
            st = worker.submit(prompt, body.get("max_tokens", 16),
                               body.get("stop"))
            if body.get("stream"):
                return self._stream(st)
            toks: List[int] = []
            while True:
                item = st.q.get()
                if item[0] == "done":
                    return self._json(200, {
                        "rid": st.rid, "finish_reason": item[1],
                        "output": item[2]})
                toks.append(item[1])

        def _stream(self, st: _Stream) -> None:
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            self.end_headers()
            # rid first so the client can POST /v1/cancel mid-stream
            self._event("start", {"rid": st.rid})
            i = 0
            while True:
                item = st.q.get()
                try:
                    if item[0] == "done":
                        self._event("done", {"rid": st.rid,
                                             "finish_reason": item[1],
                                             "output": item[2]})
                        return
                    self._event("token", {"token": item[1], "index": i})
                    i += 1
                except (BrokenPipeError, ConnectionResetError):
                    worker.cancel(st.rid)   # client went away: free blocks
                    return

        def _event(self, event: str, payload: dict) -> None:
            self.wfile.write(f"event: {event}\n"
                             f"data: {json.dumps(payload)}\n\n".encode())
            self.wfile.flush()

    return Handler


class Frontend:
    """HTTP server + engine worker pair.  ``port=0`` binds ephemeral
    (read the chosen one back from ``.port``) — used by the tests."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 8000):
        self.worker = EngineWorker(engine)
        self.httpd = ThreadingHTTPServer((host, port), _make_handler(self.worker))
        self.host, self.port = self.httpd.server_address[:2]
        self._serve_thread: Optional[threading.Thread] = None

    def start(self) -> "Frontend":
        self.worker.start()
        self._serve_thread = threading.Thread(
            target=self.httpd.serve_forever, daemon=True, name="http-serve")
        self._serve_thread.start()
        return self

    def serve_forever(self) -> None:
        """Blocking variant for the CLI (Ctrl-C to stop)."""
        self.worker.start()
        try:
            self.httpd.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            self.stop()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        self.worker.stop()
