"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-v2-236b \
        --smoke --steps 50 --batch 8 --seq 128

``--smoke`` selects the reduced config (CPU-runnable); omit it on real
hardware to train the full config (the mesh is then the production mesh).
Fault tolerance: ``--ckpt-dir`` enables auto-resume; kill and relaunch to
continue from the last complete checkpoint.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro import configs, models
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_production_mesh
from repro.nn import module as nnm
from repro.nn import sharding as shd
from repro.optim import AdamWConfig, adamw_init, cosine
from repro.runtime import LoopConfig, TrainLoop, TrainStepConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none",
                    help="none | single | multi | RxC (e.g. 2x2)")
    ap.add_argument("--impl", default="ref", help="ref | chunked | kernel")
    ap.add_argument("--scheme", default="seq")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.full(args.arch)
    if args.mesh == "none":
        mesh = None
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    else:
        r, c = map(int, args.mesh.split("x"))
        mesh = jax.make_mesh((r, c), ("data", "model"))

    dtype = jnp.float32 if mesh is None else jnp.bfloat16
    params = nnm.init_params(jax.random.PRNGKey(args.seed),
                             models.model_defs(cfg), dtype)
    opt_cfg = AdamWConfig(lr=cosine(args.lr, warmup=20, total=args.steps))
    opt = adamw_init(params, opt_cfg)
    if mesh is not None:
        rules = shd.make_rules(mesh, cfg=cfg)
        shardings = shd.param_shardings(models.model_defs(cfg), mesh, rules)
        params = jax.tree.map(jax.device_put, params, shardings)
        opt = {"step": opt["step"],
               "mu": jax.tree.map(jax.device_put, opt["mu"], shardings),
               "nu": jax.tree.map(jax.device_put, opt["nu"], shardings)}

    step_fn, _ = make_train_step(
        cfg, mesh, opt_cfg,
        TrainStepConfig(microbatches=args.microbatches, compute_dtype=dtype,
                        impl=args.impl, scheme=args.scheme))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    def make_batch(toks, labels):
        b = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if cfg.family in ("vlm", "encdec"):
            P = cfg.n_patches if cfg.family == "vlm" else cfg.n_frames
            key = jax.random.PRNGKey(int(toks[0, 0]))
            b["embeds"] = jax.random.normal(
                key, (toks.shape[0], P, cfg.d_model), dtype) * 0.02
        return b

    ckpt_dir = args.ckpt_dir or os.path.join("/tmp/repro_train", cfg.name)
    loop = TrainLoop(
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   ckpt_dir=ckpt_dir, fail_at_step=args.fail_at),
        step_fn, params, opt, data, make_batch=make_batch)
    metrics = loop.run()
    print(f"[train] done at step {loop.step}: "
          f"loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
