"""Pre-jax-init environment knobs.  DELIBERATELY imports nothing heavy:
the whole point is to mutate ``XLA_FLAGS`` before the first jax backend
call, so callers import this (or copy its one-liner) ahead of jax.

Call sites that must stay self-contained keep their own variants with
different, intentional semantics: launch/dryrun.py prepends
unconditionally (it owns its subprocess env), and the subprocess driver
in tests/test_mesh_paged.py overwrites (fresh interpreter, fixed lane).
"""
from __future__ import annotations

import os

_COUNT_FLAG = "host_platform_device_count"


def force_host_device_count(n: int) -> bool:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS
    unless a count is already forced (a user/CI setting wins).  Returns
    True when the flag was added.  Must run before jax initializes its
    backend — import this module ahead of jax."""
    flags = os.environ.get("XLA_FLAGS", "")
    if n <= 1 or _COUNT_FLAG in flags:
        return False
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_{_COUNT_FLAG}={n}".strip()
    return True
