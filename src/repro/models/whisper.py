"""Whisper-style encoder-decoder backbone (conv frontend STUBBED).

Per the assignment, the modality frontend is a stub: ``input_specs`` /
callers provide precomputed frame embeddings (B, n_frames, D) in place of
the log-mel conv stack.  What we build faithfully is the transformer:

  encoder : n_enc_layers x [LN -> bidirectional MHA -> LN -> GELU MLP]
  decoder : n_layers     x [LN -> causal self-MHA (cached)
                             -> LN -> cross-MHA over encoder states
                             -> LN -> GELU MLP]

Whisper fidelity notes: pre-LN LayerNorm (not RMSNorm), GELU MLP, biased
projections, learned decoder position embeddings, sinusoidal encoder
positions (added by the stub frontend upstream, so omitted here).

Decode-time caches:
  self-attn : standard per-layer KV cache over generated tokens
  cross-attn: K/V of the encoder states, computed ONCE at prefill — the
    extreme "reuse" point of the paper's recompute/reuse spectrum (zero
    marginal FLOPs per step, pure streaming), called out in DESIGN.md §5.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core import cache as cachelib
from ..core.attention import gqa_attention, gqa_decode
from ..core.chunked_attention import chunked_attention_pairs
from ..nn import layers as nl
from ..nn import module as nnm
from ..nn.module import P
from .common import ModelConfig


# ------------------------------------------------------------------ defs ---


def _attn_defs(cfg: ModelConfig) -> Dict:
    D, H, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    return {
        "w_q": P((D, H, dh), ("embed", "heads", None)),
        "b_q": P((H, dh), ("heads", None), init="zeros"),
        "w_k": P((D, H, dh), ("embed", "heads", None)),
        "w_v": P((D, H, dh), ("embed", "heads", None)),
        "b_v": P((H, dh), ("heads", None), init="zeros"),
        "w_o": P((H, dh, D), ("heads", None, "embed")),
        "b_o": P((D,), (None,), init="zeros"),
    }


def _enc_layer_defs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": nl.layernorm_defs(cfg.d_model),
        "attn": _attn_defs(cfg),
        "ln2": nl.layernorm_defs(cfg.d_model),
        "mlp": nl.mlp_defs(cfg.d_model, cfg.d_ff, kind="gelu", bias=True),
    }


def _dec_layer_defs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": nl.layernorm_defs(cfg.d_model),
        "self_attn": _attn_defs(cfg),
        "ln_x": nl.layernorm_defs(cfg.d_model),
        "cross_attn": _attn_defs(cfg),
        "ln2": nl.layernorm_defs(cfg.d_model),
        "mlp": nl.mlp_defs(cfg.d_model, cfg.d_ff, kind="gelu", bias=True),
    }


def whisper_defs(cfg: ModelConfig) -> Dict:
    d: Dict = {
        "embed": nl.embed_defs(cfg.vocab, cfg.d_model),
        "pos_dec": P((cfg.max_seq, cfg.d_model), (None, "embed"),
                     init="normal", scale=0.02),
        "ln_enc": nl.layernorm_defs(cfg.d_model),
        "ln_dec": nl.layernorm_defs(cfg.d_model),
    }
    d["encoder"] = nnm.stack_defs(_enc_layer_defs(cfg), cfg.n_enc_layers, "layers")
    d["decoder"] = nnm.stack_defs(_dec_layer_defs(cfg), cfg.n_layers, "layers")
    return d


def param_count(cfg: ModelConfig) -> int:
    return nnm.count_params(whisper_defs(cfg))


# ------------------------------------------------------------- attention ---


def _proj_qkv(params, x, which=("q", "k", "v")):
    outs = []
    for n in which:
        y = jnp.einsum("bld,dhk->blhk", x, params[f"w_{n}"].astype(x.dtype))
        if f"b_{n}" in params:
            y = y + params[f"b_{n}"].astype(x.dtype)
        outs.append(y)
    return outs


def _out_proj(params, o, dtype):
    return jnp.einsum("blhk,hkd->bld", o, params["w_o"].astype(dtype)) \
        + params["b_o"].astype(dtype)


def _mha(params, x, kv_src, *, causal: bool, impl: str = "ref") -> jax.Array:
    """Full-sequence MHA; kv_src=x for self, encoder states for cross."""
    q, = _proj_qkv(params, x, ("q",))
    k, v = _proj_qkv(params, kv_src, ("k", "v"))
    if impl == "chunked" and causal:   # long causal self-attn: bound memory
        o = chunked_attention_pairs(q, k, v, True, None, 0, None)
    else:
        o = gqa_attention(q, k, v, causal=causal)
    return _out_proj(params, o, x.dtype)


# ----------------------------------------------------------------- model ---


def encode(params, cfg: ModelConfig, frames) -> jax.Array:
    """frames: (B, n_frames, D) precomputed stub embeddings -> enc states."""
    x = frames

    def layer(x, p):
        h = nl.layernorm(p["ln1"], x)
        x = x + _mha(p["attn"], h, h, causal=False)
        h = nl.layernorm(p["ln2"], x)
        x = x + nl.mlp(p["mlp"], h, kind="gelu")
        return x, ()

    body = layer
    if cfg.remat:
        body = jax.checkpoint(layer)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return nl.layernorm(params["ln_enc"], x)


def _dec_embed(params, cfg: ModelConfig, tokens, pos_start):
    x = nl.embed(params["embed"], tokens, jnp.bfloat16)
    L = tokens.shape[1]
    pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos_start, L, 0)
    return (x + pos.astype(x.dtype)[None]).astype(x.dtype)


def decoder_forward(params, cfg: ModelConfig, tokens, enc_states,
                    *, compute_dtype=jnp.bfloat16, impl: str = "ref",
                    return_hidden: bool = False) -> jax.Array:
    """Teacher-forced decoder pass. tokens: (B, L) -> logits (B, L, V)."""
    x = _dec_embed(params, cfg, tokens, 0).astype(compute_dtype)
    enc = enc_states.astype(compute_dtype)

    def layer(x, p):
        h = nl.layernorm(p["ln1"], x)
        x = x + _mha(p["self_attn"], h, h, causal=True, impl=impl)
        h = nl.layernorm(p["ln_x"], x)
        x = x + _mha(p["cross_attn"], h, enc, causal=False)
        h = nl.layernorm(p["ln2"], x)
        x = x + nl.mlp(p["mlp"], h, kind="gelu")
        return x, ()

    body = layer
    if cfg.remat:
        body = jax.checkpoint(layer)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = nl.layernorm(params["ln_dec"], x)
    if return_hidden:
        return x
    return nl.unembed(params["embed"], x)


def forward(params, cfg: ModelConfig, tokens, *, embeds=None,
            compute_dtype=jnp.bfloat16, impl: str = "ref",
            return_hidden: bool = False, **_unused) -> Tuple[jax.Array, Dict]:
    """Training forward: encoder on stub frames + teacher-forced decoder.
    embeds: (B, n_frames, D) stub frame embeddings (required)."""
    enc = encode(params, cfg, embeds.astype(compute_dtype))
    logits = decoder_forward(params, cfg, tokens, enc,
                             compute_dtype=compute_dtype, impl=impl,
                             return_hidden=return_hidden)
    aux = {"balance": jnp.float32(0), "z_loss": jnp.float32(0),
           "dropped_frac": jnp.float32(0)}
    return logits, aux


# ---------------------------------------------------------------- serving --


def _cross_kv(params_layer, enc):
    """Precompute cross-attn K/V from encoder states (once per request)."""
    k, v = _proj_qkv(params_layer["cross_attn"], enc, ("k", "v"))
    return k, v


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16) -> Dict:
    H, dh, NL = cfg.n_heads, cfg.resolved_head_dim, cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((NL, batch, capacity, H, dh), dtype),
            "v": jnp.zeros((NL, batch, capacity, H, dh), dtype),
        },
        "cross": {
            "k": jnp.zeros((NL, batch, cfg.n_frames, H, dh), dtype),
            "v": jnp.zeros((NL, batch, cfg.n_frames, H, dh), dtype),
        },
    }


def prefill(params, cfg: ModelConfig, tokens, *, embeds=None, capacity: int = 0,
            compute_dtype=jnp.bfloat16, impl: str = "ref",
            **_unused) -> Tuple[jax.Array, Dict]:
    """Encode stub frames, precompute cross K/V, run the decoder prompt.
    Returns (last-token logits, cache)."""
    B, L = tokens.shape
    cap = capacity or L
    enc = encode(params, cfg, embeds.astype(compute_dtype))
    x = _dec_embed(params, cfg, tokens, 0).astype(compute_dtype)

    def layer(x, p):
        ck, cv = _cross_kv(p, enc)
        h = nl.layernorm(p["ln1"], x)
        q, = _proj_qkv(p["self_attn"], h, ("q",))
        k, v = _proj_qkv(p["self_attn"], h, ("k", "v"))
        if impl == "chunked":
            o = chunked_attention_pairs(q, k, v, True, None, 0, None)
        else:
            o = gqa_attention(q, k, v, causal=True)
        x = x + _out_proj(p["self_attn"], o, x.dtype)
        h = nl.layernorm(p["ln_x"], x)
        q, = _proj_qkv(p["cross_attn"], h, ("q",))
        o = gqa_attention(q, ck, cv, causal=False)
        x = x + _out_proj(p["cross_attn"], o, x.dtype)
        h = nl.layernorm(p["ln2"], x)
        x = x + nl.mlp(p["mlp"], h, kind="gelu")
        pad = lambda a: jnp.pad(a, ((0, 0), (0, cap - L), (0, 0), (0, 0)))
        return x, (pad(k), pad(v), ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(layer, x, params["decoder"])
    x = nl.layernorm(params["ln_dec"], x)
    logits = nl.unembed(params["embed"], x[:, -1])
    cache = {"self": {"k": ks, "v": vs}, "cross": {"k": cks, "v": cvs}}
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache, index, *,
                compute_dtype=jnp.bfloat16, **_unused) -> Tuple[jax.Array, Dict]:
    """One decoder token. token: (B,), index: current cache length."""
    x = _dec_embed(params, cfg, token[:, None], index)[:, 0].astype(compute_dtype)

    def layer(x, slices):
        p, ks, vs, ck, cv = slices
        h = nl.layernorm(p["ln1"], x[:, None])[:, 0]
        q = jnp.einsum("bd,dhk->bhk", h, p["self_attn"]["w_q"].astype(x.dtype)) \
            + p["self_attn"]["b_q"].astype(x.dtype)
        k = jnp.einsum("bd,dhk->bhk", h, p["self_attn"]["w_k"].astype(x.dtype))
        v = jnp.einsum("bd,dhk->bhk", h, p["self_attn"]["w_v"].astype(x.dtype)) \
            + p["self_attn"]["b_v"].astype(x.dtype)
        new = cachelib.update_kv({"k": ks, "v": vs}, k[:, None], v[:, None], index)
        o = gqa_decode(q, new["k"], new["v"], index)
        x = x + (jnp.einsum("bhk,hkd->bd", o, p["self_attn"]["w_o"].astype(x.dtype))
                 + p["self_attn"]["b_o"].astype(x.dtype))
        h = nl.layernorm(p["ln_x"], x[:, None])[:, 0]
        q = jnp.einsum("bd,dhk->bhk", h, p["cross_attn"]["w_q"].astype(x.dtype)) \
            + p["cross_attn"]["b_q"].astype(x.dtype)
        o = gqa_decode(q, ck, cv, ck.shape[1] - 1)   # all frames valid
        x = x + (jnp.einsum("bhk,hkd->bd", o, p["cross_attn"]["w_o"].astype(x.dtype))
                 + p["cross_attn"]["b_o"].astype(x.dtype))
        h = nl.layernorm(p["ln2"], x[:, None])[:, 0]
        x = x + nl.mlp(p["mlp"], h, kind="gelu")
        return x, (new["k"], new["v"])

    x, (ks, vs) = jax.lax.scan(
        layer, x, (params["decoder"], cache["self"]["k"], cache["self"]["v"],
                   cache["cross"]["k"], cache["cross"]["v"]))
    x = nl.layernorm(params["ln_dec"], x[:, None])[:, 0]
    logits = nl.unembed(params["embed"], x)
    return logits, {"self": {"k": ks, "v": vs}, "cross": cache["cross"]}
