"""Unified decoder-only LM covering 9 of the 10 assigned architectures
(whisper's encoder-decoder lives in whisper.py and reuses these blocks).

Layer stack = prefix (unrolled) + scanned periods (stacked weights) +
suffix (unrolled).  Scanning keeps the HLO — and 512-way GSPMD partitioning
time — independent of depth (granite-34b: 88 layers, one scanned body).

Public API (all pure):
    lm_defs(cfg)                                   param definitions
    forward(params, cfg, tokens, ...)   -> logits, aux       (train)
    prefill(params, cfg, tokens, ...)   -> last_logits, cache
    decode_step(params, cfg, token, cache, index, ...) -> logits, cache
    init_cache(cfg, batch, capacity, dtype)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn import layers as nl
from ..nn import module as nnm
from .blocks import Ctx, ZERO_AUX, sub_apply, sub_cache, sub_defs
from .common import ModelConfig


# ------------------------------------------------------------------ defs ---


def lm_defs(cfg: ModelConfig) -> Dict:
    prefix, period, n_periods, suffix = cfg.layer_plan()
    d: Dict = {"embed": nl.embed_defs(cfg.vocab, cfg.d_model),
               "ln_f": nl.rmsnorm_defs(cfg.d_model)}
    d["prefix"] = {f"l{i}": sub_defs(cfg, desc, d_ff=cfg.first_dense_d_ff or None)
                   for i, desc in enumerate(prefix)}
    if n_periods:
        period_defs = {f"s{i}": sub_defs(cfg, desc) for i, desc in enumerate(period)}
        d["period"] = nnm.stack_defs(period_defs, n_periods, "layers")
    d["suffix"] = {f"l{i}": sub_defs(cfg, desc) for i, desc in enumerate(suffix)}
    return d


def param_count(cfg: ModelConfig) -> int:
    return nnm.count_params(lm_defs(cfg))


# ----------------------------------------------------------------- stack ---


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def _zero_aux():
    return {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}


def _run_stack(params, cfg: ModelConfig, x, ctx: Ctx, caches: Optional[Dict]):
    """Returns (x, new_caches (same structure) or None, aux_sum)."""
    prefix, period, n_periods, suffix = cfg.layer_plan()
    aux_sum = _zero_aux()
    new_caches: Dict = {"prefix": {}, "suffix": {}}
    with_cache = ctx.mode != "train"

    for i, desc in enumerate(prefix):
        c = caches["prefix"][f"l{i}"] if caches else None
        x, nc, aux = sub_apply(params["prefix"][f"l{i}"], cfg, desc, x,
                               dataclasses.replace(ctx, cache=c))
        new_caches["prefix"][f"l{i}"] = nc
        aux_sum = _tree_add(aux_sum, aux)

    if n_periods:
        def body(x, slices):
            p_slice, c_slice = slices
            nc_period: Dict = {}
            aux_tot = _zero_aux()
            for i, desc in enumerate(period):
                c = c_slice[f"s{i}"] if c_slice is not None else None
                x, nc, aux = sub_apply(p_slice[f"s{i}"], cfg, desc, x,
                                       dataclasses.replace(ctx, cache=c))
                nc_period[f"s{i}"] = nc
                aux_tot = _tree_add(aux_tot, aux)
            return x, (nc_period, aux_tot)

        if ctx.mode == "train" and cfg.remat:
            body = jax.checkpoint(body)
        c_stacked = caches["period"] if caches else None
        xs = (params["period"], c_stacked)
        x, (nc_stacked, auxs) = jax.lax.scan(body, x, xs)
        if with_cache:
            new_caches["period"] = nc_stacked
        aux_sum = _tree_add(aux_sum, jax.tree.map(jnp.sum, auxs))

    for i, desc in enumerate(suffix):
        c = caches["suffix"][f"l{i}"] if caches else None
        x, nc, aux = sub_apply(params["suffix"][f"l{i}"], cfg, desc, x,
                               dataclasses.replace(ctx, cache=c))
        new_caches["suffix"][f"l{i}"] = nc
        aux_sum = _tree_add(aux_sum, aux)

    return x, (new_caches if with_cache else None), aux_sum


def _embed(params, cfg: ModelConfig, tokens, embeds, dtype):
    x = nl.embed(params["embed"], tokens, dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(dtype), x], axis=1)
    return x


def _logits(params, cfg: ModelConfig, x):
    x = nl.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return nl.unembed(params["embed"], x)


# ------------------------------------------------------------ public API ---


def forward(params, cfg: ModelConfig, tokens, *, embeds=None,
            compute_dtype=jnp.bfloat16, impl: str = "ref", mesh=None,
            scheme: str = "seq", return_hidden: bool = False
            ) -> Tuple[jax.Array, Dict]:
    """Training forward. tokens: (B, L_text); embeds: (B, P, D) stub
    modality prefix (vlm/audio). Returns (logits (B, L, V), aux); with
    ``return_hidden`` the final-norm hidden states (B, L, D) instead of
    logits (vocab-chunked loss does its own unembed — see runtime.steps)."""
    x = _embed(params, cfg, tokens, embeds, compute_dtype)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    ctx = Ctx(mode="train", positions=positions, impl=impl, mesh=mesh,
              scheme=scheme)
    x, _, aux = _run_stack(params, cfg, x, ctx, None)
    if return_hidden:
        return nl.rmsnorm(params["ln_f"], x, cfg.norm_eps), aux
    return _logits(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, tokens, *, embeds=None, capacity: int = 0,
            compute_dtype=jnp.bfloat16, impl: str = "ref", mesh=None,
            scheme: str = "seq", shard_mode: str = "train"
            ) -> Tuple[jax.Array, Dict]:
    """Returns (last-token logits (B, V), cache filled with L entries)."""
    x = _embed(params, cfg, tokens, embeds, compute_dtype)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    ctx = Ctx(mode="prefill", positions=positions, impl=impl, mesh=mesh,
              scheme=scheme, capacity=capacity or L, shard_mode=shard_mode)
    x, caches, _ = _run_stack(params, cfg, x, ctx, None)
    return _logits(params, cfg, x[:, -1]), caches


def prefill_chunk_paged(params, cfg: ModelConfig, tokens, pool,
                        block_tables, lengths, n_valid, *,
                        compute_dtype=jnp.bfloat16, impl: str = "ref",
                        mesh=None, scheme: str = "seq",
                        shard_mode: str = "serve") -> Tuple[jax.Array, Dict]:
    """One batched prefill CHUNK straight into the paged pool.

    tokens: (B, C) int32 — row b holds its request's next ``n_valid[b]``
    prompt tokens (rest is padding), starting at absolute position
    ``lengths[b]`` (tokens already resident: prefix-cache hits + earlier
    chunks).  Returns (logits (B, V) of each row's LAST VALID position,
    new_pool) — the logits row matters only for the chunk that finishes a
    request's prompt (it samples generated token #1); other rows are
    discarded by the engine.  One compiled shape per (B, C), independent
    of prompt length — the whole point vs the per-plen retraces of the
    contiguous prefill.

    ``impl`` 'kernel' / 'pallas' routes the chunk attention through the
    fused paged Pallas prefill kernel (kernels.mla_prefill): the block
    table is walked in place, no contiguous (B, S) gather of the pool is
    materialized.  'ref' keeps the gather reference path.  With ``mesh``
    the kernel path runs under shard_map (batch over DP, heads over
    'model', pool replicated — kernels.ops.mla_prefill_paged_attention);
    the gather path is partitioned by GSPMD."""
    x, caches = _chunk_paged_hidden(params, cfg, tokens, pool, block_tables,
                                    lengths, n_valid,
                                    compute_dtype=compute_dtype, impl=impl,
                                    mesh=mesh, scheme=scheme,
                                    shard_mode=shard_mode)
    B = x.shape[0]
    last = jnp.maximum(jnp.asarray(n_valid, jnp.int32) - 1, 0)
    h = x[jnp.arange(B), last]                    # (B, D) last valid hidden
    return _logits(params, cfg, h), caches


def verify_chunk_paged(params, cfg: ModelConfig, tokens, pool,
                       block_tables, lengths, n_valid, *,
                       compute_dtype=jnp.bfloat16, impl: str = "ref",
                       mesh=None, scheme: str = "seq",
                       shard_mode: str = "serve") -> Tuple[jax.Array, Dict]:
    """Multi-token VERIFY step for speculative decoding: the chunked
    paged prefill with logits at EVERY chunk position.

    tokens: (B, C) int32 — row b carries [last sampled token, draft_1 ..
    draft_{n_valid[b]-1}] at absolute positions lengths[b].. (the verify
    window; C = k + 1).  Same attention math and same pool scatter as
    :func:`prefill_chunk_paged` — scoring k + 1 positions re-reads each
    request's resident latent prefix exactly ONCE, which is the cache-read
    amortization speculative decoding exists for (hwmodel.attention_costs
    .mla_verify_cost) — but the head returns (B, C, V): position j's
    logits row is the target's next-token distribution after draft j,
    which the engine samples with the same fold(rid, position) keys plain
    decode uses, so accepted streams are token-identical to plain decode.
    Rows/positions past ``n_valid`` scatter to the null block and their
    logits are garbage the engine never reads."""
    x, caches = _chunk_paged_hidden(params, cfg, tokens, pool, block_tables,
                                    lengths, n_valid,
                                    compute_dtype=compute_dtype, impl=impl,
                                    mesh=mesh, scheme=scheme,
                                    shard_mode=shard_mode)
    return _logits(params, cfg, x), caches


def _chunk_paged_hidden(params, cfg: ModelConfig, tokens, pool,
                        block_tables, lengths, n_valid, *,
                        compute_dtype, impl, mesh, scheme, shard_mode):
    """Shared body of prefill_chunk_paged / verify_chunk_paged: run one
    (B, C) chunk through the stack against the paged pool; returns the
    pre-norm hidden states (B, C, D) and the updated pool."""
    x = _embed(params, cfg, tokens, None, compute_dtype)
    ctx = Ctx(mode="prefill_chunk", positions=None, impl=impl, mesh=mesh,
              scheme=scheme, shard_mode=shard_mode,
              block_tables=block_tables, lengths=lengths, n_valid=n_valid)
    return _run_stack(params, cfg, x, ctx, pool)[:2]


def decode_step(params, cfg: ModelConfig, token, cache, index, *,
                compute_dtype=jnp.bfloat16, impl: str = "ref", mesh=None,
                scheme: str = "seq", shard_mode: str = "train",
                block_tables=None, lengths=None) -> Tuple[jax.Array, Dict]:
    """token: (B,) int32; index: scalar (current cache length).
    Returns (logits (B, V), updated cache).

    Paged continuous-batching decode: pass ``lengths`` (B,) int32 ragged
    per-request cache lengths and ``block_tables`` (B, max_blocks) with a
    paged ``cache`` tree (see init_paged_cache); ``index`` is ignored."""
    x = _embed(params, cfg, token[:, None], None, compute_dtype)[:, 0]
    ctx = Ctx(mode="decode", positions=None, index=index, impl=impl,
              mesh=mesh, scheme=scheme, shard_mode=shard_mode,
              block_tables=block_tables, lengths=lengths)
    x, caches, _ = _run_stack(params, cfg, x, ctx, cache)
    return _logits(params, cfg, x), caches


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16) -> Dict:
    prefix, period, n_periods, suffix = cfg.layer_plan()
    out: Dict = {
        "prefix": {f"l{i}": sub_cache(cfg, d, batch, capacity, dtype)
                   for i, d in enumerate(prefix)},
        "suffix": {f"l{i}": sub_cache(cfg, d, batch, capacity, dtype)
                   for i, d in enumerate(suffix)},
    }
    if n_periods:
        one = {f"s{i}": sub_cache(cfg, d, batch, capacity, dtype)
               for i, d in enumerate(period)}
        out["period"] = jax.tree.map(
            lambda a: jnp.tile(a[None], (n_periods,) + (1,) * a.ndim), one)
    return out


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, cache_dtype=None) -> Dict:
    """Paged decode-state tree: same layer structure as init_cache but every
    MLA latent cache is a (num_blocks, block_size, .) block pool shared by
    all requests.  Block tables / lengths live OUTSIDE this tree (one table
    per request, shared across layers) and are passed to decode_step.
    ``cache_dtype`` in {int8, fp8} quantizes every pool (per-token-slot
    scale leaves ride the tree — see core.cache.paged_latent_cache)."""
    from .blocks import sub_paged_cache
    prefix, period, n_periods, suffix = cfg.layer_plan()
    out: Dict = {
        "prefix": {f"l{i}": sub_paged_cache(cfg, d, num_blocks, block_size,
                                            dtype, cache_dtype)
                   for i, d in enumerate(prefix)},
        "suffix": {f"l{i}": sub_paged_cache(cfg, d, num_blocks, block_size,
                                            dtype, cache_dtype)
                   for i, d in enumerate(suffix)},
    }
    if n_periods:
        one = {f"s{i}": sub_paged_cache(cfg, d, num_blocks, block_size,
                                        dtype, cache_dtype)
               for i, d in enumerate(period)}
        out["period"] = jax.tree.map(
            lambda a: jnp.tile(a[None], (n_periods,) + (1,) * a.ndim), one)
    return out
