"""Unified model configuration + per-layer descriptors.

Every assigned architecture is expressed as a ``ModelConfig`` whose layer
stack is a repeating *period* of sublayer descriptors (``Sub``): e.g.
gemma3 = 5x local-attn + 1x global-attn, jamba = 7x mamba + 1x attn with
MoE every 2nd layer, xlstm = alternating mLSTM/sLSTM.  Periods are scanned
(stacked weights, ``lax.scan``) so HLO size — and 512-way GSPMD compile
time — is independent of depth; remainder/prefix layers are unrolled.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..core.mla import MLAConfig


@dataclasses.dataclass(frozen=True)
class Sub:
    """Static sublayer descriptor."""
    mixer: str = "attn"          # attn | mamba | mlstm | slstm
    ffn: str = "dense"           # dense | moe | none
    window: Optional[int] = None  # sliding-window size for local attention
    rope_base: float = 10000.0
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp_kind: str = "swiglu"     # swiglu | gelu
    rope_base: float = 10000.0
    norm_eps: float = 1e-6
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale
    # -- attention ------------------------------------------------------
    attn_kind: str = "gqa"       # gqa | mla
    window: Optional[int] = None
    local_global_period: int = 0  # N>0: every Nth layer global, rest local
    global_rope_base: float = 1_000_000.0
    # -- MLA (attn_kind='mla') -----------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # -- MoE -------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # -- hybrid (jamba) ---------------------------------------------------
    attn_period: int = 0         # 1 attention layer per N (rest mamba)
    attn_offset: int = 3         # position of the attn layer in the period
    moe_period: int = 0          # MoE every Nth layer
    d_state: int = 16
    conv_width: int = 4
    expand: int = 2
    # -- ssm (xlstm) ------------------------------------------------------
    slstm_every: int = 0         # alternate mLSTM/sLSTM every Nth layer
    # -- encoder-decoder (whisper) ---------------------------------------
    n_enc_layers: int = 0
    n_frames: int = 1500         # stub conv-frontend output length
    # -- vlm ---------------------------------------------------------------
    n_patches: int = 0           # stub ViT patch embeddings prepended
    # -- runtime -----------------------------------------------------------
    max_seq: int = 8192
    remat: bool = True
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, (self.d_model + 15) // 16)

    def mla_config(self) -> MLAConfig:
        return MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            q_lora_rank=self.q_lora_rank, kv_lora_rank=self.kv_lora_rank,
            qk_nope_dim=self.qk_nope_dim, qk_rope_dim=self.qk_rope_dim,
            v_head_dim=self.v_head_dim, rope_base=self.rope_base)

    # ------------------------------------------------ layer structure ----

    def layer_plan(self) -> Tuple[List[Sub], List[Sub], int, List[Sub]]:
        """Returns (prefix, period, n_periods, suffix)."""
        subs: List[Sub] = []
        for i in range(self.n_layers):
            mixer = "attn"
            if self.attn_period:
                mixer = "attn" if i % self.attn_period == self.attn_offset else "mamba"
            elif self.slstm_every:
                mixer = "slstm" if i % self.slstm_every == self.slstm_every - 1 else "mlstm"
            ffn = "dense"
            if self.d_ff == 0:
                ffn = "none"
            if self.n_experts and i >= self.first_dense_layers:
                if self.moe_period == 0 or i % self.moe_period == self.moe_period - 1:
                    ffn = "moe"
            window, base = None, self.rope_base
            if self.local_global_period:
                if i % self.local_global_period == self.local_global_period - 1:
                    window, base = None, self.global_rope_base   # global layer
                else:
                    window, base = self.window, self.rope_base    # local layer
            elif self.window:
                window = self.window
            subs.append(Sub(mixer=mixer, ffn=ffn, window=window, rope_base=base))

        prefix = subs[: self.first_dense_layers]
        rest = subs[self.first_dense_layers:]
        # find the shortest repeating period among candidate lengths
        plen = 1
        for cand in (self.local_global_period or 0, self.attn_period or 0,
                     self.slstm_every or 0, self.moe_period or 0, 1):
            if cand:
                plen = max(plen, cand)
        if self.attn_period and self.moe_period:
            import math
            plen = self.attn_period * self.moe_period // math.gcd(
                self.attn_period, self.moe_period)
        # NOTE: the unrolled fallbacks return prefix + rest — dropping the
        # first_dense prefix here silently shed layers for short stacks
        # (e.g. the shallow self-speculation drafts of runtime.spec, which
        # truncate deepseek's 1-dense + N-MoE plan below one full period).
        if not self.scan_layers:
            return prefix + rest, [], 0, []
        n_periods = len(rest) // plen
        period = rest[:plen] if n_periods > 0 else []
        # verify periodicity; if broken, fall back to unrolled
        for p in range(n_periods):
            if rest[p * plen:(p + 1) * plen] != period:
                return prefix + rest, [], 0, []
        suffix = rest[n_periods * plen:]
        if n_periods <= 1:
            return prefix + rest, [], 0, []
        return prefix, period, n_periods, suffix
