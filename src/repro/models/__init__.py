"""Unified model API: every architecture exposes the same five functions,
dispatched on ``cfg.family`` ('encdec' -> whisper, everything else -> the
generic decoder-only LM stack).

    model_defs(cfg)                      param definitions (P tree)
    forward(params, cfg, tokens, ...)    training logits + aux losses
    prefill(params, cfg, tokens, ...)    last-token logits + filled cache
    decode_step(params, cfg, tok, ...)   one-token serve step
    init_cache(cfg, batch, capacity)     decode-state pytree
"""
from __future__ import annotations

from . import lm, whisper
from .common import ModelConfig, Sub


def _mod(cfg: ModelConfig):
    return whisper if cfg.family == "encdec" else lm


def model_defs(cfg: ModelConfig):
    return whisper.whisper_defs(cfg) if cfg.family == "encdec" else lm.lm_defs(cfg)


def param_count(cfg: ModelConfig) -> int:
    return nnm_count(cfg)


def nnm_count(cfg: ModelConfig) -> int:
    from ..nn import module as nnm
    return nnm.count_params(model_defs(cfg))


def forward(params, cfg: ModelConfig, tokens, **kw):
    return _mod(cfg).forward(params, cfg, tokens, **kw)


def prefill(params, cfg: ModelConfig, tokens, **kw):
    return _mod(cfg).prefill(params, cfg, tokens, **kw)


def decode_step(params, cfg: ModelConfig, token, cache, index, **kw):
    return _mod(cfg).decode_step(params, cfg, token, cache, index, **kw)


def prefill_chunk_paged(params, cfg: ModelConfig, tokens, pool, block_tables,
                        lengths, n_valid, **kw):
    """Batched chunked prefill into the paged latent pool (MLA decoder-only
    models; see models.lm.prefill_chunk_paged)."""
    if cfg.family == "encdec":
        raise NotImplementedError("paged serving targets decoder-only MLA")
    return lm.prefill_chunk_paged(params, cfg, tokens, pool, block_tables,
                                  lengths, n_valid, **kw)


def verify_chunk_paged(params, cfg: ModelConfig, tokens, pool, block_tables,
                       lengths, n_valid, **kw):
    """Speculative-decode verify step: chunked paged prefill returning
    logits at every position (see models.lm.verify_chunk_paged)."""
    if cfg.family == "encdec":
        raise NotImplementedError("paged serving targets decoder-only MLA")
    return lm.verify_chunk_paged(params, cfg, tokens, pool, block_tables,
                                 lengths, n_valid, **kw)


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None):
    import jax.numpy as jnp
    dtype = dtype if dtype is not None else jnp.bfloat16
    return _mod(cfg).init_cache(cfg, batch, capacity, dtype)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=None, cache_dtype=None):
    """Paged latent-KV block pool tree for continuous-batching decode
    (MLA architectures only; see models.lm.init_paged_cache).
    ``cache_dtype`` in {int8, fp8} stores the pool quantized with
    per-token-slot scale leaves riding the tree (core.cache)."""
    import jax.numpy as jnp
    if cfg.family == "encdec":
        raise NotImplementedError("paged serving targets decoder-only MLA")
    dtype = dtype if dtype is not None else jnp.bfloat16
    return lm.init_paged_cache(cfg, num_blocks, block_size, dtype,
                               cache_dtype=cache_dtype)
