"""Mixture-of-Experts FFN with expert parallelism (EP over 'model').

Dispatch strategy (see DESIGN.md §7): activations are replicated across the
'model' axis at the MoE boundary (they already are, post-attention
all-reduce), so each EP rank *locally selects* the tokens routed to its own
expert shard — no all-to-all is required; the outputs are combined by the
same psum a row-parallel FFN would need anyway.  Sort-based position
assignment (argsort over expert ids) avoids materializing the (T, E, C)
one-hot dispatch tensor of the GShard formulation, which at
T=32k, E=160, C=1.5k would be ~16 GB/device.

Capacity: C = ceil(T_local * top_k / E * capacity_factor); overflow tokens
are dropped (standard token-choice semantics).  Router aux losses
(load-balance + z-loss) are returned for the trainer.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import compat
from ..nn import layers as nl
from ..nn.module import P
from .common import ModelConfig


def moe_defs(cfg: ModelConfig) -> Dict:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    # 'expert_mlp' on F: replicated under the train rules (EP over 'model'
    # suffices); 2D-sharded (experts x F) under serve_2dtp so the expert
    # bank stays resident at decode (EXPERIMENTS.md §Perf A1).
    d: Dict = {
        "router": P((D, E), ("embed", None), init="normal", scale=0.02),
        "gate_up": P((E, D, 2, F), ("experts", "embed", None, "expert_mlp")),
        "down": P((E, F, D), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        d["shared"] = nl.mlp_defs(D, cfg.n_shared_experts * F, kind="swiglu")
    return d


def _capacity(t_local: int, cfg: ModelConfig) -> int:
    return max(4, int(math.ceil(t_local * cfg.top_k / cfg.n_experts
                                * cfg.capacity_factor)))


def _moe_local(x, router_w, gate_up, down, *, cfg: ModelConfig,
               model_axis: Optional[str], f_axis: Optional[str] = None):
    """x: (T, D) local tokens (replicated over model axis); gate_up/down are
    the LOCAL expert shard (possibly also F-sharded over ``f_axis`` in the
    serve_2dtp layout). Returns (out (T,D) partial-summed, aux dict)."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_local = gate_up.shape[0]
    C = _capacity(T, cfg)

    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, k)                      # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- aux losses (load balance + z-loss) ---------------------------
    density = jnp.mean(jax.nn.one_hot(sel, E, dtype=jnp.float32), axis=(0, 1))
    balance = E * jnp.sum(density * jnp.mean(probs, axis=0)) * k
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based slot assignment ------------------------------------
    flat_e = sel.reshape(-1)                                      # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(T * k) - seg_start[se]
    keep = pos < C

    tok_tbl = jnp.full((E, C), T, jnp.int32)                      # T = pad row
    tok_tbl = tok_tbl.at[se, pos].set(jnp.where(keep, st, T), mode="drop")
    gate_tbl = jnp.zeros((E, C), jnp.float32)
    gate_tbl = gate_tbl.at[se, pos].set(jnp.where(keep, sg, 0.0), mode="drop")

    # local expert shard of the tables
    r = jax.lax.axis_index(model_axis) if model_axis else 0
    tok_loc = jax.lax.dynamic_slice_in_dim(tok_tbl, r * E_local, E_local, 0)
    gate_loc = jax.lax.dynamic_slice_in_dim(gate_tbl, r * E_local, E_local, 0)

    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    x_e = x_pad[tok_loc]                                          # (El, C, D)
    h = jnp.einsum("ecd,edgf->ecgf", x_e, gate_up.astype(x.dtype))
    h = jax.nn.silu(h[:, :, 0]) * h[:, :, 1]                      # (El, C, F)
    y_e = jnp.einsum("ecf,efd->ecd", h, down.astype(x.dtype))
    y_e = y_e * gate_loc[..., None].astype(x.dtype)

    out = jnp.zeros((T + 1, D), x.dtype)
    out = out.at[tok_loc.reshape(-1)].add(y_e.reshape(-1, D))[:T]
    axes = tuple(a for a in (model_axis, f_axis) if a)
    if axes:
        out = jax.lax.psum(out, axes)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"balance": balance, "z_loss": z_loss, "dropped_frac": dropped}
    return out, aux


def moe_apply(params, cfg: ModelConfig, x, *, mesh=None,
              shard_mode: str = "train") -> Tuple[jax.Array, Dict]:
    """x: (B, L, D) or (B, D). Shared experts (dense, TP-sharded) computed
    outside the shard_map; routed experts inside (EP).

    shard_mode='serve_2dtp': tokens replicated (decode activations are
    KB-sized), expert bank 2D-sharded (experts over 'model', F over
    'data') and RESIDENT — the psum over both axes replaces the baseline's
    per-step 5 GB/layer weight all-gather with an activation-sized
    reduction (EXPERIMENTS.md §Perf A1)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim == 3 else x

    if mesh is not None and "model" in mesh.axis_names:
        from jax.sharding import PartitionSpec as PS
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if shard_mode == "serve_2dtp":
            f_ax = "data" if "data" in mesh.axis_names and \
                cfg.moe_d_ff % sizes.get("data", 1) == 0 else None
            fn = lambda xl, rw, gu, dn: _moe_local(
                xl, rw, gu, dn, cfg=cfg, model_axis="model", f_axis=f_ax)
            out, aux = compat.shard_map(
                fn, mesh=mesh,
                in_specs=(PS(None, None), PS(None, None),
                          PS("model", None, None, f_ax),
                          PS("model", f_ax, None)),
                out_specs=(PS(None, None), PS()),
                check_vma=False,
            )(x2, params["router"], params["gate_up"], params["down"])
            if cfg.n_shared_experts:
                out = out + nl.mlp(params["shared"], x2, kind="swiglu")
            return out.reshape(shape), aux
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = 1
        for a in dp:
            dp_size *= sizes[a]
        if x2.shape[0] % dp_size != 0:   # e.g. batch=1 long-decode
            dp = ()
        fn = lambda xl, rw, gu, dn: _moe_local(xl, rw, gu, dn, cfg=cfg,
                                               model_axis="model")
        # tokens sharded over DP (flattened B*L), replicated over model;
        # experts sharded over model; router replicated.
        out, aux = compat.shard_map(
            fn, mesh=mesh,
            in_specs=(PS(dp or None, None), PS(None, None),
                      PS("model", None, None, None), PS("model", None, None)),
            out_specs=(PS(dp or None, None), PS()),
            check_vma=False,
        )(x2, params["router"], params["gate_up"], params["down"])
    else:
        out, aux = _moe_local(x2, params["router"], params["gate_up"],
                              params["down"], cfg=cfg, model_axis=None)

    if cfg.n_shared_experts:
        out = out + nl.mlp(params["shared"], x2, kind="swiglu")
    return out.reshape(shape), aux
