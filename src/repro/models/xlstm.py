"""xLSTM mixers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential scan).

TPU adaptation (DESIGN.md §3): the mLSTM recurrence
    C_t = f_t C_{t-1} + i_t v_t k_t^T ,  y_t = (q_t C_t) / max(|q_t n_t|, 1)
is evaluated in *chunkwise-parallel* form (GLA-style): within a chunk the
decay-weighted attention matrix P[t,s] = exp(F_t - F_s) i_s (q_t.k_s) is
computed densely (F = cumulative log-decay, monotone decreasing => the
exponent is <= 0, numerically stable), and the matrix state C / normalizer
n carry across chunks via a small sequential scan.  This is the TPU-native
equivalent of the fused CUDA kernel in the xLSTM reference.

Simplifications vs the paper (noted per DESIGN.md): sigmoid input gate
(instead of exponential-with-stabilizer) for mLSTM; sLSTM keeps the
exponential gating with the m-stabilizer but uses full (non-block-diagonal)
recurrent weights.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..nn.module import P
from .common import ModelConfig

CHUNK = 128


# ------------------------------------------------------------- mLSTM -------


def mlstm_defs(cfg: ModelConfig) -> Dict:
    D, dI, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = dI // H
    return {
        "up": P((D, 2, dI), ("embed", None, "mlp")),
        "qkv": P((dI, H, 3, dh), ("mlp", None, None, None)),
        "gates": P((dI, H, 2), ("mlp", None, None), init="normal", scale=0.02),
        "gate_bias": P((H, 2), (None, None), init="zeros"),
        "out": P((dI, D), ("mlp", "embed")),
    }


def _mlstm_qkvif(params, cfg, x_m):
    """x_m: (..., dI) -> q,k,v (...,H,dh) f32; log_f, i (...,H) f32."""
    dh = cfg.d_inner // cfg.n_heads
    qkv = jnp.einsum("...i,ihcj->...hcj", x_m, params["qkv"].astype(x_m.dtype))
    q, k, v = (qkv[..., 0, :].astype(jnp.float32),
               qkv[..., 1, :].astype(jnp.float32) * dh ** -0.5,
               qkv[..., 2, :].astype(jnp.float32))
    g = jnp.einsum("...i,ihc->...hc", x_m, params["gates"].astype(x_m.dtype)
                   ).astype(jnp.float32) + params["gate_bias"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(g[..., 0])      # forget gate in log space
    i_g = jax.nn.sigmoid(g[..., 1])            # input gate (stable sigmoid)
    return q, k, v, log_f, i_g


def mlstm_forward(params, cfg: ModelConfig, x, *, return_state: bool = False):
    B, L, D = x.shape
    H = cfg.n_heads
    dh = cfg.d_inner // H
    up = jnp.einsum("bld,dcj->blcj", x, params["up"].astype(x.dtype))
    x_m, z = up[:, :, 0], up[:, :, 1]
    q, k, v, log_f, i_g = _mlstm_qkvif(params, cfg, x_m)

    n_chunks = max(1, L // CHUNK)
    c = L // n_chunks
    rs = lambda a: a.reshape((B, n_chunks, c) + a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, lfc, igc = map(rs, (q, k, v, log_f, i_g))

    def chunk(carry, inp):
        C0, n0 = carry                       # (B,H,dh,dh), (B,H,dh)
        q_, k_, v_, lf, ig = inp             # (B,c,H,dh)... (B,c,H)
        F = jnp.cumsum(lf, axis=1)           # (B,c,H) cumulative log decay
        # cross-chunk: y_t += exp(F_t) q_t C0 ; denom += exp(F_t) q_t n0
        qF = q_ * jnp.exp(F)[..., None]
        cross = jnp.einsum("bchd,bhde->bche", qF, C0)
        cross_n = jnp.einsum("bchd,bhd->bch", qF, n0)
        # intra-chunk: P[t,s] = exp(F_t - F_s) i_s (q_t . k_s), s <= t
        logdiff = F[:, :, None] - F[:, None]           # (B,c,c,H) t,s
        mask = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(logdiff), 0.0)
        s = jnp.einsum("bthd,bshd->btsh", q_, k_)
        Pm = s * w * ig[:, None]                        # i_s -> broadcast s
        y = cross + jnp.einsum("btsh,bshd->bthd", Pm, v_)
        denom = cross_n + jnp.sum(Pm, axis=2)           # (B,c,H)
        y = y / jnp.maximum(jnp.abs(denom), 1.0)[..., None]
        # state to end of chunk
        decay_tail = jnp.exp(F[:, -1:] - F)             # exp(F_c - F_s)
        kw = k_ * (decay_tail * ig)[..., None]
        C1 = C0 * jnp.exp(F[:, -1])[..., None, None] + jnp.einsum(
            "bshd,bshe->bhde", kw, v_)
        n1 = n0 * jnp.exp(F[:, -1])[..., None] + jnp.sum(kw, axis=1)
        return (C1, n1), y

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    (C1, n1), ys = jax.lax.scan(chunk, (C0, n0), (qc, kc, vc, lfc, igc))
    y = ys.swapaxes(0, 1).reshape(B, L, H * dh)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out"].astype(x.dtype)
    state = {"C": C1, "n": n1} if return_state else None
    return out, state


def mlstm_step(params, cfg: ModelConfig, x_t, state):
    """x_t: (B, D); state {'C': (B,H,dh,dh), 'n': (B,H,dh)} (f32)."""
    up = jnp.einsum("bd,dcj->bcj", x_t, params["up"].astype(x_t.dtype))
    x_m, z = up[:, 0], up[:, 1]
    q, k, v, log_f, i_g = _mlstm_qkvif(params, cfg, x_m)
    f = jnp.exp(log_f)                                   # (B,H)
    C = state["C"] * f[..., None, None] + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = state["n"] * f[..., None] + i_g[..., None] * k
    y = jnp.einsum("bhd,bhde->bhe", q, C)
    denom = jnp.einsum("bhd,bhd->bh", q, n)
    y = y / jnp.maximum(jnp.abs(denom), 1.0)[..., None]
    y = y.reshape(y.shape[0], -1)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    return y @ params["out"].astype(x_t.dtype), {"C": C, "n": n}


def mlstm_state_init(cfg: ModelConfig, batch: int) -> Dict:
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32)}


# ------------------------------------------------------------- sLSTM -------


def slstm_defs(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    return {
        "wx": P((D, 4, D), ("embed", None, "mlp")),
        "rh": P((D, 4, D), ("mlp", None, None), init="normal", scale=0.02),
        "bias": P((4, D), (None, "mlp"), init="zeros"),
        "out": P((D, D), ("mlp", "embed")),
    }


def _slstm_cell(params, x_row, h, c, n, m):
    """One step; all f32 (B, D)."""
    g = (x_row + jnp.einsum("bd,dcj->bcj", h, params["rh"].astype(jnp.float32))
         + params["bias"].astype(jnp.float32))
    log_i = g[:, 0]                      # input gate (log space)
    log_f = jax.nn.log_sigmoid(g[:, 1])  # forget gate
    z = jnp.tanh(g[:, 2])
    o = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_forward(params, cfg: ModelConfig, x, *, return_state: bool = False):
    B, L, D = x.shape
    xw = jnp.einsum("bld,dcj->blcj", x, params["wx"].astype(x.dtype)
                    ).astype(jnp.float32)

    def step(carry, x_row):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(params, x_row, h, c, n, m)
        return (h, c, n, m), h

    init = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(3)) + (
        jnp.full((B, D), -1e30, jnp.float32),)
    carry, hs = jax.lax.scan(step, init, xw.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    out = y @ params["out"].astype(x.dtype)
    if not return_state:
        return out, None
    h, c, n, m = carry
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_step(params, cfg: ModelConfig, x_t, state):
    xw = jnp.einsum("bd,dcj->bcj", x_t, params["wx"].astype(x_t.dtype)
                    ).astype(jnp.float32)
    h, c, n, m = _slstm_cell(params, xw, state["h"], state["c"],
                             state["n"], state["m"])
    out = h.astype(x_t.dtype) @ params["out"].astype(x_t.dtype)
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_state_init(cfg: ModelConfig, batch: int) -> Dict:
    D = cfg.d_model
    z = lambda: jnp.zeros((batch, D), jnp.float32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, D), -1e30, jnp.float32)}
