"""Mamba-1 selective SSM mixer (jamba's sequence layer).

TPU adaptation: the recurrence  h_t = dA_t * h_{t-1} + dB_t x_t  (diagonal
A) is evaluated with a *chunked associative scan* — ``associative_scan``
inside fixed-size chunks (parallel, VMEM-friendly (B, chunk, d_inner, N)
working set) and a sequential ``lax.scan`` carrying the boundary state
across chunks.  This replaces the CUDA selective-scan kernel of the
reference implementation with a form XLA:TPU pipelines well.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..nn import layers as nl
from ..nn.module import P
from .common import ModelConfig

CHUNK = 256


def mamba_defs(cfg: ModelConfig) -> Dict:
    D, dI, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    return {
        "in_proj": P((D, 2, dI), ("embed", None, "mlp")),
        "conv": nl.causal_conv1d_defs(dI, cfg.conv_width),
        "x_proj": P((dI, R + 2 * N), ("mlp", None)),
        "dt_proj": P((R, dI), (None, "mlp")),
        "dt_bias": P((dI,), ("mlp",), init="zeros"),
        "A_log": P((dI, N), ("mlp", None), init="ones"),
        "D": P((dI,), ("mlp",), init="ones"),
        "out_proj": P((dI, D), ("mlp", "embed")),
    }


def _ssm_params(params, cfg: ModelConfig, x_c):
    """x_c: (..., dI) post-conv activations -> dt, B, C (f32)."""
    R, N = cfg.dt_rank, cfg.d_state
    proj = (x_c @ params["x_proj"].astype(x_c.dtype)).astype(jnp.float32)
    dt_low, Bs, Cs = proj[..., :R], proj[..., R:R + N], proj[..., R + N:]
    dt = jax.nn.softplus(dt_low @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    return dt, Bs, Cs


def _chunked_ssm(dt, Bs, Cs, x_c, A, *, remat: bool):
    """y_t = C_t . h_t with h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    Memory discipline (critical at jamba scale, d_inner=16k): the
    (B, c, dI, N) state tensor exists only per-chunk inside the scan body
    (VMEM-friendly working set); the scan carries (B, dI, N) across chunks
    and emits (B, c, dI) outputs.  ``jax.checkpoint`` on the body keeps the
    backward pass at the same footprint (recompute, don't store).
    """
    B, L, dI = x_c.shape
    N = A.shape[-1]
    n_chunks = max(1, L // CHUNK)
    c = L // n_chunks
    rs = lambda a: a.reshape((B, n_chunks, c) + a.shape[2:]).swapaxes(0, 1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h0, inp):
        dt_c, B_c, C_c, x_cc = inp                  # (B,c,dI) / (B,c,N)
        dA = jnp.exp(dt_c[..., None] * A)           # (B,c,dI,N)
        dBx = (dt_c * x_cc)[..., None] * B_c[:, :, None, :]
        A_cum, B_cum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        h = A_cum * h0[:, None] + B_cum
        y = jnp.einsum("bcdn,bcn->bcd", h, C_c)
        return h[:, -1], y

    body = jax.checkpoint(chunk_step) if remat else chunk_step
    h0 = jnp.zeros((B, dI, N), jnp.float32)
    h_last, ys = jax.lax.scan(
        body, h0, (rs(dt), rs(Bs), rs(Cs), rs(x_c.astype(jnp.float32))))
    return ys.swapaxes(0, 1).reshape(B, L, dI), h_last


def mamba_forward(params, cfg: ModelConfig, x, *, return_state: bool = False):
    """x: (B, L, D) -> (B, L, D) (+ decode state)."""
    B, L, D = x.shape
    xz = jnp.einsum("bld,dcj->blcj", x, params["in_proj"].astype(x.dtype))
    x_in, z = xz[:, :, 0], xz[:, :, 1]
    x_c = jax.nn.silu(nl.causal_conv1d(params["conv"], x_in))
    dt, Bs, Cs = _ssm_params(params, cfg, x_c)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))             # (dI, N)
    y, h_last = _chunked_ssm(dt, Bs, Cs, x_c, A, remat=cfg.remat)
    y = y + params["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"].astype(x.dtype)
    if not return_state:
        return out, None
    W = cfg.conv_width
    conv_state = x_in[:, -(W - 1):, :] if L >= W - 1 else jnp.pad(
        x_in, ((0, 0), (W - 1 - L, 0), (0, 0)))
    return out, {"conv": conv_state, "ssm": h_last}


def mamba_step(params, cfg: ModelConfig, x_t, state) -> Tuple[jax.Array, Dict]:
    """x_t: (B, D); state: {'conv': (B,W-1,dI), 'ssm': (B,dI,N) f32}."""
    xz = jnp.einsum("bd,dcj->bcj", x_t, params["in_proj"].astype(x_t.dtype))
    x_in, z = xz[:, 0], xz[:, 1]
    x_c, conv_state = nl.causal_conv1d_step(params["conv"], x_in, state["conv"])
    x_c = jax.nn.silu(x_c)
    dt, Bs, Cs = _ssm_params(params, cfg, x_c)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None] * A)                                # (B,dI,N)
    dBx = (dt * x_c.astype(jnp.float32))[..., None] * Bs[:, None, :]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cs)
    y = y + params["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    out = y @ params["out_proj"].astype(x_t.dtype)
    return out, {"conv": conv_state, "ssm": h}


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32)}
