"""Sublayer bodies: (pre-norm mixer) + (pre-norm FFN), dispatched on the
``Sub`` descriptor.  One uniform interface:

    sub_defs(cfg, desc)                          -> param defs
    sub_apply(params, cfg, desc, x, ctx)         -> (x, new_cache)
    sub_cache(cfg, desc, batch, capacity, dtype) -> cache pytree ({} if none)

``ctx`` carries mode ('train'|'prefill'|'decode'), positions, cache slice,
decode index, attention impl ('ref'|'kernel'), mesh, and the MLA execution
scheme — the paper's runtime-selectable feature threads through here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat
from ..core import cache as cachelib
from ..core import mla as mlalib
from ..core.attention import gqa_attention, gqa_decode
from ..core.chunked_attention import chunked_attention_pairs
from ..kernels import ops as kops
from ..nn import layers as nl
from ..nn.module import P
from . import mamba as mambalib
from . import moe as moelib
from . import xlstm as xlstmlib
from .common import ModelConfig, Sub


@dataclasses.dataclass
class Ctx:
    mode: str                       # train | prefill | prefill_chunk | decode
    positions: Optional[jax.Array]  # (B, L) for train/prefill
    index: Any = None               # decode position (traced scalar)
    cache: Optional[Dict] = None    # this sublayer's cache slice
    impl: str = "ref"               # attention impl
    mesh: Any = None
    scheme: str = "seq"             # MLA execution scheme
    capacity: int = 0               # cache capacity for prefill
    shard_mode: str = "train"       # sharding policy (see nn.sharding)
    # Paged continuous batching (MLA only): when ``lengths`` is set the
    # cache slice is a paged pool and ``index`` is unused.  Decode feeds
    # one token per slot; mode 'prefill_chunk' feeds a (B, C) chunk of
    # prompt tokens with ``n_valid`` real tokens per row, scattered into
    # the pool at positions lengths[b]..lengths[b]+n_valid[b]-1.
    block_tables: Any = None        # (B, max_blocks) int32
    lengths: Any = None             # (B,) int32 — ragged per-request
    n_valid: Any = None             # (B,) int32 — chunked prefill only


# ------------------------------------------------------------------ defs ---


def _attn_defs(cfg: ModelConfig) -> Dict:
    if cfg.attn_kind == "mla":
        return mlalib.mla_defs(cfg.mla_config())
    D, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "w_q": P((D, H, dh), ("embed", "heads", None)),
        "w_k": P((D, Hkv, dh), ("embed", "kv_heads", None)),
        "w_v": P((D, Hkv, dh), ("embed", "kv_heads", None)),
        "w_o": P((H, dh, D), ("heads", None, "embed")),
    }


def sub_defs(cfg: ModelConfig, desc: Sub, d_ff: Optional[int] = None) -> Dict:
    d: Dict = {"ln1": nl.rmsnorm_defs(cfg.d_model)}
    if desc.mixer == "attn":
        d["attn"] = _attn_defs(cfg)
    elif desc.mixer == "mamba":
        d["attn"] = mambalib.mamba_defs(cfg)
    elif desc.mixer == "mlstm":
        d["attn"] = xlstmlib.mlstm_defs(cfg)
    elif desc.mixer == "slstm":
        d["attn"] = xlstmlib.slstm_defs(cfg)
    else:
        raise ValueError(desc.mixer)
    if desc.ffn != "none":
        d["ln2"] = nl.rmsnorm_defs(cfg.d_model)
        if desc.ffn == "moe":
            d["ffn"] = moelib.moe_defs(cfg)
        else:
            d["ffn"] = nl.mlp_defs(cfg.d_model, d_ff or cfg.d_ff, kind=cfg.mlp_kind)
    return d


def sub_cache(cfg: ModelConfig, desc: Sub, batch: int, capacity: int,
              dtype=jnp.bfloat16) -> Dict:
    if desc.mixer == "attn":
        if cfg.attn_kind == "mla":
            return cachelib.latent_cache(batch, capacity, cfg.kv_lora_rank,
                                         cfg.qk_rope_dim, dtype)
        eff_cap = capacity if desc.window is None else min(capacity, cfg.max_seq)
        return cachelib.kv_cache(batch, eff_cap, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype)
    if desc.mixer == "mamba":
        return mambalib.mamba_state_init(cfg, batch, dtype)
    if desc.mixer == "mlstm":
        return xlstmlib.mlstm_state_init(cfg, batch)
    if desc.mixer == "slstm":
        return xlstmlib.slstm_state_init(cfg, batch)
    return {}


def sub_paged_cache(cfg: ModelConfig, desc: Sub, num_blocks: int,
                    block_size: int, dtype=jnp.bfloat16,
                    cache_dtype=None) -> Dict:
    """Paged decode-state for one sublayer.  Only MLA latent caches page
    (the paper's compact cache is what makes a shared block pool pay off);
    other mixers raise — serve those models through the contiguous path.
    ``cache_dtype`` in {int8, fp8} stores the pool quantized with
    per-token-slot scale leaves (core.cache)."""
    if desc.mixer == "attn" and cfg.attn_kind == "mla":
        return cachelib.paged_latent_cache(num_blocks, block_size,
                                           cfg.kv_lora_rank,
                                           cfg.qk_rope_dim, dtype,
                                           cache_dtype=cache_dtype)
    raise NotImplementedError(
        f"paged serving requires MLA attention sublayers, got "
        f"mixer={desc.mixer!r} attn_kind={cfg.attn_kind!r}")


# ------------------------------------------------------------- attention ---


def _gqa_padding(H: int, Hkv: int, model: int):
    """Function-preserving GQA head padding to align with the 'model' mesh
    axis (EXPERIMENTS.md §Perf B1).

    When H % model != 0 the attention activations cannot shard over the
    TP axis and every chip computes ALL heads (measured 12x compute waste
    on starcoder2-7b train_4k, whose 36 heads do not divide a 16-way
    axis).  Pad: replicate each kv head ``rep`` times (Hkv*rep % model ==
    0) and scatter the q heads into H_pad = Hkv*rep*ceil(q_per_kv/rep)
    slots so that slot s attends kv_pad[s // G_pad] == its original kv
    head.  Unused slots carry zero queries and their outputs are dropped,
    so forward AND backward are exactly preserved.

    Returns (src_idx (H_pad,), slot_of_head (H,), rep) or None.
    """
    if model <= 1 or H % model == 0:
        return None
    q_per_kv = H // Hkv
    rep = 1
    while (Hkv * rep) % model:
        rep += 1
    g_pad = -(-q_per_kv // rep)             # ceil
    h_pad = Hkv * rep * g_pad
    slot_of_head = np.array([(h // q_per_kv) * rep * g_pad + (h % q_per_kv)
                             for h in range(H)])
    src_idx = np.zeros(h_pad, dtype=np.int32)
    src_idx[slot_of_head] = np.arange(H)
    mask = np.zeros(h_pad, dtype=np.float32)
    mask[slot_of_head] = 1.0
    return src_idx, slot_of_head, mask, rep


def _dp_axes_of(mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def _gqa_seq(params, cfg: ModelConfig, desc: Sub, x, ctx: Ctx):
    """Train/prefill GQA path. x: (B, L, D) normalized input."""
    B, L, _ = x.shape
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bld,dhk->blhk", x, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bld,dhk->blhk", x, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bld,dhk->blhk", x, params["w_v"].astype(x.dtype))
    q = nl.apply_rope(q, ctx.positions, desc.rope_base)
    k = nl.apply_rope(k, ctx.positions, desc.rope_base)
    pad = None
    if ctx.mesh is not None and ctx.impl == "chunked":
        model = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)
                     ).get("model", 1)
        pad = _gqa_padding(cfg.n_heads, cfg.n_kv_heads, model)
    if pad is not None:
        src_idx, slot_of_head, mask, rep = pad
        from jax.sharding import NamedSharding, PartitionSpec as PS
        dp = _dp_axes_of(ctx.mesh)
        cons = lambda a: jax.lax.with_sharding_constraint(
            a, NamedSharding(ctx.mesh, PS(dp, None, "model", None)))
        q_pad = cons(jnp.take(q, src_idx, axis=2)
                     * jnp.asarray(mask, x.dtype)[None, None, :, None])
        k_pad = cons(jnp.repeat(k, rep, axis=2))
        v_pad = cons(jnp.repeat(v, rep, axis=2))
        o_pad = chunked_attention_pairs(q_pad, k_pad, v_pad, desc.causal,
                                        desc.window, 0, None)
        o = jnp.take(o_pad, slot_of_head, axis=2)
    elif ctx.impl == "kernel":
        o = kops.attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                           impl="kernel", causal=desc.causal, window=desc.window,
                           mesh=ctx.mesh).swapaxes(1, 2)
    elif ctx.impl == "chunked":
        o = chunked_attention_pairs(q, k, v, desc.causal, desc.window, 0, None)
    else:
        o = gqa_attention(q, k, v, causal=desc.causal, window=desc.window,
                          q_positions=ctx.positions[0], k_positions=ctx.positions[0])
    out = jnp.einsum("blhk,hkd->bld", o, params["w_o"].astype(x.dtype))
    new_cache = None
    if ctx.mode == "prefill":
        cap = ctx.capacity or L
        kc = jnp.zeros((B, cap, cfg.n_kv_heads, dh), x.dtype)
        vc = jnp.zeros((B, cap, cfg.n_kv_heads, dh), x.dtype)
        new_cache = cachelib.update_kv({"k": kc, "v": vc}, k, v, 0)
    return out, new_cache


def _gqa_step(params, cfg: ModelConfig, desc: Sub, x_t, ctx: Ctx):
    """Decode. x_t: (B, D) normalized input."""
    B, _ = x_t.shape
    pos = jnp.full((B, 1), ctx.index, dtype=jnp.int32)
    q = jnp.einsum("bd,dhk->bhk", x_t, params["w_q"].astype(x_t.dtype))
    k = jnp.einsum("bd,dhk->bhk", x_t, params["w_k"].astype(x_t.dtype))
    v = jnp.einsum("bd,dhk->bhk", x_t, params["w_v"].astype(x_t.dtype))
    q = nl.apply_rope(q[:, None], pos, desc.rope_base)[:, 0]
    k = nl.apply_rope(k[:, None], pos, desc.rope_base)[:, 0]
    cache = cachelib.update_kv(ctx.cache, k[:, None], v[:, None], ctx.index)
    o = gqa_decode(q, cache["k"], cache["v"], ctx.index, window=desc.window)
    out = jnp.einsum("bhk,hkd->bd", o, params["w_o"].astype(x_t.dtype))
    return out, cache


def _mla_seq(params, cfg: ModelConfig, desc: Sub, x, ctx: Ctx):
    mcfg = cfg.mla_config()
    attn_fn = None
    if ctx.impl == "kernel":
        def attn_fn(q, k, v, softmax_scale):
            return kops.attention(
                q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                impl="kernel", causal=desc.causal, window=desc.window,
                softmax_scale=softmax_scale, mesh=ctx.mesh).swapaxes(1, 2)
    elif ctx.impl == "chunked":
        def attn_fn(q, k, v, softmax_scale):
            return chunked_attention_pairs(q, k, v, desc.causal, desc.window,
                                           0, softmax_scale)
    out, entries = mlalib.mla_prefill(params, mcfg, x, ctx.positions,
                                      attn_fn=attn_fn,
                                      return_cache=ctx.mode == "prefill")
    new_cache = None
    if ctx.mode == "prefill":
        B, L, _ = x.shape
        cap = ctx.capacity or L
        new_cache = cachelib.update_latent(
            cachelib.latent_cache(B, cap, mcfg.kv_lora_rank,
                                  mcfg.qk_rope_dim, x.dtype),
            entries["ckv"], entries["krope"], 0)
    return out, new_cache


def _mla_step(params, cfg: ModelConfig, desc: Sub, x_t, ctx: Ctx):
    mcfg = cfg.mla_config()
    if ctx.lengths is not None:     # paged continuous-batching decode
        decode_kernel = None
        if ctx.impl in ("kernel", "pallas"):
            def decode_kernel(q_full, ckv, krope, tables, idx, softmax_scale,
                              **qkw):
                return kops.mla_decode_paged_attention(
                    q_full, ckv, krope, tables, idx, impl="kernel",
                    softmax_scale=softmax_scale, mesh=ctx.mesh, **qkw)
        return mlalib.mla_decode_paged(params, mcfg, x_t, ctx.cache,
                                       ctx.block_tables, ctx.lengths,
                                       scheme=ctx.scheme,
                                       decode_kernel=decode_kernel)
    decode_kernel = None
    if ctx.impl in ("kernel", "pallas"):
        def decode_kernel(q_full, ckv, krope, index, softmax_scale):
            return kops.mla_decode_attention(
                q_full, ckv, krope, index, impl="kernel",
                softmax_scale=softmax_scale, mesh=ctx.mesh)
    return mlalib.mla_decode(params, mcfg, x_t, ctx.cache, ctx.index,
                             scheme=ctx.scheme, decode_kernel=decode_kernel)


def _mla_chunk(params, cfg: ModelConfig, desc: Sub, x, ctx: Ctx):
    """Batched chunked prefill into the paged pool (mode 'prefill_chunk').
    x: (B, C, D) normalized chunk; the shared prefix is attended through
    the block table — see core.mla.mla_prefill_chunk_paged.  With
    ctx.impl 'kernel'/'pallas' the fused paged Pallas prefill kernel
    (kernels.mla_prefill) replaces the materialized block-table gather."""
    prefill_kernel, impl = None, "gather"
    if ctx.impl in ("kernel", "pallas"):
        impl = "pallas"

        def prefill_kernel(q_full, ckv, krope, tables, lens, nv,
                           softmax_scale, **qkw):
            return kops.mla_prefill_paged_attention(
                q_full, ckv, krope, tables, lens, nv, impl="kernel",
                softmax_scale=softmax_scale, mesh=ctx.mesh, **qkw)
    return mlalib.mla_prefill_chunk_paged(params, cfg.mla_config(), x,
                                          ctx.cache, ctx.block_tables,
                                          ctx.lengths, ctx.n_valid,
                                          scheme=ctx.scheme, impl=impl,
                                          prefill_kernel=prefill_kernel)


def _slstm_sharded(params, cfg: ModelConfig, x, ctx: Ctx):
    """sLSTM under shard_map over the DP axes (EXPERIMENTS.md §Perf C2).

    Under plain GSPMD autodiff, the gradient of the recurrent weights
    ``rh`` is all-reduced across the data axis INSIDE the backward BPTT
    scan — once per time step (measured: a 16.8 MB all-reduce firing
    12,288 times = 387 GB/chip/step on xlstm-350m train_4k).  Inside
    shard_map the scan runs on the local batch shard with replicated
    weights, and the weight-gradient psum happens ONCE at the shard_map
    boundary."""
    train_like = ctx.mode in ("train", "prefill")
    if ctx.mesh is None or not train_like or x.ndim != 3:
        return xlstmlib.slstm_forward(params, cfg, x,
                                      return_state=ctx.mode == "prefill")
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in ctx.mesh.axis_names)
    dp_size = 1
    for a in dp_axes:
        dp_size *= sizes[a]
    if not dp_axes or x.shape[0] % dp_size:
        return xlstmlib.slstm_forward(params, cfg, x,
                                      return_state=ctx.mode == "prefill")
    from jax.sharding import PartitionSpec as PS
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return_state = ctx.mode == "prefill"

    def local(p, xl):
        out, state = xlstmlib.slstm_forward(p, cfg, xl,
                                            return_state=return_state)
        return out, (state if return_state else {})

    pspecs = jax.tree.map(lambda _: PS(), params)
    state_specs = {k: PS(dp, None) for k in ("h", "c", "n", "m")} \
        if return_state else {}
    out, state = compat.shard_map(
        local, mesh=ctx.mesh,
        in_specs=(pspecs, PS(dp, None, None)),
        out_specs=(PS(dp, None, None), state_specs),
        check_vma=False,
    )(params, x)
    return out, (state if return_state else None)


# ---------------------------------------------------------------- apply ----


ZERO_AUX = {"balance": 0.0, "z_loss": 0.0, "dropped_frac": 0.0}


# GSPMD sequence parallelism (§Perf B3) — DEFAULT OFF.  Measured outcome on
# starcoder2-7b train_4k: compute -33%, memory -27%, temp HBM -80%, but the
# COLLECTIVE term (the cell's new bottleneck) grew +12% because GSPMD kept
# lowering the row-parallel output reductions as all-reduce instead of
# reduce-scatter around the constraint boundary.  Hypothesis refuted as a
# net win at this cell; retained for memory-limited configs (temp 55.9 ->
# 11.0 GiB is the difference between fitting and not fitting at seq 8k+).
SEQ_PARALLEL = False


def _seq_parallel_constraint(x, ctx: Ctx, *, on: bool = True):
    """Sequence parallelism, GSPMD-style (EXPERIMENTS.md §Perf B3): pin the
    residual stream's SEQ dim to the 'model' axis between sublayers, so
    norms/elementwise run on 1/model of the tokens.

    ``on=False`` releases the constraint (Megatron SP's pre-attention
    all-gather): transitioning a seq-sharded tensor directly into the
    head-sharded QKV layout makes GSPMD fall back to full
    rematerialization (measured +2s collective on starcoder2 train_4k);
    gathering the sequence FIRST makes the head shard a free slice."""
    if not SEQ_PARALLEL or ctx.mesh is None or ctx.mode != "train" \
            or x.ndim != 3:
        return x
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    if x.shape[1] % sizes.get("model", 1):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as PS
    spec = PS(_dp_axes_of(ctx.mesh), "model" if on else None, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def sub_apply(params, cfg: ModelConfig, desc: Sub, x, ctx: Ctx):
    """x: (B, L, D) for train/prefill, (B, D) for decode.
    Returns (x, new_cache, aux) — aux has a FIXED structure (zeros when the
    sublayer has no router) so it can thread through lax.scan ys."""
    sp = desc.mixer == "attn"   # SSM scans iterate the seq dim: keep whole
    x = _seq_parallel_constraint(x, ctx, on=sp)
    h = nl.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if sp:
        # Megatron-SP boundary: gather the sequence before the QKV
        # projection (head sharding then becomes a free slice).
        h = _seq_parallel_constraint(h, ctx, on=False)
    if ctx.mode == "prefill_chunk" and \
            not (desc.mixer == "attn" and cfg.attn_kind == "mla"):
        raise NotImplementedError(
            "chunked paged prefill requires MLA attention sublayers")
    if desc.mixer == "attn":
        if cfg.attn_kind == "mla":
            fn = {"decode": _mla_step,
                  "prefill_chunk": _mla_chunk}.get(ctx.mode, _mla_seq)
        else:
            fn = _gqa_step if ctx.mode == "decode" else _gqa_seq
        a, new_cache = fn(params["attn"], cfg, desc, h, ctx)
    elif desc.mixer == "mamba":
        if ctx.mode == "decode":
            a, new_cache = mambalib.mamba_step(params["attn"], cfg, h, ctx.cache)
        else:
            a, new_cache = mambalib.mamba_forward(
                params["attn"], cfg, h, return_state=ctx.mode == "prefill")
    elif desc.mixer == "mlstm":
        if ctx.mode == "decode":
            a, new_cache = xlstmlib.mlstm_step(params["attn"], cfg, h, ctx.cache)
        else:
            a, new_cache = xlstmlib.mlstm_forward(
                params["attn"], cfg, h, return_state=ctx.mode == "prefill")
    elif desc.mixer == "slstm":
        if ctx.mode == "decode":
            a, new_cache = xlstmlib.slstm_step(params["attn"], cfg, h, ctx.cache)
        else:
            a, new_cache = _slstm_sharded(params["attn"], cfg, h, ctx)
    else:
        raise ValueError(desc.mixer)
    x = x + a

    aux = {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}
    if desc.ffn != "none":
        h = nl.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if desc.ffn == "moe":
            f, aux = moelib.moe_apply(params["ffn"], cfg, h, mesh=ctx.mesh,
                                      shard_mode=ctx.shard_mode)
            aux = {k: jnp.asarray(aux[k], jnp.float32) for k in ZERO_AUX}
        else:
            f = nl.mlp(params["ffn"], h, kind=cfg.mlp_kind)
        x = x + f
    return x, (new_cache if new_cache is not None else {}), aux
