"""Telemetry facade wiring the tracer, metrics registry, roofline drift
tracker and structured logger into one object the serving engine takes.

    tel = Telemetry.on(trace=True, metrics=True, drift=True)
    eng = PagedMLAEngine(..., telemetry=tel)
    eng.run(reqs)
    tel.finalize(eng)
    tel.export(trace_path="out.json", metrics_path="metrics.json",
               drift_path="drift.json")

Disabled mode (``Telemetry.off()`` — the engine default) costs one
attribute check per instrumentation site and one no-op call per span:
the hot path never formats a string or allocates a dict on behalf of
telemetry that is off (bench_serving gates the per-step total under 2%
of mean step latency).

Cost placement: the per-STEP phase spans, step/phase histograms and
drift rows are recorded live inside ``engine.step`` (they need the
clock around the device call); everything per-REQUEST is reconstructed
in :meth:`Telemetry.finalize` from the lifecycle timestamps the
scheduler stamps onto each ``Request`` (submit/admit/first-token/finish,
one ``perf_counter`` per transition) — so request bookkeeping costs the
hot loop nothing regardless of telemetry mode.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from .drift import RooflineDrift
from .logger import StructLogger
from .metrics import MetricsRegistry
from .trace import NULL_TRACER, PID_ENGINE, PID_REQUESTS, Tracer

# EngineStats summary keys mirrored into the counters section (the
# registry "subsumes EngineStats" — parity is pinned in tests/test_obs.py)
_ENGINE_COUNTERS = (
    "steps", "decode_tokens", "prefill_tokens", "prompt_tokens",
    "prefill_chunks", "admissions", "mid_gen_admissions", "preemptions",
    "scheme_switches", "spec_rounds", "spec_drafted", "spec_accepted",
    "fork_groups", "fork_children",
)
_ENGINE_GAUGES = (
    "tokens_per_s", "cache_utilization", "pool_occupancy",
    "spec_accept_rate", "spec_mean_emitted",
)


class Telemetry:
    def __init__(self, *, tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 drift: Optional[RooflineDrift] = None,
                 logger: Optional[StructLogger] = None):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.drift = drift
        self.logger = logger
        self.enabled = bool(self.tracer.enabled or metrics is not None
                            or drift is not None)
        self._finalized = False

    @classmethod
    def off(cls) -> "Telemetry":
        return OFF_TELEMETRY

    @classmethod
    def on(cls, *, trace: bool = True, metrics: bool = True,
           drift: bool = True,
           logger: Optional[StructLogger] = None) -> "Telemetry":
        return cls(tracer=Tracer() if trace else None,
                   metrics=MetricsRegistry() if metrics else None,
                   drift=RooflineDrift() if drift else None, logger=logger)

    # ---------------------------------------------------------- finalize --

    def finalize(self, engine) -> "Telemetry":
        """Build the per-request lifecycle spans and the metrics snapshot
        from the engine's terminal state (idempotent).  Duck-typed on
        ``engine.sched`` / ``engine.summary()`` so obs stays import-free
        of the runtime package."""
        if self._finalized:
            return self
        self._finalized = True
        sched = engine.sched
        reqs = (list(sched.finished)
                + [r for r in sched.slots if r is not None]
                + list(sched.waiting))
        if self.tracer.enabled:
            self._emit_request_spans(reqs)
        if self.metrics is not None:
            self._snapshot_metrics(engine, sched)
        return self

    def _emit_request_spans(self, reqs) -> None:
        tr = self.tracer
        tr.set_process_name(PID_ENGINE, "engine")
        tr.set_thread_name(PID_ENGINE, 0, "step phases")
        tr.set_process_name(PID_REQUESTS, "requests")
        rel = lambda t: max(t - tr.t0, 0.0)
        for req in reqs:
            tid = int(req.rid)
            tr.set_thread_name(PID_REQUESTS, tid, f"req {req.rid}")
            if req.submit_t >= 0:
                tr.instant_at("arrival", PID_REQUESTS, tid, rel(req.submit_t))
            if req.submit_t >= 0 and req.admit_t >= 0:
                tr.complete("queued", PID_REQUESTS, tid, rel(req.submit_t),
                            rel(req.admit_t))
            if req.admit_t >= 0 and req.first_tok_t >= 0:
                tr.complete("prefill", PID_REQUESTS, tid, rel(req.admit_t),
                            rel(req.first_tok_t),
                            args={"plen": req.plen, "cached": req.n_cached})
            if req.first_tok_t >= 0 and req.finish_t >= 0:
                tr.complete("decode", PID_REQUESTS, tid,
                            rel(req.first_tok_t), rel(req.finish_t),
                            args={"new_tokens": len(req.output)})
                tr.instant_at("finish", PID_REQUESTS, tid, rel(req.finish_t))
            for t in req.preempt_ts:
                tr.instant_at("preempt", PID_REQUESTS, tid, rel(t))

    def _snapshot_metrics(self, engine, sched) -> None:
        m = self.metrics
        summ = engine.summary()
        m.engine_summary = summ
        for k in _ENGINE_COUNTERS:
            m.counter(f"engine.{k}").value = float(summ[k])
        for k in _ENGINE_GAUGES:
            m.gauge(f"engine.{k}").set(float(summ[k]))
        for k, v in summ.items():
            if k.startswith("prefix_"):
                m.gauge(f"prefix_cache.{k[len('prefix_'):]}").set(float(v))
        m.counter("requests.finished").value = float(len(sched.finished))
        qd = m.histogram("queue_delay_ms")
        ttft = m.histogram("ttft_ms")
        tpot = m.histogram("tpot_ms")
        for req in sched.finished:
            if req.submit_t >= 0 and req.admit_t >= 0:
                qd.record((req.admit_t - req.submit_t) * 1e3)
            if req.submit_t >= 0 and req.first_tok_t >= 0:
                ttft.record((req.first_tok_t - req.submit_t) * 1e3)
            n = len(req.output)
            if req.first_tok_t >= 0 and req.finish_t >= 0 and n > 1:
                tpot.record((req.finish_t - req.first_tok_t) / (n - 1) * 1e3)

    # ------------------------------------------------------------ export --

    def export(self, *, trace_path: Optional[str] = None,
               metrics_path: Optional[str] = None,
               drift_path: Optional[str] = None) -> Dict[str, str]:
        """Write the requested artifacts; returns {channel: path}."""
        written: Dict[str, str] = {}
        if trace_path:
            written["trace"] = self.tracer.export(trace_path)
        if metrics_path and self.metrics is not None:
            written["metrics"] = self.metrics.save(metrics_path)
        if drift_path and self.drift is not None:
            with open(drift_path, "w") as f:
                json.dump(self.drift.report(), f, indent=1)
            written["drift"] = drift_path
        return written


OFF_TELEMETRY = Telemetry()
