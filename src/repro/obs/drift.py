"""Roofline drift channel: modeled step cost vs measured wall time.

The paper's contribution is a closed-form *model* of MLA serving cost,
and the runtime dispatches schemes per step off that model
(``core.schemes.auto_dispatch``) — but a model nobody compares against
measurements rots silently.  This tracker closes the loop: every engine
step records the hwmodel-predicted time and off-chip bytes for the
scheme it actually dispatched next to the measured device-step wall
time, and :meth:`RooflineDrift.report` aggregates the ratio per
(kind x scheme x batch bucket).

What "drift" means here: on the serving hardware the model was
calibrated for, ``measured / predicted`` sits near a stable constant per
bucket; on CPU CI the absolute ratio is huge (the model predicts TPU
time) but still *stable step to step* — so the regression gate
(benchmarks/check_regression.py) watches the per-bucket p50 ratio and
its p95/p50 spread against committed baselines rather than the absolute
value: a cost-model term going wrong, or a runtime path suddenly doing
more work than the model claims, moves both.

Predictions reuse the exact functions the dispatcher consults
(``core.schemes.step_time`` / ``verify_time`` / ``prefill_time`` and the
byte totals underneath them in ``hwmodel.attention_costs``), so the
drift channel can never disagree with the dispatch about what was
promised.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .metrics import percentile


def batch_bucket(batch: int) -> int:
    """Power-of-two bucket (1, 2, 4, 8, ...) so the report stays small."""
    b = 1
    while b < batch:
        b *= 2
    return b


@dataclasses.dataclass
class DriftRow:
    kind: str          # decode | verify | prefill
    scheme: str
    batch: int
    cache_len: int
    pred_time_s: float
    pred_bytes: float
    meas_time_s: float

    @property
    def ratio(self) -> float:
        return self.meas_time_s / max(self.pred_time_s, 1e-12)


class RooflineDrift:
    """Per-step predicted-vs-measured recorder.

    Construct unbound (``RooflineDrift()``) and let the engine
    :meth:`bind` its model context (MLA shape, platform point, paged
    block size, DP shard count) at startup, or pass everything up front.
    ``record`` is a no-op until bound with a platform — an engine pinned
    to a fixed scheme with no :class:`~repro.core.schemes.PlatformPoint`
    has no model to drift from.
    """

    def __init__(self, mla=None, platform=None, paged_block: int = 0,
                 dp_shards: int = 1, cache_dtype: Optional[str] = None):
        self.mla = mla
        self.platform = platform
        self.paged_block = paged_block
        self.dp_shards = dp_shards
        # cache_dtype keeps the predictions dispatcher-exact for quantized
        # pools: a drift channel still pricing bf16 cache streams would
        # report phantom "drift" the moment --cache-dtype int8 lands.
        self.cache_dtype = cache_dtype
        self.rows: List[DriftRow] = []

    def bind(self, *, mla, platform, paged_block: int,
             dp_shards: int = 1, cache_dtype: Optional[str] = None) -> None:
        self.mla = mla
        self.platform = platform
        self.paged_block = paged_block
        self.dp_shards = dp_shards
        self.cache_dtype = cache_dtype

    @property
    def active(self) -> bool:
        return self.mla is not None and self.platform is not None

    # ------------------------------------------------------------ record --

    def record_decode(self, scheme: str, batch: int, cache_len: int,
                      meas_time_s: float) -> None:
        if not self.active:
            return
        from ..core.schemes import cache_width, step_time
        from ..hwmodel import attention_costs as ac
        t = step_time(scheme, self.mla, self.platform, cache_len=cache_len,
                      batch=batch, paged_block=self.paged_block,
                      dp_shards=self.dp_shards, cache_dtype=self.cache_dtype)
        c = ac.mla_decode_cost(self.mla, scheme=scheme, cache_len=cache_len,
                               batch=batch,
                               dtype_bytes=self.platform.dtype_bytes,
                               paged_block=self.paged_block,
                               dp_shards=self.dp_shards,
                               cache_dtype_bytes=cache_width(
                                   self.mla, self.platform,
                                   self.cache_dtype))
        self.rows.append(DriftRow("decode", scheme, batch, cache_len,
                                  t, c.bytes, meas_time_s))

    def record_verify(self, scheme: str, batch: int, cache_len: int, k: int,
                      meas_time_s: float) -> None:
        if not self.active:
            return
        from ..core.schemes import cache_width, verify_time
        from ..hwmodel import attention_costs as ac
        t = verify_time(scheme, self.mla, self.platform, cache_len=cache_len,
                        k=k, batch=batch, paged_block=self.paged_block,
                        dp_shards=self.dp_shards,
                        cache_dtype=self.cache_dtype)
        c = ac.mla_verify_cost(self.mla, scheme=scheme, cache_len=cache_len,
                               k=k, batch=batch,
                               dtype_bytes=self.platform.dtype_bytes,
                               paged_block=self.paged_block,
                               dp_shards=self.dp_shards,
                               cache_dtype_bytes=cache_width(
                                   self.mla, self.platform,
                                   self.cache_dtype))
        self.rows.append(DriftRow("verify", scheme, batch, cache_len,
                                  t, c.bytes, meas_time_s))

    def record_prefill(self, scheme: str, batch: int, seq_len: int,
                       chunk: int, impl: str, meas_time_s: float,
                       cached_prefix: int = 0) -> None:
        """One row per admitted-batch prefill (the whole chunk loop, not
        per chunk — ``seq_len`` is the longest prompt in the batch, the
        extent the cost model's chunk walk covers)."""
        if not self.active:
            return
        from ..core.schemes import cache_width, prefill_time
        from ..hwmodel import attention_costs as ac
        t = prefill_time(self.mla, self.platform, seq_len=seq_len,
                         batch=batch, cached_prefix=cached_prefix,
                         chunk=chunk, paged_block=self.paged_block,
                         impl=impl, cache_dtype=self.cache_dtype)
        c = ac.mla_prefill_chunk_cost(self.mla, seq_len=seq_len, chunk=chunk,
                                      paged_block=self.paged_block,
                                      batch=batch,
                                      dtype_bytes=self.platform.dtype_bytes,
                                      cached_prefix=cached_prefix, impl=impl,
                                      cache_dtype_bytes=cache_width(
                                          self.mla, self.platform,
                                          self.cache_dtype))
        self.rows.append(DriftRow("prefill", scheme, batch, seq_len,
                                  t, c.bytes, meas_time_s))

    # ------------------------------------------------------------ report --

    def schemes_covered(self) -> Dict[str, List[str]]:
        out: Dict[str, set] = {}
        for r in self.rows:
            out.setdefault(r.kind, set()).add(r.scheme)
        return {k: sorted(v) for k, v in sorted(out.items())}

    def report(self) -> Dict:
        """Aggregate per (kind x scheme x batch bucket): row count,
        modeled vs measured time sums, measured/modeled ratio p50 + p95,
        spread (p95/p50 — machine-speed-independent), and mean modeled
        bytes per step."""
        buckets: Dict[str, List[DriftRow]] = {}
        for r in self.rows:
            key = f"{r.kind}/{r.scheme}/b{batch_bucket(r.batch)}"
            buckets.setdefault(key, []).append(r)
        out_buckets = {}
        all_ratios: List[float] = []
        for key, rows in sorted(buckets.items()):
            ratios = sorted(r.ratio for r in rows)
            all_ratios.extend(ratios)
            p50, p95 = percentile(ratios, 50), percentile(ratios, 95)
            out_buckets[key] = {
                "n": len(rows),
                "pred_time_s": sum(r.pred_time_s for r in rows),
                "meas_time_s": sum(r.meas_time_s for r in rows),
                "pred_bytes_per_step": (sum(r.pred_bytes for r in rows)
                                        / len(rows)),
                "time_ratio_p50": p50,
                "time_ratio_p95": p95,
                "spread": p95 / max(p50, 1e-12),
            }
        all_ratios.sort()
        kinds = {}
        for kind, schemes in self.schemes_covered().items():
            kinds[kind] = {"schemes": schemes,
                           "rows": sum(1 for r in self.rows
                                       if r.kind == kind)}
        p50 = percentile(all_ratios, 50)
        p95 = percentile(all_ratios, 95)
        return {
            "platform": self.platform.name if self.platform else None,
            "paged_block": self.paged_block,
            "dp_shards": self.dp_shards,
            "cache_dtype": self.cache_dtype or "bf16",
            "rows": len(self.rows),
            "kinds": kinds,
            "buckets": out_buckets,
            "summary": {
                "time_ratio_p50": p50,
                "time_ratio_p95": p95,
                "spread": p95 / max(p50, 1e-12),
            },
        }

    def check_coverage(self, schemes_used: Dict[str, int],
                       kinds: Optional[List[str]] = None) -> List[str]:
        """Problems list: schemes the engine dispatched (engine
        ``schemes_used`` keys) that have no drift row in the expected
        kinds (decode/verify)."""
        covered = self.schemes_covered()
        seen = set()
        for kind in (kinds or ("decode", "verify")):
            seen.update(covered.get(kind, []))
        return [f"scheme '{s}' dispatched but has no drift row"
                for s in sorted(schemes_used) if s not in seen]
