"""Structured logging for the serving runtime.

Replaces the ad-hoc ``print`` / ``log(f"[engine] ...")`` paths so every
engine/loop line carries machine-readable context (step, request id,
phase) and the whole stream can be silenced or JSON-formatted uniformly:

    log = StructLogger("engine")                  # text lines to print
    log = StructLogger("engine", json_mode=True)  # one JSON object/line
    log = StructLogger("engine", level="off")     # silenced
    log.info("admitted", step=12, rid=3, slot=0)

Text mode renders ``[engine] admitted step=12 rid=3 slot=0``; JSON mode
renders ``{"logger": "engine", "msg": "admitted", "step": 12, ...}``.

``as_logger`` adapts the bare ``log=print``-style callables the existing
APIs accept (tests pass ``log=lambda *_: None``) into a StructLogger
writing through that callable, so ``TrainLoop`` and ``PagedMLAEngine``
route one code path regardless of what the caller handed them.
"""
from __future__ import annotations

import json
from typing import Callable, Dict, Optional

_LEVELS = {"debug": 10, "info": 20, "warning": 30, "off": 100}


class StructLogger:
    def __init__(self, name: str = "repro", *, sink: Callable = print,
                 level: str = "info", json_mode: bool = False,
                 bound: Optional[Dict] = None):
        if level not in _LEVELS:
            raise ValueError(f"unknown level {level!r} "
                             f"(one of {sorted(_LEVELS)})")
        self.name = name
        self.sink = sink
        self.level = level
        self.json_mode = json_mode
        self.bound = dict(bound or {})

    def bind(self, **fields) -> "StructLogger":
        """Child logger with ``fields`` attached to every line."""
        return StructLogger(self.name, sink=self.sink, level=self.level,
                            json_mode=self.json_mode,
                            bound={**self.bound, **fields})

    @property
    def silenced(self) -> bool:
        return _LEVELS[self.level] >= _LEVELS["off"]

    def _emit(self, level: str, msg: str, fields: Dict) -> None:
        if _LEVELS[level] < _LEVELS[self.level]:
            return
        record = {**self.bound, **fields}
        if self.json_mode:
            self.sink(json.dumps({"logger": self.name, "level": level,
                                  "msg": msg, **record}))
            return
        tail = "".join(f" {k}={_fmt(v)}" for k, v in record.items())
        self.sink(f"[{self.name}] {msg}{tail}")

    def debug(self, msg: str, **fields) -> None:
        self._emit("debug", msg, fields)

    def info(self, msg: str, **fields) -> None:
        self._emit("info", msg, fields)

    def warning(self, msg: str, **fields) -> None:
        self._emit("warning", msg, fields)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def as_logger(log, name: str = "repro") -> StructLogger:
    """Adapt ``log`` into a StructLogger: StructLoggers pass through,
    bare callables (the legacy ``log=print`` API) become the sink, and
    None silences."""
    if isinstance(log, StructLogger):
        return log
    if log is None:
        return StructLogger(name, level="off")
    return StructLogger(name, sink=log)
