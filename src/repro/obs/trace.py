"""Low-overhead span tracer emitting Chrome/Perfetto trace-event JSON.

Two implementations behind one duck-typed interface:

  * :class:`Tracer` — records spans ("ph": "X" complete events), instants
    and metadata rows into an in-memory list and serializes them as the
    trace-event JSON object format (``{"traceEvents": [...]}``), which
    loads directly in Perfetto / chrome://tracing via ``export(path)``.
  * :class:`NullTracer` — the disabled mode.  Every call short-circuits
    BEFORE any string formatting or dict allocation: ``span()`` returns a
    module-level singleton context manager and ignores its arguments, so
    an instrumented hot path costs one attribute lookup plus one call per
    span when tracing is off (measured < µs/span; bench_serving gates the
    per-step total under 2% of step latency).

Conventions (what the exporter and the tests pin):

  * timestamps are MICROseconds since tracer construction
    (``time.perf_counter`` based — monotonic, sub-µs resolution);
  * pid :data:`PID_ENGINE` (1) carries the per-step phase spans (tid 0:
    schedule / prefill / draft / verify / device_step / host_sample,
    nested under one "step" span per engine tick);
  * pid :data:`PID_REQUESTS` (2) carries per-request lifecycle spans,
    one tid per request id (arrival instant, then queued -> prefill ->
    decode complete spans, then a finish or preempt instant);
  * within one (pid, tid), "X" events are properly nested — no partial
    overlap (:func:`validate_trace` checks this).
"""
from __future__ import annotations

import json
from time import perf_counter
from typing import Dict, List, Optional

PID_ENGINE = 1
PID_REQUESTS = 2


class _NullSpan:
    """Shared no-op context manager — the disabled tracer's only span."""
    __slots__ = ()
    dur_s = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every method is a no-op that ignores its
    arguments without touching them (no formatting, no allocation)."""

    enabled = False
    __slots__ = ()

    def span(self, name, pid=PID_ENGINE, tid=0):
        return _NULL_SPAN

    def complete(self, name, pid, tid, start_s, end_s, args=None):
        pass

    def instant(self, name, pid=PID_ENGINE, tid=0, args=None):
        pass

    def set_process_name(self, pid, name):
        pass

    def set_thread_name(self, pid, tid, name):
        pass

    def now(self) -> float:
        return 0.0

    def to_dict(self) -> Dict:
        return {"traceEvents": []}

    def export(self, path: str) -> None:
        raise RuntimeError("cannot export a NullTracer (tracing is off)")


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one "X" complete event on exit."""
    __slots__ = ("_tr", "_name", "_pid", "_tid", "_t0", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, pid: int, tid: int):
        self._tr = tracer
        self._name = name
        self._pid = pid
        self._tid = tid
        self.dur_s = 0.0

    def __enter__(self):
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = perf_counter()
        self.dur_s = t1 - self._t0
        tr = self._tr
        tr.events.append({
            "name": self._name, "ph": "X", "pid": self._pid,
            "tid": self._tid, "ts": (self._t0 - tr.t0) * 1e6,
            "dur": self.dur_s * 1e6,
        })
        return False


class Tracer:
    """Recording tracer.  ``now()`` gives seconds since construction on
    the same clock the spans use, so callers can stamp external
    timestamps (e.g. request lifecycle times captured by the scheduler)
    into retrospective :meth:`complete` events."""

    enabled = True

    def __init__(self):
        self.t0 = perf_counter()
        self.events: List[Dict] = []
        self._named: set = set()

    def now(self) -> float:
        return perf_counter() - self.t0

    # ------------------------------------------------------------ events --

    def span(self, name: str, pid: int = PID_ENGINE, tid: int = 0) -> _Span:
        return _Span(self, name, pid, tid)

    def complete(self, name: str, pid: int, tid: int, start_s: float,
                 end_s: float, args: Optional[Dict] = None) -> None:
        """Retrospective "X" event from two ``now()``-clock timestamps."""
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": start_s * 1e6, "dur": max(end_s - start_s, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, pid: int = PID_ENGINE, tid: int = 0,
                args: Optional[Dict] = None) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "pid": pid, "tid": tid,
              "ts": self.now() * 1e6}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant_at(self, name: str, pid: int, tid: int, at_s: float,
                   args: Optional[Dict] = None) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "pid": pid, "tid": tid,
              "ts": at_s * 1e6}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ---------------------------------------------------------- metadata --

    def set_process_name(self, pid: int, name: str) -> None:
        if ("p", pid) in self._named:
            return
        self._named.add(("p", pid))
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def set_thread_name(self, pid: int, tid: int, name: str) -> None:
        if ("t", pid, tid) in self._named:
            return
        self._named.add(("t", pid, tid))
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # ------------------------------------------------------------ export --

    def to_dict(self) -> Dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path


# ------------------------------------------------------------ validation --


def validate_trace(trace: Dict) -> List[str]:
    """Structural checks on a trace-event JSON object; returns a list of
    problems (empty == valid).  Pinned by tests/test_obs.py and run as an
    in-bench gate on the bench_serving smoke trace:

      * top-level ``traceEvents`` list; every event carries name/ph/pid/tid
        (+ ts for non-metadata, + dur >= 0 for "X");
      * pids/tids are integers (stable identity for Perfetto tracks);
      * within each (pid, tid), "X" spans NEST — an event starting inside
        an open span must also end inside it (no partial overlap).
    """
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' list missing"]
    per_track: Dict = {}
    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                problems.append(f"event {i} missing '{k}': {ev}")
                break
        else:
            if not isinstance(ev["pid"], int) or not isinstance(ev["tid"], int):
                problems.append(f"event {i}: non-integer pid/tid: {ev}")
            if ev["ph"] == "M":
                continue
            if "ts" not in ev:
                problems.append(f"event {i} missing 'ts': {ev}")
                continue
            if ev["ph"] == "X":
                if ev.get("dur", -1.0) < 0:
                    problems.append(f"event {i}: 'X' without dur >= 0: {ev}")
                    continue
                per_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    # nesting: sort by (start, -end); each span must close before any
    # enclosing span still on the stack does.
    eps = 1e-3  # µs slack: perf_counter is ns-resolution, format is float
    for track, evs in per_track.items():
        evs = sorted(evs, key=lambda e: (e["ts"], -(e["ts"] + e["dur"])))
        stack: List = []
        for ev in evs:
            start, end = ev["ts"], ev["ts"] + ev["dur"]
            while stack and start >= stack[-1][1] - eps:
                stack.pop()
            if stack and end > stack[-1][1] + eps:
                problems.append(
                    f"track {track}: span '{ev['name']}' "
                    f"[{start:.1f}, {end:.1f}] overlaps "
                    f"'{stack[-1][0]}' ending at {stack[-1][1]:.1f}")
            stack.append((ev["name"], end))
    return problems
