"""Metrics registry for the serving runtime: counters, gauges, and
histograms with percentile summaries, exportable as JSON and as a
human-readable table.

The registry subsumes ``runtime.engine.EngineStats``: the engine's
summary (plus the prefix-cache / allocator block) is snapshotted into the
export verbatim under ``"engine"`` (parity is tier-1-gated in
tests/test_obs.py), and the registry adds the request-level distributions
EngineStats cannot carry — TTFT, TPOT, queueing delay, per-phase step
times — as histograms with p50/p95/p99.

Percentile math (pinned by tests): linear interpolation between closest
ranks on the sorted sample, i.e. numpy's default ``np.percentile``
definition — p in [0, 100] maps to rank ``p/100 * (n-1)``.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional


def percentile(sorted_vals: List[float], p: float) -> float:
    """Linear-interpolation percentile of an ASCENDING-sorted sample."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return float(sorted_vals[0])
    rank = (p / 100.0) * (len(sorted_vals) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = rank - lo
    return float(sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-sample histogram (the serving bench records thousands of
    points, not millions — keep the math exact rather than sketched)."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: List[float] = []

    def record(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"count": 0}
        s = sorted(self.values)
        return {
            "count": len(s),
            "mean": sum(s) / len(s),
            "min": s[0],
            "max": s[-1],
            "p50": percentile(s, 50),
            "p95": percentile(s, 95),
            "p99": percentile(s, 99),
        }


class MetricsRegistry:
    """Get-or-create registry; metric names are flat dotted strings."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.engine_summary: Optional[Dict[str, float]] = None

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    # ------------------------------------------------------------ export --

    def to_dict(self) -> Dict:
        out: Dict = {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
        }
        if self.engine_summary is not None:
            out["engine"] = self.engine_summary
        return out

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path

    def render_table(self) -> str:
        """Human summary: one aligned row per metric; histograms show
        count / mean / p50 / p95 / p99."""
        lines: List[str] = []
        w = max([len(k) for k in (*self.counters, *self.gauges,
                                  *self.histograms)] + [6])
        for k in sorted(self.counters):
            lines.append(f"  {k:<{w}}  {self.counters[k].value:>12g}")
        for k in sorted(self.gauges):
            lines.append(f"  {k:<{w}}  {self.gauges[k].value:>12.4g}")
        if self.histograms:
            lines.append(f"  {'-- histograms --':<{w}}  "
                         f"{'count':>8} {'mean':>10} {'p50':>10} "
                         f"{'p95':>10} {'p99':>10}")
            for k in sorted(self.histograms):
                s = self.histograms[k].summary()
                if not s["count"]:
                    lines.append(f"  {k:<{w}}  {0:>8}")
                    continue
                lines.append(
                    f"  {k:<{w}}  {s['count']:>8} {s['mean']:>10.4g} "
                    f"{s['p50']:>10.4g} {s['p95']:>10.4g} {s['p99']:>10.4g}")
        return "\n".join(lines)
