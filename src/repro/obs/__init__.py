"""Serving telemetry: span tracer (Perfetto trace-event JSON), metrics
registry (counters / gauges / percentile histograms), roofline drift
tracking (hwmodel-predicted vs measured step time), and structured
logging.  ``Telemetry`` is the facade the runtime takes; everything here
is import-free of the runtime package so it can be used standalone."""
from .drift import DriftRow, RooflineDrift, batch_bucket
from .logger import StructLogger, as_logger
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, percentile
from .telemetry import OFF_TELEMETRY, Telemetry
from .trace import (
    NULL_TRACER,
    PID_ENGINE,
    PID_REQUESTS,
    NullTracer,
    Tracer,
    validate_trace,
)

__all__ = [
    "Counter",
    "DriftRow",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "OFF_TELEMETRY",
    "PID_ENGINE",
    "PID_REQUESTS",
    "RooflineDrift",
    "StructLogger",
    "Telemetry",
    "Tracer",
    "as_logger",
    "batch_bucket",
    "percentile",
    "validate_trace",
]
