"""repro — multi-pod JAX framework around DeepSeek-style MLA.

Reproduction of Geens & Verhelst, "Hardware-Centric Analysis of DeepSeek's
Multi-Head Latent Attention" (2025), grown into a deployable training +
serving framework. See DESIGN.md.
"""
__version__ = "0.1.0"
