from .pipeline import DataConfig, DataState, SyntheticLM
